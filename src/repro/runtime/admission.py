"""Admission control for open-loop serving: arrival streams, a priority
queue shared with training tenants, latency tracking, and the SLO-driven
autoscaler's control law.

Serving becomes an *open* system here: requests arrive on their own
clock (``core.simulator.arrival_times`` — Poisson / diurnal / burst),
wait in an ``AdmissionQueue`` ordered by (priority class, arrival), and
enter a ``ContinuousServeLoop`` slot as soon as one frees.  Two drivers
replay the same stream against real engines on a deterministic virtual
clock (one decode step = ``step_s``):

* ``run_open_loop`` — the continuous engine: admit-on-free-slot,
  per-request completion times.
* ``run_fixed_batch`` — the fixed-batch baseline: wait for a full
  batch, drain it to the slowest member, repeat (what the serve path
  did before continuous batching).

``ServeAutoscaler`` is the control loop: it watches queue depth and the
sliding-window p99 per-token latency against a ``ServeSLO`` and asks
``ElasticPolicy.decide_scaled`` / ``PlacementEngine`` for grow, shrink
or clone actions — the same placement path trace jobs use, so serve
capacity and training tenants contend under one accounting.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry
from repro.core.simulator import ARRIVAL_REGIMES, arrival_times
from repro.runtime.serve_loop import Request

__all__ = ["ARRIVAL_REGIMES", "AdmissionQueue", "LatencyWindow",
           "ScaleAction", "ServeAutoscaler", "ServeReport", "ServeSLO",
           "request_stream", "run_fixed_batch", "run_open_loop"]


def request_stream(n: int, rate: float, seed: int,
                   regime: str = "poisson", vocab: int = 256,
                   prompt_lens: Tuple[int, int] = (4, 12),
                   max_new: Tuple[int, int] = (4, 12),
                   priority_classes: Optional[Sequence[Tuple[int, float]]]
                   = None) -> List[Request]:
    """``n`` serve requests with open-loop arrivals at offered load
    ``rate`` (req/s of virtual time).  Prompt lengths and decode budgets
    draw uniformly from their ranges (ragged by default); priorities
    sample from ``priority_classes`` [(class, weight)].  Deterministic
    given ``seed`` — the arrival process and the payload draws use
    separate rng streams, so changing the regime keeps the payloads."""
    times = arrival_times(n, rate, seed, regime=regime)
    rng = np.random.default_rng([seed, 3])
    lo_p, hi_p = prompt_lens
    lo_m, hi_m = max_new
    pris = np.zeros(n, np.int64)
    if priority_classes:
        classes = [p for p, _ in priority_classes]
        w = np.asarray([w for _, w in priority_classes], np.float64)
        picks = np.random.default_rng([seed, 4]).choice(
            len(classes), size=n, p=w / w.sum())
        pris = np.asarray([classes[int(k)] for k in picks], np.int64)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, int(rng.integers(
                        lo_p, hi_p + 1)), dtype=np.int32),
                    max_new_tokens=int(rng.integers(lo_m, hi_m + 1)),
                    priority=int(pris[i]),
                    arrival=float(times[i]))
            for i in range(n)]


class AdmissionQueue:
    """Priority admission queue: requests pop by (priority class,
    arrival, rid) — class 0 first, FIFO within a class.  The same
    priority ordering the trace scheduler applies to jobs, so a serve
    request and a training job at the same class rank consistently."""

    def __init__(self) -> None:
        self._heap: List[Tuple[int, float, int, Request]] = []

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap,
                       (req.priority, req.arrival, req.rid, req))
        tel = telemetry.get()
        if tel.enabled:
            tel.count("serve.queued")
            tel.gauge("serve.queue_depth", len(self._heap),
                      t=req.arrival)

    def pop(self) -> Request:
        req = heapq.heappop(self._heap)[3]
        tel = telemetry.get()
        if tel.enabled:
            tel.gauge("serve.queue_depth", len(self._heap))
        return req

    def peek(self) -> Optional[Request]:
        return self._heap[0][3] if self._heap else None

    def depth(self) -> int:
        return len(self._heap)

    def __len__(self) -> int:
        return len(self._heap)


class LatencyWindow:
    """Sliding window of completed-request latency samples; the
    autoscaler's measurement side.  Per-token latency of a finished
    request = (t_done - arrival) / tokens — queueing delay included,
    which is exactly what an end user experiences."""

    def __init__(self, window: int = 64) -> None:
        self.window = int(window)
        self._samples: List[float] = []

    def record(self, req: Request) -> None:
        if req.t_done is None or not req.out:
            return
        lat = (req.t_done - req.arrival) / len(req.out)
        self._samples.append(lat)
        tel = telemetry.get()
        if tel.enabled:
            tel.observe("serve.latency_per_token_s", lat)
        if len(self._samples) > self.window:
            del self._samples[:-self.window]

    def percentile(self, q: float) -> Optional[float]:
        if not self._samples:
            return None
        return float(np.percentile(np.asarray(self._samples), q))

    @property
    def p50(self) -> Optional[float]:
        return self.percentile(50.0)

    @property
    def p99(self) -> Optional[float]:
        return self.percentile(99.0)


@dataclasses.dataclass
class ServeSLO:
    """The serve objective the autoscaler holds: p99 per-token latency
    below ``target_p99_s`` with queue depth bounded per slot."""
    target_p99_s: float = 0.5
    queue_high: float = 2.0        # queued requests per slot -> grow
    queue_low: float = 0.25        # queued requests per slot -> shrink
    headroom: float = 0.6          # shrink only when p99 < headroom*target


@dataclasses.dataclass
class ScaleAction:
    gang_id: str
    kind: str            # "grow" | "shrink" | "clone" | "need"
    world: int           # target world (new gang world for clone)


class ServeAutoscaler:
    """SLO-driven control loop over one or more serve gangs.

    Each control tick compares the measured p99 per-token latency and
    queue pressure against the ``ServeSLO`` and emits ``ScaleAction``s:

    * breach (p99 over target, or queue above ``queue_high``/slot) →
      grow the busiest gang 2x through ``ElasticPolicy.decide_scaled``;
      if the policy can't grow it (budget/probe), **clone** a new gang
      at base world instead — scale out when scale up is exhausted.
    * comfortable (p99 under ``headroom``*target and queue below
      ``queue_low``/slot) → shrink the largest gang 2x, retiring clones
      at min world, returning chips to the pool for training backfill.

    A cooldown of ``cooldown_s`` separates actions so one decision's
    effect lands in the window before the next is taken.  The caller
    applies actions (rescale / spawn / retire) — the controller only
    decides, against the same engine accounting placements use.
    """

    def __init__(self, policy, engine, slo: Optional[ServeSLO] = None,
                 slots_per_chip: int = 1, base_world: Optional[int] = None,
                 cooldown_s: float = 2.0, kind: str = "omp"):
        self.policy = policy
        self.engine = engine
        self.slo = slo or ServeSLO()
        self.slots_per_chip = int(slots_per_chip)
        self.base_world = base_world or policy.min_world
        self.cooldown_s = float(cooldown_s)
        self.kind = kind
        self._last_action_t = -1e18
        self.actions: List[Tuple[float, ScaleAction]] = []

    def _emit(self, now: float, act: ScaleAction) -> List[ScaleAction]:
        self._last_action_t = now
        self.actions.append((now, act))
        return [act]

    def decide(self, now: float, queue_depth: int,
               p99: Optional[float],
               gang_worlds: Dict[str, int]) -> List[ScaleAction]:
        if not gang_worlds or now - self._last_action_t < self.cooldown_s:
            return []
        slots = sum(gang_worlds.values()) * self.slots_per_chip
        per_slot = queue_depth / max(1, slots)
        breach = (p99 is not None and p99 > self.slo.target_p99_s) \
            or per_slot > self.slo.queue_high
        comfy = (p99 is None or p99 < self.slo.headroom
                 * self.slo.target_p99_s) \
            and per_slot < self.slo.queue_low
        if breach:
            # grow the most loaded gang; clone when grow is impossible;
            # when the pool itself is exhausted, emit "need" — the
            # fleet's cue to reclaim chips from elastic tenants (a
            # training gang drains at its control point) and retry
            gid = max(gang_worlds, key=lambda g: (-gang_worlds[g], g))
            new = self.policy.decide_scaled(gang_worlds[gid], self.engine,
                                            2.0, kind=self.kind)
            if new is not None and new > gang_worlds[gid]:
                return self._emit(now, ScaleAction(gid, "grow", new))
            res = self.engine.reserve(self.base_world, kind=self.kind)
            if res is not None:
                self.engine.cancel(res)
                return self._emit(
                    now, ScaleAction(f"clone-{len(self.actions)}",
                                     "clone", self.base_world))
            want = min(self.policy.max_world, gang_worlds[gid] * 2)
            if want > gang_worlds[gid]:
                return self._emit(now, ScaleAction(gid, "need", want))
            return []
        if comfy and (len(gang_worlds) > 1
                      or max(gang_worlds.values()) > self.policy.min_world):
            gid = max(gang_worlds, key=lambda g: (gang_worlds[g], g))
            new = self.policy.decide_scaled(gang_worlds[gid], self.engine,
                                            0.5, kind=self.kind)
            if new is not None and new < gang_worlds[gid]:
                return self._emit(now, ScaleAction(gid, "shrink", new))
            if len(gang_worlds) > 1:    # clone already at min world
                return self._emit(now, ScaleAction(gid, "shrink", 0))
        return []


# ---------------------------------------------------------------------------
# Open-loop drivers for real engines (virtual step clock)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServeReport:
    finished: int
    elapsed_s: float
    decoded_tokens: int
    prefill_tokens: int
    steps: int
    tokens_per_s: float
    token_lat_p50: float
    token_lat_p99: float
    ttft_p50: float
    ttft_p99: float
    queue_wait_p50: float
    queue_wait_p99: float

    @staticmethod
    def from_requests(reqs: Sequence[Request], stats,
                      elapsed: float) -> "ServeReport":
        done = [r for r in reqs if r.t_done is not None and r.out]
        tok = np.asarray([(r.t_done - r.arrival) / len(r.out)
                          for r in done]) if done else np.asarray([0.0])
        ttft = np.asarray([r.t_first - r.arrival for r in done
                           if r.t_first is not None])
        ttft = ttft if ttft.size else np.asarray([0.0])
        wait = np.asarray([r.t_admit - r.arrival for r in done
                           if r.t_admit is not None])
        wait = wait if wait.size else np.asarray([0.0])
        return ServeReport(
            finished=len(done), elapsed_s=float(elapsed),
            decoded_tokens=stats.decoded_tokens,
            prefill_tokens=stats.prefill_tokens, steps=stats.steps,
            tokens_per_s=stats.decoded_tokens / max(elapsed, 1e-9),
            token_lat_p50=float(np.percentile(tok, 50)),
            token_lat_p99=float(np.percentile(tok, 99)),
            ttft_p50=float(np.percentile(ttft, 50)),
            ttft_p99=float(np.percentile(ttft, 99)),
            queue_wait_p50=float(np.percentile(wait, 50)),
            queue_wait_p99=float(np.percentile(wait, 99)))


def run_open_loop(loop, requests: Sequence[Request], step_s: float = 1.0,
                  prefill_s: Optional[float] = None,
                  extras_fn=None) -> ServeReport:
    """Replay an open-loop request stream through a continuous-batching
    engine on a virtual clock: each decode step advances ``step_s``,
    each admission's prefill ``prefill_s`` (default ``step_s``).  A
    request joins the running batch the step a slot frees — nobody
    waits for a drain.  ``extras_fn(req)`` supplies per-request model
    extras (audio frames / image tokens) at admission."""
    prefill_s = step_s if prefill_s is None else prefill_s
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    queue = AdmissionQueue()
    now, i = 0.0, 0
    while i < len(reqs) or queue.depth() or not loop.done:
        while i < len(reqs) and reqs[i].arrival <= now + 1e-12:
            queue.push(reqs[i])
            i += 1
        while queue.depth() and loop.free_slots:
            req = queue.pop()
            loop.admit(req, now=now,
                       extras=extras_fn(req) if extras_fn else None)
            now += prefill_s
        if not loop.done:
            loop.decode_step(now=now + step_s)
            now += step_s
        elif not queue.depth() and i < len(reqs):
            now = max(now, reqs[i].arrival)       # idle: jump ahead
    return ServeReport.from_requests(reqs, loop.stats, now)


def run_fixed_batch(loop, requests: Sequence[Request], batch: int,
                    step_s: float = 1.0,
                    prefill_s: Optional[float] = None,
                    extras_fn=None) -> ServeReport:
    """The pre-continuous baseline on the same virtual clock: queue
    until ``batch`` equal-length requests are waiting (or the stream is
    exhausted), prefill them together, decode until the *slowest*
    request finishes, then admit the next batch.  ``extras_fn(reqs)``
    supplies batch-shaped model extras at each batch start."""
    prefill_s = step_s if prefill_s is None else prefill_s
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    queue = AdmissionQueue()
    now, i = 0.0, 0
    current: List[Request] = []
    while i < len(reqs) or queue.depth() or current:
        while i < len(reqs) and reqs[i].arrival <= now + 1e-12:
            queue.push(reqs[i])
            i += 1
        if not current:
            if queue.depth() >= batch or (i >= len(reqs)
                                          and queue.depth()):
                take = min(batch, queue.depth())
                current = [queue.pop() for _ in range(take)]
                for r in current:
                    r.t_admit = now
                loop.start(current,
                           extras=extras_fn(current) if extras_fn
                           else None)
                now += prefill_s * len(current)
            elif i < len(reqs):
                now = max(now, reqs[i].arrival)   # wait for the batch
                continue
        if current:
            loop.decode_step()
            now += step_s
            for r in current:
                if r.out and r.t_first is None:
                    r.t_first = now
                if len(r.out) >= r.max_new_tokens and r.t_done is None:
                    r.t_done = now
            if loop.done:
                current = []
    return ServeReport.from_requests(reqs, loop.stats, now)
