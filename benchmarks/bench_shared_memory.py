"""Paper Fig 12 (shared-memory/DGEMM overhead) — TPU adaptation.

The paper measures Faabric's distributed-shared-memory overhead on OpenMP
DGEMM.  Our analogue measures the cost of the diff-sync protocol itself on
training-state-sized buffers:

  * chunk-diff throughput (detect dirty chunks against a snapshot),
  * merge-op apply throughput, vectorized vs the pinned pre-PR
    reference implementation (the before/after of the batched data
    plane),
  * end-to-end "parallel section": N workers fork from a snapshot via
    ``TrackedFork`` (chunk-granular write tracking, the mprotect
    analogue), write disjoint slices, and ``apply_many`` merges the
    diffs back in one pass — vs a direct in-place update,
  * diff size vs write density (the protocol's bandwidth win),
  * delta-checkpoint bytes: a ``CheckpointManager`` ``(base, delta*)``
    chain on a synthetic training state, delta vs full footprint.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import diffsync as D


def _timeit(fn, reps=3):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(report, tiny=False):
    rng = np.random.default_rng(0)
    mb = 4 if tiny else 64
    base = rng.normal(size=mb * 2 ** 20 // 4).astype(np.float32)

    # dirty-chunk detection throughput (clustered writes: a contiguous 1%
    # slice — scattered single-element writes would dirty every page/chunk,
    # exactly as in the paper's page-granular tracking)
    child = base.copy()
    start = base.size // 3
    child[start:start + base.size // 100] += 1.0
    t = _timeit(lambda: D.diff_leaf(base, child))
    report("diff_detect_throughput", round(mb / t / 1024, 2), "GiB/s",
           "Fig12 analogue: dirty tracking cost")

    # merge-apply: the vectorized batched path (gather dirty chunks,
    # one merge, scatter) vs the pinned pre-PR per-chunk reference —
    # same diff, same result, before/after of the data-plane rewrite
    d = D.diff_leaf(base, child, op="sum")
    scratch = base.copy()
    t = _timeit(lambda: D.apply_leaf(scratch, d, inplace=True))
    report("merge_apply_throughput", round(mb / t / 1024, 2), "GiB/s",
           "Fig12 analogue: merge cost (vectorized, in-place)")
    t_out = _timeit(lambda: D.apply_leaf(base, d))
    report("merge_apply_throughput_outofplace",
           round(mb / t_out / 1024, 2), "GiB/s",
           "vectorized, fresh output buffer")
    t_ref = _timeit(lambda: D.reference_apply_leaf(base, d))
    report("merge_apply_throughput_reference",
           round(mb / t_ref / 1024, 2), "GiB/s",
           "pinned pre-PR chunk-loop implementation")
    report("merge_apply_speedup", round(t_ref / t, 1), "x",
           "acceptance: >=10x over the chunk-loop reference")
    report("diff_fraction_1pct_writes",
           round(d.nbytes / base.nbytes, 4), "of full state",
           "diff protocol bandwidth win")

    # write-density sweep: diff bytes vs densities (contiguous writes)
    for density in (0.001, 0.01, 0.1, 0.5):
        child = base.copy()
        k = max(1, int(base.size * density))
        child[:k] += 1.0
        dd = D.diff_leaf(base, child)
        report(f"diff_bytes_density_{density}",
               round(dd.nbytes / base.nbytes, 4), "of full state",
               "byte-wise diff scaling")

    # "parallel section": 4 workers fork from the snapshot, write
    # disjoint slices, merge back.  TrackedFork records dirty chunks at
    # write time (the mprotect analogue) so the diff needs no compare
    # pass, and apply_many merges every worker in a single output pass.
    workers = 4
    quarter = base.size // workers

    def parallel_section():
        diffs = []
        for w in range(workers):
            fork = D.TrackedFork(base)
            sl = slice(w * quarter, (w + 1) * quarter)
            np.multiply(base[sl], 1.01, out=fork.writable(sl))
            diffs.append(fork.diff(op="overwrite"))
        return D.apply_many(base, diffs)

    t_sync = _timeit(parallel_section)

    def direct():
        out = base.copy()
        out *= 1.01
        return out

    t_direct = _timeit(direct)
    report("parallel_section_overhead", round(t_sync / t_direct, 2),
           "x direct update",
           "Fig12: paper reports 20-30% WASM overhead; ours is "
           "diff-sync (acceptance: <=1.5x)")

    # the pre-PR shape of the same section: full-copy forks, a compare
    # pass per worker, and a chained merge per diff
    def parallel_section_compare():
        merged = base
        for w in range(workers):
            child = base.copy()
            child[w * quarter:(w + 1) * quarter] *= 1.01
            merged = D.apply_leaf(merged,
                                  D.diff_leaf(base, child, op="overwrite"))
        return merged

    t_cmp = _timeit(parallel_section_compare)
    report("parallel_section_overhead_compare",
           round(t_cmp / t_direct, 2), "x direct update",
           "copy-fork + compare-diff + chained merges (pre-PR shape)")
    # correctness of the merged result
    expect = base * 1.01
    got = parallel_section()
    report("parallel_section_exact",
           int(np.allclose(got, expect, rtol=1e-6)), "bool", "")
    assert np.array_equal(got, parallel_section_compare())

    # delta-checkpoint footprint: (base, delta*) chain on a synthetic
    # training state where each step touches ~1% of the weights — the
    # sparse-update regime the delta data plane is built for
    n = mb * 2 ** 20 // 8
    state = {"w": rng.normal(size=n).astype(np.float32),
             "m": np.zeros(n, dtype=np.float32),
             "step": np.int64(0)}
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, "bench", keep=4,
                                delta_chain=True, rebase_every=8)
        t0 = time.perf_counter()
        for s in range(8):
            # one contiguous ~1% block per step (a layer's worth of
            # weights), as in the paper's page-granular tracking —
            # scattered single-element writes would dirty every chunk
            off = int(rng.integers(0, n - n // 100))
            sl = slice(off, off + n // 100)
            state = {k: (np.array(v, copy=True)
                         if isinstance(v, np.ndarray) else v)
                     for k, v in state.items()}
            state["w"][sl] += 0.01
            state["m"][sl] = 0.9 * state["m"][sl] + 0.01
            state["step"] = np.int64(s)
            mgr.save(s, state)
        t_chain = time.perf_counter() - t0
        deltas = [st["bytes"] for st in mgr.stats
                  if st["kind"] == "delta"]
        full = mgr.stats[0]["full_bytes"]
        restored, _ = mgr.restore(7)
        assert np.array_equal(restored["w"], state["w"])
    report("delta_checkpoint_bytes",
           round(float(np.mean(deltas)) / 2 ** 20, 3), "MiB",
           f"avg delta link, full state = {round(full / 2**20, 1)} MiB")
    report("delta_checkpoint_fraction",
           round(float(np.mean(deltas)) / full, 4), "of full state",
           "acceptance: <=0.2 (>=5x smaller than full snapshots)")
    report("delta_checkpoint_chain_s", round(t_chain, 3), "s",
           "8 saves incl. pickling (1 full + 7 deltas)")
