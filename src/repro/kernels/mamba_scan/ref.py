"""Pure-jnp oracle for the Mamba2 SSD kernel: exact sequential recurrence.

    s_t = exp(dt_t a) s_{t-1} + dt_t x_t B_t^T
    y_t = C_t . s_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c):
    """x: (B,H,L,P); dt: (B,H,L,1); a: (H,1,1); b,c: (B,L,N)."""
    bs, h, l, p = x.shape
    n = b.shape[-1]
    xf = jnp.moveaxis(x.astype(jnp.float32), 2, 0)        # (L,B,H,P)
    dtf = jnp.moveaxis(dt.astype(jnp.float32), 2, 0)      # (L,B,H,1)
    bf = jnp.moveaxis(b.astype(jnp.float32), 1, 0)        # (L,B,N)
    cf = jnp.moveaxis(c.astype(jnp.float32), 1, 0)
    af = a[:, 0, 0]                                       # (H,)

    def step(s, inp):
        xt, dtt, bt, ct = inp                             # (B,H,P),(B,H,1)...
        da = jnp.exp(dtt[..., 0] * af)                    # (B,H)
        s = s * da[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt, bt, dtt[..., 0])
        y = jnp.einsum("bn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    s_fin, ys = jax.lax.scan(step, s0, (xf, dtf, bf, cf))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype), s_fin
