"""Telemetry plane: no-op bit-identity, recorder semantics, Action
round-trips, Chrome-trace export, and predicted-vs-live diffing
(DESIGN.md §14).

The load-bearing contract is the first block: with the default no-op
recorder, every instrumented path — simulator, placement engines
(central AND sharded), straggler control — produces output bit-identical
to a run with telemetry enabled, because recording only ever *observes*
(the ``risk_tau_s=None`` opt-in pattern).
"""
import json

import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import simulator as S
from repro.core import telemetry
from repro.core.control import Action, ControlPointRunner, \
    EwmaStragglerDetector
from repro.core.placement import CostModel, PlacementEngine, \
    ShardedPlacementEngine


@pytest.fixture(autouse=True)
def _noop_default():
    """Every test starts and ends on the module-level no-op recorder."""
    telemetry.disable()
    yield
    telemetry.disable()


def _churn_sim(sched="central", shrink=False):
    return S.Simulator(8, 4, "granular", migrate=True, policy="binpack",
                      sched=sched, shard_hosts=4,
                      checkpoint_interval=6.0, shrink_recovery=shrink)


def _churn_run(sched="central", shrink=False, seed=3):
    jobs = S.mixed_trace(14, seed=seed, chips_per_host=4,
                         arrival_rate=0.5)
    events = F.churn_schedule("spot-heavy", 8, 4, 60.0, seed=seed,
                              rate=0.05)
    return _churn_sim(sched, shrink).run(jobs, fleet_events=events)


# ---- no-op fast path: bit-identity ------------------------------------------

@pytest.mark.parametrize("sched", ["central", "sharded"])
def test_noop_recorder_is_bit_identical_on_pinned_trace(sched):
    # telemetry off vs on over the same pinned churn trace: Action
    # streams, makespan and every TraceResult counter must match
    # exactly — recording never perturbs the scheduler
    off = _churn_run(sched)
    with telemetry.recording() as tel:
        on = _churn_run(sched)
    assert off.actions == on.actions
    assert off.makespan == on.makespan
    assert off.finish_order == on.finish_order
    assert off.lost_work_s == on.lost_work_s
    assert off.straggler_migrations == on.straggler_migrations
    # and the enabled run actually recorded the timeline
    assert tel.summary()["spans_total"] > 0
    assert tel.counters["sim.runs"] == 1


def test_disabled_recorder_records_nothing():
    tel = telemetry.get()
    assert not tel.enabled
    with tel.span("x", track="t", a=1):
        pass
    tel.span_at("y", 0.0, 1.0)
    tel.instant("z", t=0.5)
    tel.count("c")
    tel.gauge("g", 2.0)
    tel.observe("h", 0.1)
    tel.step_time("cpu", "train", 0.2)
    tel.record_actions([Action("start", {"job": "a", "t": 0.0})])
    assert tel.spans == [] and tel.instants == []
    assert tel.counters == {} and tel.gauges == {}
    assert tel.histograms == {} and tel.step_times == {}


def test_recording_scope_restores_previous_recorder():
    assert telemetry.get() is not telemetry.enable()  # installs live
    live = telemetry.get()
    with telemetry.recording() as inner:
        assert telemetry.get() is inner
    assert telemetry.get() is live
    telemetry.disable()
    assert not telemetry.get().enabled


# ---- Action round-trip ------------------------------------------------------

def test_every_simulated_action_kind_round_trips_through_json():
    # churn + shrink-recovery + straggler-free mixed trace covers the
    # full Action vocabulary the simulator emits
    res = _churn_run("central", shrink=True)
    kinds = {a.kind for a in res.actions}
    assert {"start", "finish", "checkpoint"} <= kinds
    for a in res.actions:
        wire = json.loads(json.dumps(a.to_dict()))
        back = Action.from_dict(wire)
        assert back.kind == a.kind
        assert back.payload == telemetry._plain(a.payload)


def test_action_to_dict_coerces_numpy_payloads():
    a = Action("migrate", {"t": np.float64(1.5), "job": "j",
                           "placement": [(np.int64(0), np.int32(4))],
                           "hosts": np.array([1, 2])})
    wire = json.loads(json.dumps(a.to_dict()))
    assert wire == {"kind": "migrate",
                    "payload": {"t": 1.5, "job": "j",
                                "placement": [[0, 4]], "hosts": [1, 2]}}
    assert Action.from_dict(wire).payload["t"] == 1.5


# ---- recorder basics + Chrome export ----------------------------------------

def test_recorder_spans_counters_histograms_and_chrome_trace():
    with telemetry.recording() as tel:
        with tel.span("placement.reserve", track="sched", n=3):
            pass
        tel.span_at("run", 1.0, 5.0, track="gang:a", clock="virtual")
        tel.instant("action.start", t=1.0, track="gang:a",
                    clock="virtual", job="a")
        tel.instant("fleet.join", t=2.0, track="host:1", clock="virtual")
        tel.count("sim.actions", 7)
        tel.gauge("serve.queue_depth", 4, t=0.5)
        for v in (1e-5, 1e-3, 0.1):
            tel.observe("placement.decision_latency_s", v)
    s = tel.summary()
    assert s["spans_total"] == 2 and s["instants_total"] == 2
    assert s["counters"]["sim.actions"] == 7
    hist = s["histograms"]["placement.decision_latency_s"]
    assert hist["count"] == 3
    assert hist["min"] == 1e-5 and hist["max"] == 0.1

    trace = tel.to_chrome_trace()
    events = trace["traceEvents"]
    json.dumps(trace)                       # Perfetto-loadable JSON
    by_ph = {}
    for e in events:
        by_ph.setdefault(e["ph"], []).append(e)
    # virtual gang span in pid 1, host instant in pid 2, wall span in 10
    run = next(e for e in by_ph["X"] if e["name"] == "run")
    assert run["pid"] == 1 and run["dur"] == 4e6
    join = next(e for e in by_ph["i"] if e["name"] == "fleet.join")
    assert join["pid"] == 2
    wall = next(e for e in by_ph["X"] if e["name"] == "placement.reserve")
    assert wall["pid"] == 10 and wall["cat"] == "placement"
    # gauges AND counter totals render as 'C' samples with a layer cat
    assert any(e["name"] == "serve.queue_depth" and e["cat"] == "serve"
               for e in by_ph["C"])
    assert any(e["name"] == "sim.actions" and e["args"]["sim.actions"] == 7
               for e in by_ph["C"])
    # track names registered as thread metadata
    names = {e["args"]["name"] for e in by_ph["M"] if
             e["name"] == "thread_name"}
    assert {"gang:a", "host:1", "sched"} <= names


def test_spans_from_actions_builds_run_segments():
    actions = [
        Action("start", {"job": "a", "t": 0.0}),
        Action("preempt", {"job": "a", "t": 2.0}),
        Action("resume", {"job": "a", "t": 3.0}),
        Action("finish", {"job": "a", "t": 7.0}),
        Action("join", {"hosts": [4], "t": 1.0}),
        Action("start", {"job": "b", "t": 5.0}),   # left open
    ]
    spans, instants = telemetry.spans_from_actions(actions)
    segs = [(s["t0"], s["t1"], s["attrs"]["closed_by"]) for s in spans
            if s["track"] == "gang:a"]
    assert segs == [(0.0, 2.0, "preempt"), (3.0, 7.0, "finish")]
    b = next(s for s in spans if s["track"] == "gang:b")
    assert b["attrs"]["closed_by"] == "end-of-trace" and b["t1"] == 7.0
    assert any(i["track"] == "host:4" and i["name"] == "fleet.join"
               for i in instants)
    assert all(i["clock"] == "virtual" for i in instants)


# ---- diff_traces ------------------------------------------------------------

def test_diff_traces_zero_divergence_on_identical_streams():
    res = _churn_run()
    diff = telemetry.diff_traces(res, res)
    assert diff["divergences"] == 0
    assert diff["first_divergence"] is None
    assert diff["aligned"] == len(res.actions)
    for ph in diff["phase_error"].values():
        assert ph["max_abs_dt_s"] == 0.0
        assert ph["span_rel_error"] == 0.0


def test_diff_traces_reports_first_divergence_with_context():
    pred = [Action("start", {"job": "a", "t": 0.0}),
            Action("checkpoint", {"job": "a", "t": 2.0}),
            Action("finish", {"job": "a", "t": 5.0})]
    live = [pred[0],
            Action("migrate", {"job": "a", "t": 2.5}),   # extra event
            pred[1],
            Action("finish", {"job": "a", "t": 5.5})]
    diff = telemetry.diff_traces(pred, live)
    assert diff["divergences"] == 1
    first = diff["first_divergence"]
    assert first["op"] == "insert"
    assert first["live"][0]["kind"] == "migrate"
    assert first["context_before"][-1]["kind"] == "start"
    # aligned finish pair still contributes phase timing error
    assert diff["phase_error"]["finish"]["max_abs_dt_s"] == \
        pytest.approx(0.5)


def test_diff_traces_phase_error_measures_time_skew():
    pred = [Action("start", {"job": j, "t": float(i)})
            for i, j in enumerate("abc")]
    live = [Action("start", {"job": j, "t": float(i) * 1.1})
            for i, j in enumerate("abc")]
    diff = telemetry.diff_traces(pred, live)
    assert diff["divergences"] == 0
    ph = diff["phase_error"]["start"]
    assert ph["count"] == 3
    assert ph["max_abs_dt_s"] == pytest.approx(0.2)
    assert ph["span_rel_error"] == pytest.approx(0.1)


# ---- placement + calibration ------------------------------------------------

@pytest.mark.parametrize("engine_fn", [
    lambda: PlacementEngine(8, 4),
    lambda: ShardedPlacementEngine(8, 4, hosts_per_shard=4)],
    ids=["central", "sharded"])
def test_placement_decisions_record_latency_and_attrs(engine_fn):
    with telemetry.recording() as tel:
        eng = engine_fn()
        alloc = eng.reserve(6)
        assert alloc is not None
    hist = tel.histograms["placement.decision_latency_s"]
    assert hist.n == 1
    span = next(s for s in tel.spans
                if s["name"] == "placement.reserve")
    assert span["track"] == "sched"
    assert span["attrs"]["placed"] is True
    assert span["attrs"]["n"] == 6
    assert tel.counters["placement.reserve"] == 1


def test_step_time_aggregates_feed_cost_model():
    model = CostModel()
    with telemetry.recording() as tel:
        for s in (0.1, 0.2, 0.3):
            tel.step_time("cpu", "train", s)
        tel.step_time("tpu", "serve", 0.05)
        assert tel.feed_cost_model(model) == 2
    assert model.observed_step_time("cpu", "train") == \
        pytest.approx(0.2)
    agg = model.observed_step_times()
    assert agg[("cpu", "train")][0] == 3
    assert agg[("tpu", "serve")] == (1, pytest.approx(0.05))
    # blind to objects without the hook
    assert telemetry.Telemetry().feed_cost_model(object()) == 0


# ---- straggler surfacing ----------------------------------------------------

def test_straggler_detector_counts_flags_and_runner_migrations():
    with telemetry.recording() as tel:
        det = EwmaStragglerDetector(alpha=0.5, factor=1.5, patience=2)
        runner = ControlPointRunner(straggler=det)
        for step in range(6):
            runner.on_step(step, 0.1, 4)
        acts = []
        for step in range(6, 10):
            acts += runner.on_step(step, 10.0, 4)
    migrations = [a for a in acts if a.kind == "migrate"
                  and a.payload.get("reason") == "straggler"]
    assert migrations and runner.straggler_migrations == len(migrations)
    assert det.flagged >= 1
    assert tel.counters["straggler.flagged"] == det.flagged
    assert tel.counters["straggler.migrations"] == \
        runner.straggler_migrations
    assert tel.gauges["straggler.ewma_s"] > 0
    assert any(i["name"] == "straggler.flag" for i in tel.instants)


def test_trace_result_straggler_migrations_defaults_to_zero():
    res = _churn_run()
    # pure-simulator gangs have no stragglers: field exists, stays 0
    assert res.straggler_migrations == 0
