"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run pins the device count via XLA_FLAGS before any jax initialisation,
while smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Tuple

from jax.sharding import Mesh

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e target: one 16x16 pod (256 chips), or 2 pods = 512 chips.

    Axes: ("data", "model") single pod; ("pod", "data", "model") multi-pod.
    The "pod" axis rides the slow inter-pod links (DCI/DCN); "data" and
    "model" ride intra-pod ICI — the hierarchy the paper's VM-leader
    collectives exploit (DESIGN.md §5).  All axes are Auto-typed, which
    is ``compat.make_mesh``'s behaviour on every jax version.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...],
                   axes: Tuple[str, ...]) -> Mesh:
    """Small mesh over host (CPU) devices for tests/benchmarks."""
    return make_mesh(shape, axes)
