"""Discrete-event simulator of job traces on a shared cluster (paper §6).

The paper's evaluation is a *scheduling-policy* experiment: 100-job traces
of MPI (LAMMPS) and OpenMP (DGEMM) jobs on 32 8-vCPU VMs, comparing
Faabric's chip-granular Granule scheduling (+ barrier-point migration)
against fixed-slice container baselines.  That experiment is hardware-
independent given a job-time model; we reproduce it with a model calibrated
from the paper's own microbenchmarks:

* cross-host penalty: T = (W/n) * (1 + beta * chi), with chi the
  cross-host pair fraction of the gang placement
  (``Allocation.cross_host_fraction``).  beta is calibrated from Fig 14:
  compute-bound LAMMPS co-located vs 4+4-fragmented = 1.2x  -> beta = 0.4;
  network-bound all-to-all = 7.5x -> beta = 13.0.
* runtime overhead: Faabric's shared-memory (OpenMP) jobs carry a 1.25x
  execution-time factor (paper §6.4: 20–30% WASM floating-point overhead).
* migration: at barrier control points a fragmented gang may be
  consolidated; cost = snapshot transfer (Fig 14: worth it except >80%
  progress for compute-bound jobs).
* centralised-scheduler latency: a per-decision cost proportional to the
  host count (reproduces the 128-VM degradation of Fig 11).

The simulator is deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import Allocation, ClusterState

BETA = {"mpi-compute": 0.4, "mpi-network": 13.0, "omp": 1.0}
WASM_OVERHEAD_OMP = 1.25          # paper §6.4
OVERCOMMIT_PENALTY = 1.5          # threads > vCPUs in one container (§6.2)
MIGRATION_COST_S = 2.0            # snapshot transfer at a barrier point
SCHED_LATENCY_PER_HOST = 0.004    # centralised scheduler cost (Fig 11)


@dataclasses.dataclass
class Job:
    job_id: str
    kind: str                     # mpi-compute | mpi-network | omp
    parallelism: int              # MPI world size / OMP_NUM_THREADS
    work: float                   # chip-seconds at perfect scaling


@dataclasses.dataclass
class RunningJob:
    job: Job
    alloc: Allocation
    start: float
    progress: float = 0.0         # fraction of work done
    last_update: float = 0.0
    eff_parallelism: int = 0
    finish_event: int = -1        # heap token (lazy deletion)

    def rate(self) -> float:
        """Fraction of work per second under the current placement."""
        j = self.job
        chi = self.alloc.cross_host_fraction()
        overhead = 1.0 + BETA[j.kind] * chi
        runtime = WASM_OVERHEAD_OMP if (
            j.kind == "omp" and self.alloc.slice_size == 0) else 1.0
        if j.parallelism > self.alloc.n:     # overcommitted container
            runtime *= OVERCOMMIT_PENALTY
        n = self.eff_parallelism
        return n / (self.job.work * overhead * runtime)


@dataclasses.dataclass
class TraceResult:
    makespan: float
    exec_times: List[float]
    idle_samples: List[Tuple[float, float]]   # (time, idle_fraction)
    migrations: int
    waited: List[float]
    queue_drain_time: float = 0.0             # when the job queue emptied

    def idle_cdf(self, backlogged_only: bool = True) -> np.ndarray:
        """Time-weighted idle-fraction samples for CDF plotting.

        ``backlogged_only`` restricts to the period with queued jobs —
        idle chips then are pure fragmentation waste (the paper's Fig 10
        metric); the drain-down tail would otherwise dominate."""
        samples = self.idle_samples
        if backlogged_only and self.queue_drain_time > 0:
            samples = [s for s in samples
                       if s[0] <= self.queue_drain_time] or samples[:1]
        if len(samples) < 2:
            return np.asarray([samples[0][1]] if samples else [0.0])
        ts = np.array([t for t, _ in samples])
        vals = np.array([v for _, v in samples])
        w = np.diff(ts, append=ts[-1])
        order = np.argsort(vals)
        return np.repeat(vals[order], np.maximum(
            (w[order] / max(ts[-1], 1e-9) * 1000).astype(int), 1))


def generate_trace(n_jobs: int, kind: str, seed: int,
                   chips_per_host: int = 8) -> List[Job]:
    """Paper §6.2 traces: parallelism uniform over [2, 2*chips] for MPI
    (world sizes up to 2 VMs) and [2, chips] for OpenMP."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        if kind.startswith("mpi"):
            n = int(rng.integers(2, 2 * chips_per_host + 1))
            work = 400.0
        else:
            n = int(rng.integers(2, chips_per_host + 1))
            work = 240.0
        jobs.append(Job(f"{kind}-{i}", kind, n, work))
    return jobs


class Simulator:
    """Event-driven execution of a FIFO job queue on a shared cluster."""

    def __init__(self, hosts: int, chips_per_host: int, mode: str,
                 slice_size: int = 0, migrate: bool = True,
                 barrier_interval: float = 5.0):
        """mode: 'granular' (Faabric) or 'slices' (fixed baseline)."""
        self.cluster = ClusterState(hosts, chips_per_host)
        self.mode = mode
        self.slice_size = slice_size
        self.migrate = migrate and mode == "granular"
        self.barrier_interval = barrier_interval
        self.sched_latency = SCHED_LATENCY_PER_HOST * hosts

    # ---- placement --------------------------------------------------------
    def _try_place(self, job: Job) -> Optional[Allocation]:
        if self.mode == "granular":
            return self.cluster.alloc_granular(job.job_id, job.parallelism)
        if job.kind == "omp":
            # shared-memory baseline: exactly one container
            return self.cluster.alloc_slices(job.job_id, self.slice_size,
                                             self.slice_size)
        return self.cluster.alloc_slices(job.job_id, job.parallelism,
                                         self.slice_size)

    def _eff_parallelism(self, job: Job, alloc: Allocation) -> int:
        if self.mode == "granular":
            return job.parallelism
        if job.kind == "omp":
            # threads overcommit a single container (paper §6.2)
            return min(job.parallelism, alloc.n)
        return job.parallelism

    # ---- main loop ----------------------------------------------------------
    def run(self, jobs: List[Job]) -> TraceResult:
        queue: List[Job] = list(jobs)
        running: Dict[str, RunningJob] = {}
        heap: List[Tuple[float, int, str]] = []
        token = 0
        now = 0.0
        exec_times, waited = [], []
        idle_samples: List[Tuple[float, float]] = []
        submit_time = {j.job_id: 0.0 for j in jobs}
        migrations = 0

        def progress_to(t: float):
            for rj in running.values():
                rj.progress += rj.rate() * (t - rj.last_update)
                rj.last_update = t

        def schedule_finish(rj: RunningJob):
            nonlocal token
            remaining = max(0.0, 1.0 - rj.progress)
            t_fin = now + remaining / rj.rate()
            token += 1
            rj.finish_event = token
            heapq.heappush(heap, (t_fin, token, rj.job.job_id))

        def pump_queue():
            nonlocal now
            while queue:
                alloc = self._try_place(queue[0])
                if alloc is None:
                    break
                job = queue.pop(0)
                now += self.sched_latency          # centralised scheduler
                rj = RunningJob(job, alloc, start=now, last_update=now,
                                eff_parallelism=self._eff_parallelism(
                                    job, alloc))
                running[job.job_id] = rj
                waited.append(now - submit_time[job.job_id])
                schedule_finish(rj)
            idle_samples.append((now, self.cluster.idle_fraction()))

        pump_queue()
        drain_time = 0.0
        while heap:
            t, tok, job_id = heapq.heappop(heap)
            rj = running.get(job_id)
            if rj is None or rj.finish_event != tok:
                continue                            # stale event
            progress_to(t)
            now = t
            # numerical slack: the job is done
            self.cluster.release(rj.alloc)
            del running[job_id]
            exec_times.append(now - rj.start)
            # barrier-point migration: consolidate fragmented gangs
            # (only gangs with enough remaining work to pay the cost)
            if self.migrate and running:
                candidates = [r.alloc for r in running.values()
                              if r.progress <= 0.8]
                for jid, new_pl in self.cluster.migration_plan(candidates):
                    r = running[jid]
                    progress_to(now)
                    r.alloc = self.cluster.apply_migration(r.alloc, new_pl)
                    r.progress = max(
                        0.0, r.progress - MIGRATION_COST_S * r.rate())
                    migrations += 1
                    schedule_finish(r)
            had_queue = bool(queue)
            pump_queue()
            if had_queue and not queue and drain_time == 0.0:
                drain_time = now
        return TraceResult(makespan=now, exec_times=exec_times,
                           idle_samples=idle_samples, migrations=migrations,
                           waited=waited, queue_drain_time=drain_time)


def run_baselines(jobs: List[Job], hosts: int, chips_per_host: int = 8,
                  migrate: bool = True) -> Dict[str, TraceResult]:
    """Faabric vs the paper's fixed-slice baselines (1/2/4/8 ctr per VM)."""
    out = {}
    out["faabric"] = Simulator(hosts, chips_per_host, "granular",
                               migrate=migrate).run(jobs)
    for k in (1, 2, 4, 8):
        slice_size = chips_per_host // k
        out[f"{k}-ctr-per-vm"] = Simulator(
            hosts, chips_per_host, "slices", slice_size=slice_size).run(jobs)
    return out
