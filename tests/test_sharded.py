"""Sharded placement engine + vectorized hot path (PR 4).

Three pillars:

* loop parity — the numpy fill/scoring paths are bit-identical to the
  preserved pre-PR Python loops (``reference_loops``), pinned both at
  the primitive level (randomized) and action-for-action on traces;
* single-shard parity — ``ShardedPlacementEngine`` over one shard
  covering the fleet reproduces the centralised engine bit-identically
  (placements AND trace Action logs) for binpack/spread/locality;
* sharded behaviour — shard-local decisions, forwarding hops, cross-
  shard split, shard-local preemption/migration with escalation, and
  the once-per-pump scheduler-latency model.
"""
import numpy as np
import pytest

from repro.core import placement as P
from repro.core import simulator as S
from repro.core.placement import (BinpackPolicy, FixedSlicePolicy,
                                  LocalityScoredPolicy, PlacementEngine,
                                  ShardedPlacementEngine, SpreadPolicy,
                                  reference_loops)


# ---------------------------------------------------------------------------
# vectorized == reference loops
# ---------------------------------------------------------------------------
def test_fill_primitives_match_reference_loops():
    rng = np.random.default_rng(0)
    for trial in range(400):
        hosts = int(rng.integers(1, 40))
        cap = int(rng.integers(1, 12))
        free = rng.integers(0, cap + 1, hosts)
        n = int(rng.integers(1, max(2, free.sum() + 3)))
        speeds = (rng.choice([0.5, 0.75, 1.0], hosts)
                  if trial % 3 == 0 else None)
        view = P.ClusterView(free, cap, np.full(hosts, cap), speeds)
        for pol in (BinpackPolicy(), SpreadPolicy(),
                    LocalityScoredPolicy(), FixedSlicePolicy(2)):
            kind = ("omp", "mpi-network", "mpi-compute")[trial % 3]
            a = pol.place(view, n, kind=kind)
            with reference_loops():
                b = pol.place(P.ClusterView(free, cap,
                                            np.full(hosts, cap), speeds),
                              n, kind=kind)
            assert a == b, (pol.name, free.tolist(), n, speeds)


def test_trace_actions_match_reference_loops():
    """End-to-end: an arrivals/priorities/preempt/backfill trace under
    the vectorized hot path is action-for-action identical to the
    pre-PR loop implementation."""
    def run(pol):
        return S.Simulator(16, 8, "granular", policy=pol, migrate=True,
                           preempt=True, backfill=True).run(
            S.mixed_trace(60, seed=7, arrival_rate=0.3,
                          priority_classes=[(0, 0.8), (5, 0.2)]))

    for pol in ("binpack", "spread", "locality"):
        a = run(pol)
        with reference_loops():
            b = run(pol)
        assert a.actions == b.actions and a.makespan == b.makespan, pol


def test_score_batch_matches_scalar_score():
    m = P.CostModel()
    rng = np.random.default_rng(1)
    for trial in range(100):
        hosts = int(rng.integers(2, 20))
        speeds = rng.choice([0.5, 1.0], hosts) if trial % 2 else None
        pls = []
        for _ in range(int(rng.integers(1, 5))):
            k = int(rng.integers(1, min(4, hosts) + 1))
            hs = rng.choice(hosts, k, replace=False)
            pls.append(sorted((int(h), int(rng.integers(1, 8)))
                              for h in hs))
        batch = m.score_batch(pls, "omp", speeds)
        assert np.allclose(batch, [m.score(p, "omp", speeds)
                                   for p in pls], rtol=1e-12)
        assert np.allclose(P._chi_batch(pls),
                           [P.placement_cross_host_fraction(p)
                            for p in pls], rtol=1e-12)


def test_bind_with_repeated_host_keeps_accounting_consistent():
    # fancy indexing applies one update per index: a >4-entry external
    # placement that repeats a host must still account every entry
    eng = PlacementEngine(8, 8)
    a = eng.bind("dup", [(0, 3), (0, 3), (1, 1), (2, 1), (3, 1)])
    assert eng.free[0] == 2
    assert eng.idle_chips() == int(eng.free.sum()) == 64 - 9
    eng.release(a)
    assert eng.idle_chips() == eng.total_chips
    # and over-subscribing via duplicates still trips the assert
    with pytest.raises(AssertionError):
        eng.bind("over", [(0, 5), (0, 5), (1, 1), (2, 1), (3, 1)])


def test_incremental_summaries_track_free_map():
    rng = np.random.default_rng(2)
    eng = ShardedPlacementEngine(12, 8, hosts_per_shard=4,
                                 speeds=[0.5] * 6 + [1.0] * 6)
    allocs = {}
    for i in range(200):
        if allocs and rng.random() < 0.45:
            jid = sorted(allocs)[int(rng.integers(len(allocs)))]
            eng.release(allocs.pop(jid))
        else:
            a = eng.allocate(f"j{i}", int(rng.integers(1, 20)),
                             policy=("binpack", "spread",
                                     "locality")[i % 3])
            if a is not None:
                allocs[a.job_id] = a
        assert eng.idle_chips() == int(eng.free.sum())
        assert eng.idle_throughput() == pytest.approx(
            float((eng.free * eng.speeds).sum()))
        for s, (lo, hi) in enumerate(eng.shard_bounds):
            assert eng._shard_idle[s] == eng.free[lo:hi].sum()
    for a in allocs.values():
        eng.release(a)
    assert eng.idle_chips() == eng.total_chips


# ---------------------------------------------------------------------------
# single-shard parity (acceptance): sharded == centralised, bit-exact
# ---------------------------------------------------------------------------
def test_single_shard_engine_decisions_bit_identical():
    rng = np.random.default_rng(3)
    for speeds in (None, [0.5] * 8 + [1.0] * 8):
        c = PlacementEngine(16, 8, policy="locality", speeds=speeds)
        s = ShardedPlacementEngine(16, 8, hosts_per_shard=16,
                                   policy="locality", speeds=speeds)
        live = {}
        for i in range(250):
            if live and rng.random() < 0.4:
                jid = sorted(live)[int(rng.integers(len(live)))]
                ac, as_ = live.pop(jid)
                c.release(ac), s.release(as_)
            else:
                n = int(rng.integers(1, 20))
                pol = ("binpack", "spread", "locality")[i % 3]
                kind = ("mpi-compute", "omp", "mpi-network")[i % 3]
                ac = c.allocate(f"j{i}", n, policy=pol, kind=kind)
                as_ = s.allocate(f"j{i}", n, policy=pol, kind=kind)
                assert (ac is None) == (as_ is None)
                if ac is not None:
                    assert ac.placement == as_.placement
                    assert s.decision_hops == 0
                    live[f"j{i}"] = (ac, as_)
            pri = {j: 0 for j in live}
            assert c.preemption_plan(10, 5, pri) \
                == s.preemption_plan(10, 5, pri)
            kinds = {j: "mpi-network" for j in live}
            pc = c.migration_plan([a for a, _ in live.values()], kinds,
                                  {j: 50.0 for j in live})
            ps = s.migration_plan([a for _, a in live.values()], kinds,
                                  {j: 50.0 for j in live})
            assert pc == ps
            assert np.array_equal(c.free, s.free)


def test_single_shard_trace_actions_bit_identical():
    """Acceptance: one shard covering the whole fleet produces
    bit-identical trace Action logs to the centralised engine for every
    granular policy on the standard mixed trace."""
    jobs = S.mixed_trace(60, seed=7)
    for pol in ("binpack", "spread", "locality"):
        central = S.Simulator(16, 8, "granular", policy=pol,
                              migrate=True).run(list(jobs))
        sharded = S.Simulator(16, 8, "granular", policy=pol,
                              migrate=True, sched="sharded",
                              shard_hosts=16).run(list(jobs))
        assert sharded.actions == central.actions, pol
        assert sharded.makespan == central.makespan


# ---------------------------------------------------------------------------
# sharded behaviour
# ---------------------------------------------------------------------------
def test_sharded_placement_stays_shard_local_and_forwards():
    eng = ShardedPlacementEngine(32, 8, hosts_per_shard=8)
    a = eng.allocate("a", 12)
    assert {h // 8 for h, _ in a.placement} == {0}
    assert eng.decision_hops == 0
    blockers = [eng.allocate(f"b{s}", 60, policy="spread")
                for s in (1, 2, 3)]
    assert all(b is not None for b in blockers)
    # 52 chips only fit shard 0 now — the summary index routes there
    big = eng.allocate("big", 52)
    assert {h // 8 for h, _ in big.placement} == {0}
    # idle: shard0 = 0, shards 1-3 = 4 each -> a 10-gang must split
    split = eng.allocate("split", 10)
    assert len({h // 8 for h, _ in split.placement}) > 1
    assert split.n == 10 and eng.decision_hops >= 1


def test_sharded_split_conserves_and_releases():
    eng = ShardedPlacementEngine(24, 8, hosts_per_shard=8)
    gangs = [eng.allocate(f"g{i}", 30) for i in range(6)]
    assert all(g is not None for g in gangs)
    assert eng.idle_chips() == 24 * 8 - 180
    for g in gangs:
        eng.release(g)
    assert eng.idle_chips() == eng.total_chips
    assert list(eng._shard_idle) == [64, 64, 64]


def test_sharded_preemption_shard_local_then_escalates():
    eng = ShardedPlacementEngine(16, 8, hosts_per_shard=8)
    eng.allocate("low-a", 60)          # fills most of shard 0
    eng.allocate("low-b", 60)          # fills most of shard 1
    pri = {"low-a": 0, "low-b": 0}
    # one shard's eviction suffices: plan stays shard-local (1 victim)
    plan = eng.preemption_plan(60, 5, pri)
    assert plan is not None and len(plan) == 1
    # an arrival bigger than any shard escalates cross-shard
    plan = eng.preemption_plan(100, 5, pri)
    assert plan is not None and set(plan) == {"low-a", "low-b"}
    # nothing outranked -> no plan anywhere
    assert eng.preemption_plan(60, 0, pri) is None


def test_sharded_migration_shard_local_with_escalation():
    eng = ShardedPlacementEngine(6, 8, hosts_per_shard=2)
    frag = eng.bind("frag", [(0, 2), (1, 2)])     # inside shard 0
    cross = eng.bind("cross", [(3, 2), (4, 2)])   # spans shards 1-2
    plans = dict(eng.migration_plan([frag, cross]))
    # shard-local gang consolidates inside its own shard
    assert len(plans["frag"]) == 1
    assert {h // 2 for h, _ in plans["frag"]} == {0}
    # the cross-shard gang escalates to global planning and consolidates
    assert len(plans["cross"]) == 1
    eng.apply_migration(frag, plans["frag"])
    eng.apply_migration(cross, plans["cross"])
    assert eng.idle_chips() == eng.total_chips - 8


def test_sharded_simulator_latency_model():
    # single shard == centralised latency; small shards cut the
    # per-decision term to hosts_per_shard and add forwarding hops
    jobs = [S.Job(f"j{i}", "mpi-compute", 8, 80.0) for i in range(4)]
    central = S.Simulator(32, 8, "granular").run(list(jobs))
    sharded = S.Simulator(32, 8, "granular", sched="sharded",
                          shard_hosts=8).run(list(jobs))
    lat_c = S.SCHED_LATENCY_PER_HOST * 32
    lat_s = S.SCHED_LATENCY_PER_HOST * 8
    # all four jobs start in the first pump: one latency charge each
    assert central.makespan == pytest.approx(80.0 / 8 + lat_c)
    assert sharded.makespan == pytest.approx(80.0 / 8 + lat_s)
    assert sharded.makespan < central.makespan


def test_sharded_beats_central_makespan_at_scale():
    """The Fig 11 fix, in miniature: at 128 hosts the centralised
    per-decision scan cost dominates queue-era scheduling; sharding
    cuts it and the simulated makespan drops."""
    jobs = S.mixed_trace(256, seed=128, arrival_rate=2.0)
    central = S.Simulator(128, 8, "granular", policy="binpack",
                          migrate=False).run(list(jobs))
    sharded = S.Simulator(128, 8, "granular", policy="binpack",
                          migrate=False, sched="sharded",
                          shard_hosts=16).run(list(jobs))
    assert sharded.makespan < central.makespan


# ---------------------------------------------------------------------------
# once-per-pump scheduler latency (the monotone-clock fix)
# ---------------------------------------------------------------------------
def test_deep_backlog_latency_accrues_once_per_pump():
    """A deep t=0 backlog that fits concurrently is one scheduling
    pass: every gang starts after a single latency charge, and the
    makespan no longer compounds per queued job (the pre-fix behaviour
    charged k * latency for the k-th job of the pump)."""
    hosts, k = 64, 64
    jobs = [S.Job(f"j{i}", "mpi-compute", 8, 80.0) for i in range(k)]
    res = S.Simulator(hosts, 8, "granular", migrate=False).run(jobs)
    lat = S.SCHED_LATENCY_PER_HOST * hosts
    starts = [a.payload["t"] for a in res.actions if a.kind == "start"]
    assert len(starts) == k
    assert all(t == pytest.approx(lat) for t in starts)
    # one host each (chi = 0): exec = 80/8 = 10s; the pre-fix makespan
    # would have compounded to ~k*lat + 10
    assert res.makespan == pytest.approx(10.0 + lat)
    assert res.makespan < 10.0 + 2 * lat


def test_blocked_queue_pumps_do_not_charge_latency():
    # a pump that places nothing must not move the clock
    jobs = [S.Job("big", "mpi-compute", 8, 80.0),
            S.Job("blocked", "mpi-compute", 8, 160.0)]
    res = S.Simulator(1, 8, "granular").run(jobs)
    lat = S.SCHED_LATENCY_PER_HOST * 1
    # second job starts right after the first finishes + one charge
    t2 = [a.payload["t"] for a in res.actions if a.kind == "start"][1]
    assert t2 == pytest.approx(80.0 / 8 + 2 * lat)
