# Tier-1 verification and fast iteration targets.
PY ?= python

.PHONY: check quick bench-smoke

# the repo's tier-1 gate (see ROADMAP.md)
check:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast subset for scheduler/placement/simulator/fabric iteration
quick:
	PYTHONPATH=src $(PY) -m pytest -q -k "(placement or scheduler or simulator or fabric) and not run_trace and not gangs and not resume and not shared"

# benchmark smoke (the CI bench step): every benchmark at tiny sizes,
# artifacts to results/SMOKE_*.json, then assert every BENCH_/SMOKE_
# artifact parses and carries non-empty metrics
bench-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --tiny
	$(PY) benchmarks/check_results.py
