"""Benchmark driver: one module per paper table/figure.

Prints ``bench,name,value,unit,paper_ref`` CSV lines; ``--only`` selects
one benchmark; results land in results/bench.csv plus one standardized
``results/BENCH_<name>.json`` per benchmark (schema below) so the perf
trajectory is machine-readable across PRs:

    {"bench": str, "schema": 2, "unix_time": float, "wall_s": float,
     "git_sha": str, "fleet": {...},
     "metrics": {name: {"value": num, "unit": str, "note": str}}}

``git_sha`` is the commit the numbers were measured at and ``fleet``
the benchmark module's ``FLEET`` dict (hosts / chips-per-host /
scheduler config), so an artifact is attributable without the CSV.

``--tiny`` runs every benchmark at smoke sizes (the CI bench-smoke
step): artifacts then land as ``results/SMOKE_<name>.json`` so the
committed full-size ``BENCH_*.json`` trajectory is never clobbered by a
smoke run, and each smoke artifact is asserted to carry metrics.
"""
from __future__ import annotations

import argparse
import csv
import importlib
import inspect
import json
import os
import subprocess
import sys
import time

BENCHES = [
    "bench_makespan",         # Fig 10
    "bench_scaling",          # Fig 11
    "bench_shared_memory",    # Fig 12
    "bench_message_passing",  # Fig 13 / Fig 9
    "bench_migration",        # Fig 14
    "bench_scheduler_scale",  # Fig 11 fix: sharded + vectorized engine
    "bench_churn",            # fleet churn: reclaim/fail + Young/Daly
    "bench_serving",          # continuous batching + SLO autoscaling
]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
OUT = os.path.join(RESULTS_DIR, "bench.csv")


def git_sha() -> str:
    """Short SHA of the commit the numbers were measured at, with a
    ``-dirty`` marker when the working tree has uncommitted changes."""
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd)
        sha = out.stdout.strip()
        if not sha:
            return "unknown"
        status = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=cwd)
        return sha + ("-dirty" if status.stdout.strip() else "")
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_bench_json(bench: str, metrics, wall_s: float,
                     tiny: bool = False, fleet=None) -> str:
    prefix = "SMOKE" if tiny else "BENCH"
    path = os.path.join(os.path.abspath(RESULTS_DIR),
                        f"{prefix}_{bench}.json")
    payload = {
        "bench": bench,
        "schema": 2,
        "unix_time": time.time(),
        "wall_s": round(wall_s, 2),
        "git_sha": git_sha(),
        "fleet": dict(fleet or {}),
        "metrics": {name: {"value": value, "unit": unit, "note": note}
                    for name, value, unit, note in metrics},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke sizes; artifacts go to SMOKE_*.json")
    args = ap.parse_args()
    os.makedirs(os.path.abspath(RESULTS_DIR), exist_ok=True)
    rows = []
    current = ""
    current_metrics = []
    # stdout is real CSV (notes may contain commas -> quoted), matching
    # the results/bench.csv writer exactly
    stdout_csv = csv.writer(sys.stdout)

    def report(name, value, unit="", note=""):
        rows.append((current, name, value, unit, note))
        current_metrics.append((name, value, unit, note))
        stdout_csv.writerow([current, name, value, unit, note])

    stdout_csv.writerow(["bench", "name", "value", "unit", "paper_ref"])
    for mod_name in ([args.only] if args.only else BENCHES):
        current = mod_name
        current_metrics = []
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        if "tiny" in inspect.signature(mod.run).parameters:
            mod.run(report, tiny=args.tiny)
        else:
            mod.run(report)
        wall = time.time() - t0
        rows.append((mod_name, "bench_wall", round(wall, 1), "s", ""))
        path = write_bench_json(mod_name, current_metrics, wall,
                                tiny=args.tiny,
                                fleet=getattr(mod, "FLEET", None))
        assert current_metrics, f"{mod_name} reported no metrics"
        print(f"# wrote {path}")
    if not args.tiny:
        with open(OUT, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["bench", "name", "value", "unit", "paper_ref"])
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
