"""Batched serving runtime: continuous prefill + decode with KV caches.

Requests carry a prompt; the runtime batches admitted requests, prefills
them (building decode state), then decodes one token per step for the whole
batch.  Serving gangs are Granule groups like training gangs: attach a
``core.fabric.GangHandle`` and the replica's **serving state** — params +
decode caches + next-token cursor — lives replicated on the gang's mesh.
That state is the snapshot, so migration, preemption and bit-exact resume
work identically to training (a KV cache is just more shared state to diff
— paper §4 applies unchanged).  Each decode step is a barrier control
point: ``decode_step`` returns between tokens, so a driver can interleave
several gangs on one fabric and move this one mid-generation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.fabric import GangHandle
from repro.models import model as model_mod


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    steps: int = 0


class ServeLoop:
    """Fixed-batch serving of equal-length prompts (greedy decoding)."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 256,
                 window: int = 0, handle: Optional[GangHandle] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window = window
        self.handle: Optional[GangHandle] = None
        self._prefill = jax.jit(model_mod.make_prefill_step(cfg,
                                                            window=window))
        self._serve = jax.jit(model_mod.make_serve_step(cfg, window=window))
        self.stats = ServeStats()
        # in-flight decode batch (None when idle)
        self._reqs: Optional[List[Request]] = None
        self._states = None
        self._cur = None
        self._plen = 0
        self._t = 0
        self._max_new = 0
        if handle is not None:
            self.attach(handle)

    # ---- gang placement ----------------------------------------------------
    def attach(self, handle: GangHandle,
               state: Optional[Dict[str, Any]] = None) -> None:
        """Run this replica as a gang on a shared fabric: place params
        (and any in-flight decode state) replicated on the gang mesh.
        Re-attach after a migrate/rescale/resume to follow the new
        placement; ``state`` adopts a restored/resharded serving state in
        the same move."""
        self.handle = handle
        if state is not None:
            self.load_serve_state(state)
        else:
            self._place()

    def _replicated(self, tree):
        if self.handle is None or self.handle.mesh is None:
            return tree
        s = NamedSharding(self.handle.mesh, P())
        return jax.tree.map(lambda x: jax.device_put(x, s), tree)

    def _place(self) -> None:
        self.params = self._replicated(self.params)
        if self._reqs is not None:
            self._states = self._replicated(self._states)
            self._cur = self._replicated(self._cur)

    # ---- serving state = the snapshot (migration/preemption unit) ----------
    def serve_state(self) -> Dict[str, Any]:
        """Pytree capturing the replica mid-generation: params + decode
        caches + cursor, plus the host-side request bookkeeping — so the
        snapshot restores into a *fresh* ServeLoop, not just this one."""
        st: Dict[str, Any] = {"params": self.params}
        if self._reqs is not None:
            st["states"] = self._states
            st["cur"] = self._cur
            st["decode"] = {
                "meta": np.asarray([self._plen, self._t, self._max_new],
                                   np.int64),
                "rids": np.asarray([r.rid for r in self._reqs], np.int64),
                "prompts": [np.asarray(r.prompt, np.int32)
                            for r in self._reqs],
                "max_new": np.asarray([r.max_new_tokens
                                       for r in self._reqs], np.int64),
                "outs": [np.asarray(r.out, np.int64) for r in self._reqs],
            }
        return st

    def load_serve_state(self, st: Dict[str, Any]) -> None:
        """Adopt a (restored or resharded) serving state; generation
        continues exactly where the snapshot was taken.  When this loop
        has no in-flight batch (fresh process / driver), the snapshot's
        request bookkeeping rebuilds it; an already-live batch keeps its
        own Request objects (same generation, callers hold references)."""
        self.params = st["params"]
        if "states" in st:
            self._states = st["states"]
            self._cur = st["cur"]
            dec = st.get("decode")
            if dec is not None:
                plen, t, max_new = (int(x) for x in np.asarray(dec["meta"]))
                self._plen, self._t, self._max_new = plen, t, max_new
                if self._reqs is None:
                    self._reqs = [
                        Request(rid=int(rid),
                                prompt=np.asarray(p, np.int32),
                                max_new_tokens=int(mn),
                                out=[int(x) for x in np.asarray(o)])
                        for rid, p, mn, o in zip(dec["rids"],
                                                 dec["prompts"],
                                                 dec["max_new"],
                                                 dec["outs"])]
        self._place()

    def _pad_states(self, states, prompt_len: int):
        """Grow prefill KV caches to max_len-sized decode buffers."""
        size = min(self.max_len, self.window) if self.window else self.max_len

        def pad(x):
            if x.ndim == 5 and x.shape[2] == prompt_len:  # (P,B,S,kv,hd)
                if size <= prompt_len:
                    return x[:, :, -size:]
                pad_spec = [(0, 0)] * x.ndim
                pad_spec[2] = (0, size - prompt_len)
                return jnp.pad(x, pad_spec)
            return x
        return [jax.tree.map(pad, s) for s in states]

    # ---- decode lifecycle --------------------------------------------------
    def start(self, requests: Sequence[Request],
              extras: Optional[Dict[str, Any]] = None) -> None:
        """Admit + prefill a batch; decoding proceeds via decode_step."""
        reqs = list(requests)
        b = len(reqs)
        plen = len(reqs[0].prompt)
        assert all(len(r.prompt) == plen for r in reqs), "equal-length batch"
        tokens = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = self._replicated({"tokens": tokens, **(extras or {})})
        last_logits, states = self._prefill(self.params, batch)
        self.stats.prefill_tokens += b * plen
        self._reqs = reqs
        self._states = self._pad_states(states, plen)
        self._cur = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        self._plen = plen
        self._t = 0
        self._max_new = max(r.max_new_tokens for r in reqs)
        self._place()

    @property
    def done(self) -> bool:
        return self._reqs is None or self._t >= self._max_new

    def decode_step(self) -> bool:
        """One token for the whole batch; returns True while decoding.
        The step boundary is this gang's control point — between calls
        the replica may be migrated or snapshotted."""
        if self.done:
            return False
        reqs, t, b = self._reqs, self._t, len(self._reqs)
        for i, r in enumerate(reqs):
            if t < r.max_new_tokens:
                r.out.append(int(self._cur[i]))
        pos = jnp.full((b, 1), self._plen + t, jnp.int32)
        logits, self._states = self._serve(self.params, self._states,
                                           self._cur[:, None], pos)
        self._cur = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        self.stats.decoded_tokens += b
        self.stats.steps += 1
        self._t += 1
        if self.done:
            # drop the drained batch AND its device state — idle decode
            # buffers would otherwise pin device memory on a shared fabric
            self._reqs = None
            self._states = None
            self._cur = None
            return False
        return True

    def run(self, requests: Sequence[Request],
            extras: Optional[Dict[str, Any]] = None) -> List[Request]:
        reqs = list(requests)
        self.start(reqs, extras=extras)
        while self.decode_step():
            pass
        return reqs
