"""Chunk-wise threshold-select codec kernel (Pallas) for compressed
collectives (DESIGN.md §11).

The old codec ran ``jax.lax.top_k`` over the whole shard — a global
O(n log n) sort that cost more than the slow link saved (ROADMAP item
5).  The replacement is a *chunk-max* selection: the shard is reshaped
into ``(k, m)`` chunks and each chunk contributes its single
largest-magnitude element.  Selection becomes a row-wise
max/first-argmax — one O(n) streaming pass with no data-dependent
control flow, mapping onto a VPU-friendly reduce over the lane
dimension.  The per-chunk max is the selection *threshold* within that
chunk, hence threshold-select; k chunks yield exactly k (value, index)
pairs, a fixed-size message like top-k's.

One fused pass emits, per chunk row:
    col[r]   = first argmax of |x[r, :]|          (int32 column)
    vals[r]  = x[r, col[r]]
    resid[r] = x[r, :] with the selected lane zeroed
so the error-feedback residual costs no second pass.

Grid: (k / block_rows,); blocks are (block_rows, m) tiles in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import tpu_compiler_params

BLOCK_ROWS = 8  # chunk rows per block; the chunk width is the lane dim


def _select_kernel(x_ref, vals_ref, col_ref, resid_ref):
    x = x_ref[...]                                   # (rows, m)
    rows, m = x.shape
    mag = jnp.abs(x)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, m), 1)
    rowmax = jnp.max(mag, axis=1, keepdims=True)
    # first-occurrence argmax: min lane index among the maxima
    col = jnp.min(jnp.where(mag == rowmax, lane, m), axis=1,
                  keepdims=True)                     # (rows, 1)
    picked = lane == col
    vals_ref[...] = jnp.sum(jnp.where(picked, x, 0), axis=1,
                            keepdims=True).astype(x.dtype)
    col_ref[...] = col.astype(jnp.int32)
    resid_ref[...] = jnp.where(picked, jnp.zeros_like(x), x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def chunk_select(x, *, block_rows: int = BLOCK_ROWS,
                 interpret: bool = False):
    """x: (k, m) f32 -> (vals (k, 1), col (k, 1) int32, resid (k, m))."""
    k, m = x.shape
    block_rows = min(block_rows, k)
    assert k % block_rows == 0
    grid = (k // block_rows,)
    return pl.pallas_call(
        _select_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, m), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, m), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((k, 1), x.dtype),
                   jax.ShapeDtypeStruct((k, 1), jnp.int32),
                   jax.ShapeDtypeStruct((k, m), x.dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
