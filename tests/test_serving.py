"""Continuous-batching serve path + SLO autoscaling tests.

Pure pieces run in-process (arrival generators, admission queue,
autoscaler control law, virtual fleet sim, grow-with-drain).  Engine
parity and the mixed-slot snapshot/migrate paths run real jax models;
the fabric-level migrate-mid-generation test runs in a subprocess with
an 8-device CPU fabric (same pattern as test_fabric).

MoE parity caveat: capacity-factor routing couples batch lanes, so the
fixed-vs-continuous comparison pins a no-drop capacity factor — the
same mitigation ``test_decode_consistency`` uses.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Arrival generators (pure)
# ---------------------------------------------------------------------------
def test_arrival_regimes_deterministic_and_mean_preserving():
    from repro.core.simulator import ARRIVAL_REGIMES, arrival_times

    n, rate = 400, 2.0
    for regime in ARRIVAL_REGIMES:
        a = arrival_times(n, rate, seed=3, regime=regime)
        b = arrival_times(n, rate, seed=3, regime=regime)
        np.testing.assert_array_equal(a, b)       # deterministic
        assert a.shape == (n,) and np.all(np.diff(a) > 0)
        mean_rate = n / a[-1]
        assert 0.5 * rate < mean_rate < 2.0 * rate, (regime, mean_rate)
    # the poisson path keeps the exact legacy draw sequence
    rng = np.random.default_rng([3, 1])
    legacy = np.cumsum(rng.exponential(1.0 / rate, size=n))
    np.testing.assert_allclose(
        arrival_times(n, rate, seed=3), legacy, rtol=1e-12)
    with pytest.raises(ValueError):
        arrival_times(4, 1.0, 0, regime="nope")


def test_burst_regime_has_flash_crowds():
    from repro.core.simulator import arrival_times

    n, rate = 400, 2.0
    pois = arrival_times(n, rate, seed=5)
    burst = arrival_times(n, rate, seed=5, regime="burst")

    def peak_windowed_rate(t, w=2.0):
        return max(np.sum((t >= s) & (t < s + w)) / w
                   for s in np.arange(0.0, t[-1], w / 2))
    # bursts concentrate arrivals: the busiest window runs far hotter
    # than anything homogeneous traffic produces at the same mean rate
    assert peak_windowed_rate(burst) >= 1.5 * peak_windowed_rate(pois)


def test_request_stream_payloads_independent_of_regime():
    from repro.runtime.admission import request_stream

    a = request_stream(32, 1.0, seed=9, regime="poisson",
                       priority_classes=[(0, 0.5), (5, 0.5)])
    b = request_stream(32, 1.0, seed=9, regime="burst",
                       priority_classes=[(0, 0.5), (5, 0.5)])
    assert {r.priority for r in a} == {0, 5}
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.max_new_tokens == rb.max_new_tokens
        assert ra.priority == rb.priority
        assert ra.arrival != rb.arrival           # regime changes timing


def test_admission_queue_priority_then_fifo():
    from repro.runtime.admission import AdmissionQueue
    from repro.runtime.serve_loop import Request

    q = AdmissionQueue()
    mk = lambda rid, pri, t: Request(rid=rid, prompt=np.zeros(1, np.int32),
                                     priority=pri, arrival=t)
    q.push(mk(0, 5, 0.0))
    q.push(mk(1, 0, 2.0))
    q.push(mk(2, 0, 1.0))
    q.push(mk(3, 5, 0.5))
    assert [q.pop().rid for _ in range(4)] == [2, 1, 0, 3]
    assert q.peek() is None and len(q) == 0


# ---------------------------------------------------------------------------
# Autoscaler control law (pure, real PlacementEngine accounting)
# ---------------------------------------------------------------------------
def test_autoscaler_grow_clone_need_and_shrink():
    from repro.core.elastic import ElasticPolicy
    from repro.core.placement import PlacementEngine
    from repro.runtime.admission import ServeAutoscaler, ServeSLO

    eng = PlacementEngine(2, 8)
    pol = ElasticPolicy(min_world=1, max_world=16)
    slo = ServeSLO(target_p99_s=0.5)
    sc = ServeAutoscaler(pol, eng, slo=slo, base_world=2, cooldown_s=2.0)
    g = eng.allocate("g0", 2)
    assert g is not None

    # p99 breach with a free pool -> grow the gang 2x
    acts = sc.decide(0.0, queue_depth=0, p99=1.0, gang_worlds={"g0": 2})
    assert [(a.kind, a.world) for a in acts] == [("grow", 4)]
    # cooldown: the very next tick stays quiet even under breach
    assert sc.decide(0.5, 99, 9.9, {"g0": 2}) == []
    # queue pressure alone (no latency signal yet) also triggers
    acts = sc.decide(3.0, queue_depth=50, p99=None, gang_worlds={"g0": 2})
    assert acts and acts[0].kind == "grow"

    # grow impossible at max_world -> clone a new base gang
    eng2 = PlacementEngine(2, 8)
    pol2 = ElasticPolicy(min_world=1, max_world=4)
    sc2 = ServeAutoscaler(pol2, eng2, slo=slo, base_world=2)
    eng2.allocate("g0", 4)
    acts = sc2.decide(0.0, 0, 1.0, {"g0": 4})
    assert [(a.kind, a.world) for a in acts] == [("clone", 2)]

    # pool exhausted entirely -> "need" (the drain-a-trainer cue)
    eng3 = PlacementEngine(1, 4)
    pol3 = ElasticPolicy(min_world=1, max_world=16)
    sc3 = ServeAutoscaler(pol3, eng3, slo=slo, base_world=2)
    eng3.allocate("g0", 2)
    eng3.allocate("train", 2)
    acts = sc3.decide(0.0, 0, 1.0, {"g0": 2})
    assert [(a.kind, a.world) for a in acts] == [("need", 4)]

    # comfortable -> shrink back toward min world
    acts = sc.decide(10.0, queue_depth=0, p99=0.01, gang_worlds={"g0": 4})
    assert [(a.kind, a.world) for a in acts] == [("shrink", 2)]


def test_elastic_decide_scaled_directional():
    from repro.core.elastic import ElasticPolicy
    from repro.core.placement import PlacementEngine

    eng = PlacementEngine(2, 8)
    pol = ElasticPolicy(min_world=1, max_world=16)
    eng.allocate("g", 2)
    assert pol.decide_scaled(2, eng, 2.0) == 4
    assert pol.decide_scaled(2, eng, 0.5) == 1
    assert pol.decide_scaled(2, eng, 1.0) is None
    # budget-capped: 12 of 16 chips busy -> 2x of 8 clamps to free budget
    eng2 = PlacementEngine(2, 8)
    eng2.allocate("other", 10)
    eng2.allocate("g", 4)
    assert pol.decide_scaled(4, eng2, 2.0) is None   # 4->8 needs 4 idle, 2 left
    assert pol.decide_scaled(2, eng2, 4.0) == 4      # p2 floor of budget


def test_serve_slo_penalty_is_opt_in_and_gates_scoring():
    from repro.core.placement import CostModel

    base = CostModel()
    slo = CostModel(serve_slo_s=0.04, serve_token_s=0.05)
    pl = [(0, 2), (1, 2)]
    # default model: penalty off, scores identical to the shipped one
    assert base.serve_slo_penalty(pl, "omp", None) == 1.0
    assert base.score(pl, kind="omp") == CostModel().score(pl, kind="omp")
    # opt-in: the penalty multiplies score but never slowdown
    pen = slo.serve_slo_penalty(pl, "omp", None)
    assert pen > 1.0
    assert slo.slowdown(pl, "omp") == base.slowdown(pl, "omp")
    assert slo.score(pl, kind="omp") > base.score(pl, kind="omp")
    # non-serve kinds are never penalised
    assert slo.serve_slo_penalty(pl, "mpi-compute", None) == 1.0
    # slow hosts pace the token latency
    fast = slo.token_latency([(0, 4)], "omp", [1.0, 1.0])
    slow = slo.token_latency([(0, 4)], "omp", [0.5, 1.0])
    assert slow == pytest.approx(2.0 * fast)


def test_score_batch_matches_score_with_serve_penalty():
    from repro.core.placement import CostModel

    cm = CostModel(serve_slo_s=0.04, serve_token_s=0.05)
    placements = [[(0, 2)], [(0, 1), (1, 3)], [(2, 4)], [(0, 2), (3, 2)]]
    speeds = np.array([1.0, 0.5, 1.0, 0.7])
    batch = cm.score_batch(placements, kind="omp", speeds=speeds)
    single = [cm.score(p, kind="omp", speeds=speeds) for p in placements]
    np.testing.assert_allclose(batch, single, rtol=1e-9)


# ---------------------------------------------------------------------------
# Virtual fleet: autoscaling + drain-not-die (pure)
# ---------------------------------------------------------------------------
def test_fleet_sim_burst_holds_slo_and_breathes():
    from repro.runtime.admission import ServeSLO, request_stream
    from repro.runtime.serve_fleet import ServeFleetSim

    reqs = request_stream(120, 6.0, seed=7, regime="burst", vocab=64)
    slo = ServeSLO(target_p99_s=0.6)
    sim = ServeFleetSim(hosts=4, chips_per_host=8, slo=slo, base_world=2,
                        max_world=16, cooldown_s=0.5,
                        control_interval_s=0.5)
    rep = sim.run(reqs)
    assert rep.finished == 120
    assert rep.token_lat_p99 <= slo.target_p99_s
    assert rep.grew > 0 and rep.shrank > 0        # both directions fire
    assert rep.peak_world > rep.min_world
    # determinism: the same stream replays to the same report
    sim2 = ServeFleetSim(hosts=4, chips_per_host=8, slo=slo, base_world=2,
                         max_world=16, cooldown_s=0.5,
                         control_interval_s=0.5)
    rep2 = sim2.run(request_stream(120, 6.0, seed=7, regime="burst",
                                   vocab=64))
    assert rep2.timeline == rep.timeline
    assert rep2.token_lat_p99 == rep.token_lat_p99


def test_fleet_sim_drain_beats_preempt_at_equal_slo():
    from repro.runtime.admission import ServeSLO, request_stream
    from repro.runtime.serve_fleet import (ServeFleetSim,
                                           VirtualTrainTenant)

    out = {}
    for mode in ("drain", "preempt"):
        sim = ServeFleetSim(hosts=4, chips_per_host=8,
                            slo=ServeSLO(target_p99_s=0.6), base_world=2,
                            max_world=16, cooldown_s=0.5,
                            control_interval_s=0.5)
        train = VirtualTrainTenant("t0", sim.engine, world=28,
                                   min_world=4)
        out[mode] = sim.run(request_stream(150, 6.0, seed=7,
                                           regime="burst", vocab=64),
                            train=train, train_mode=mode)
    drain, pre = out["drain"], out["preempt"]
    # identical serve outcomes: the burst is absorbed either way...
    assert drain.token_lat_p99 == pre.token_lat_p99 <= 0.6
    assert drain.train_min_world == pre.train_min_world < 28
    # ...but only the kill path burns checkpoint-rollback work
    assert drain.train_lost_work == 0.0
    assert pre.train_lost_work > 0.0
    assert drain.train_progress > pre.train_progress
    assert drain.train_backfilled > 0.0           # grew back after burst


def test_fabric_grow_with_drain_reclaims_from_donors():
    print(run_sub("""
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.runtime.gang_workloads import ServeWorkload, TrainWorkload

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

        fab = Fabric(chips_per_host=2)              # 8 chips
        t = fab.allocate("train0", 6, priority=0)
        s = fab.allocate("serve0", 2, priority=5)
        twl = TrainWorkload(cfg, ocfg, dcfg, total_steps=8)
        twl.bind(t); twl.init_state(t); twl.run_step(t)
        swl = ServeWorkload(cfg, new_tokens=3, batch=2, max_len=16)
        swl.bind(s); swl.init_state(s); swl.run_step(s)
        # a serve spike wants 4 chips; the pool has 0 idle -> the
        # training donor drains (graceful shrink, zero lost work)
        state, donors = fab.grow_with_drain(
            s, swl.state, 4, donors=[(t, twl.state, 2)])
        assert s.n == 4 and t.n == 3, (s.n, t.n)
        assert set(donors) == {"train0"}
        twl.state = donors["train0"]; twl.bind(t)
        swl.state = state; swl.bind(s)
        # both gangs keep running on their new placements
        twl.run_step(t); swl.run_step(s)
        assert len(twl.losses) == 2
        # donors exhausted at their floor -> the grow raises
        try:
            fab.grow_with_drain(s, swl.state, 8,
                                donors=[(t, twl.state, 2)])
            raise AssertionError("grow past the pool must raise")
        except RuntimeError:
            pass
        print("grow-with-drain-ok")
    """))


# ---------------------------------------------------------------------------
# Engine parity + mixed-slot snapshot/resume (real models)
# ---------------------------------------------------------------------------
PARITY_ARCHS = ["llama3.2-1b", "zamba2-2.7b", "granite-moe-1b-a400m"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_continuous_matches_fixed_batch_tokens(arch):
    import jax

    from repro.configs.registry import reduced_config
    from repro.models import transformer as tf
    from repro.runtime.serve_loop import (ContinuousServeLoop, Request,
                                          ServeLoop)

    cfg = reduced_config(arch).with_(n_layers=2, vocab=64)
    if arch == "granite-moe-1b-a400m":
        cfg = cfg.with_(capacity_factor=8.0)      # no-drop: lane-independent
    params = jax.jit(lambda k: tf.init_params(k, cfg))(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)

    def mk():
        return [Request(rid=i, prompt=rng.integers(0, 64, 8,
                                                   dtype=np.int32).copy(),
                        max_new_tokens=5) for i in range(2)]
    rng = np.random.default_rng(1)
    fixed_reqs = mk()
    ref = ServeLoop(cfg, params, max_len=32).run(fixed_reqs)
    rng = np.random.default_rng(1)
    cont_reqs = mk()
    cont = ContinuousServeLoop(cfg, params, slots=2, max_len=32)
    cont.run(cont_reqs)
    for a, b in zip(ref, cont_reqs):
        assert a.out == b.out, (arch, a.out, b.out)
    # satellite fix: decoded_tokens counts real tokens, not batch*steps
    total = sum(len(r.out) for r in cont_reqs)
    assert cont.stats.decoded_tokens == total
    assert cont.stats.finished == len(cont_reqs)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b"])
def test_mixed_slot_snapshot_resume_with_midstream_join(arch):
    import jax

    from repro.configs.registry import reduced_config
    from repro.models import transformer as tf
    from repro.runtime.serve_loop import ContinuousServeLoop, Request

    cfg = reduced_config(arch).with_(n_layers=2, vocab=64)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)

    def mk():
        # ragged prompts across power-of-two buckets
        return [Request(rid=i, prompt=rng.integers(
                    0, 64, [5, 3, 9][i], dtype=np.int32).copy(),
                        max_new_tokens=[6, 3, 4][i]) for i in range(3)]

    def drive(loop, reqs, snapshot_at=None):
        loop.admit(reqs[0]); loop.admit(reqs[1])
        snap = None
        for step in range(4):
            loop.decode_step()
            if step == 2:                 # r1 (max_new=3) just freed
                assert loop.admit(reqs[2]) is not None
            if snapshot_at == step:
                snap = loop.serve_state()
        return snap

    rng = np.random.default_rng(2)
    ref = mk()
    ref_loop = ContinuousServeLoop(cfg, params, slots=2, max_len=32)
    drive(ref_loop, ref)
    while not ref_loop.done:
        ref_loop.decode_step()

    rng = np.random.default_rng(2)
    mine = mk()
    loop1 = ContinuousServeLoop(cfg, params, slots=2, max_len=32)
    # snapshot at step 3: r1 finished (slot freed), r2 spliced into the
    # freed lane mid-generation, r0 still decoding -> mixed occupancy
    snap = drive(loop1, mine, snapshot_at=3)
    assert loop1.done_rids == [1] and set(loop1.occupied_rids()) == {0, 2}

    # restore into a FRESH loop (new driver process semantics)
    loop2 = ContinuousServeLoop(cfg, params, slots=2, max_len=32)
    loop2.load_serve_state(snap)
    loop2.adopt_requests(mine)
    while not loop2.done:
        loop2.decode_step()
    for a, b in zip(ref, mine):
        assert a.out == b.out, (arch, a.out, b.out)
    assert sorted(loop2.done_rids) == [0, 1, 2]


def test_adopt_requests_rolls_outputs_back_to_snapshot():
    import jax

    from repro.configs.registry import reduced_config
    from repro.models import transformer as tf
    from repro.runtime.serve_loop import ContinuousServeLoop, Request

    cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=64)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(
        jax.random.PRNGKey(0))
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=6)
    loop = ContinuousServeLoop(cfg, params, slots=2, max_len=16)
    loop.admit(req)
    loop.decode_step(); loop.decode_step()
    snap = loop.serve_state()
    loop.decode_step(); loop.decode_step()       # post-snapshot progress
    assert len(req.out) == 4
    fresh = ContinuousServeLoop(cfg, params, slots=2, max_len=16)
    fresh.load_serve_state(snap)
    fresh.adopt_requests([req])
    assert len(req.out) == 2                     # rolled back, same object
    while not fresh.done:
        fresh.decode_step()
    assert len(req.out) == 6


def test_serve_workload_migrates_mid_generation_with_join_after():
    print(run_sub("""
        import numpy as np
        from repro.configs.registry import reduced_config
        from repro.core.fabric import Fabric
        from repro.runtime.gang_workloads import ServeWorkload
        from repro.runtime.serve_loop import Request

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        rng = np.random.default_rng(4)
        def mk():
            # ragged prompts; r2/r3 arrive later than the slot count, so
            # the batch always has mixed occupied/free slots in flight
            return [Request(rid=i,
                            prompt=rng.integers(0, 128, [7, 4, 6, 3][i],
                                                dtype=np.int32).copy(),
                            max_new_tokens=[6, 3, 5, 4][i],
                            arrival=float([0, 0, 2, 6][i]))
                    for i in range(4)]

        # reference: uninterrupted run on one placement
        fab = Fabric(chips_per_host=2)
        rng = np.random.default_rng(4)
        h = fab.allocate("ref", 2)
        ref_wl = ServeWorkload(cfg, requests=mk(), slots=2, max_len=32)
        ref_wl.bind(h); ref_wl.init_state(h)
        while not ref_wl.done:
            ref_wl.run_step(h)
        ref = [list(r.out) for r in ref_wl.requests]
        h.release()

        # interrupted: 3 steps (r2 joined mid-generation at step 2,
        # slots mixed occupied/free), then preempt + resume on a
        # DIFFERENT placement; r3 joins only after the move
        rng = np.random.default_rng(4)
        a = fab.allocate("serve", 2, priority=0)
        wl = ServeWorkload(cfg, requests=mk(), slots=2, max_len=32)
        wl.bind(a); wl.init_state(a)
        for _ in range(3):
            wl.run_step(a)
        assert wl.loop.active > 0 and not wl.done
        a.preempt(wl.state, wl.steps_done)
        blocker = fab.allocate("blocker", 4, priority=5)  # old chips busy
        state, step = a.resume()
        assert step == 3 and a.n == 2
        wl.state = state
        wl.bind(a)                  # reconcile + re-place mid-generation
        while not wl.done:
            wl.run_step(a)
        live = [list(r.out) for r in wl.requests]
        assert live == ref, (live, ref)
        blocker.release(); a.release()
        print("serve-migrate-ok", live)
    """))


def test_run_trace_serve_actions_match_prediction_all_regimes():
    print(run_sub("""
        from repro.configs.registry import reduced_config
        from repro.core import simulator as sim
        from repro.core.fabric import Fabric
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        for regime in sim.ARRIVAL_REGIMES:
            jobs = sim.mixed_trace(5, seed=2, chips_per_host=2,
                                   arrival_rate=0.2,
                                   priority_classes=[(0, 0.7), (5, 0.3)],
                                   arrival_regime=regime)
            for j in jobs:
                j.parallelism = min(j.parallelism, 4)
            fab = Fabric(chips_per_host=2)
            predicted = fab.predict_trace(jobs)
            ex = fab.run_trace(jobs, workload_factory(cfg, ocfg, dcfg,
                                                      train_steps=2,
                                                      serve_tokens=3))
            assert ex.result.actions == predicted.actions, regime
            assert ex.result.finish_order == predicted.finish_order
            serve_recs = [r for r in ex.live.values()
                          if r.get("workload") == "ServeWorkload"]
            assert serve_recs, "trace exercised no serve gangs"
            for rec in serve_recs:
                outs = rec["final_metrics"]["outputs"]
                assert all(len(o) > 0 for o in outs)
            print(regime, "actions-match-ok", len(ex.result.actions))
    """))
