"""Chunk-diff + merge-op kernel (Pallas): the paper's byte-wise diff engine
(§4.1, Table 3) as a TPU streaming kernel.

Faabric traps dirty pages with mprotect and compares bytes on the host; a
TPU has no page faults inside a program, so dirty tracking is an explicit
compare-against-snapshot — a pure bandwidth-bound streaming op, exactly
what a Pallas kernel with large VMEM blocks does at HBM speed.

One fused pass computes, per chunk (the page analogue):
    dirty[c] = any(b0[c] != b1[c])
    a1[c]    = merge_op(a0[c], b0[c], b1[c])        (Table 3)
so the diff *detection* and the *merge-apply* read the operands once.

Grid: (n_chunks / chunk_rows,); blocks are (chunk_rows, CHUNK) tiles in
VMEM.  The merge op is a compile-time specialisation (one kernel per op,
like the paper's per-diff merge-op tag).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import tpu_compiler_params

MERGE_OPS = ("sum", "subtract", "multiply", "divide", "overwrite")
BLOCK_ROWS = 8  # chunks per block (rows); chunk width is the lane dim


def compute_dtype(dtype, op: str):
    """Dtype the merge maths run in, derived from the *leaf* dtype:
    integer leaves stay integer for the exact ops (sum/subtract/
    overwrite — a float round-trip silently corrupts large ints),
    f32/f64 keep their own precision, and only low-precision floats
    (bf16/f16) promote to f32.  Shared with ``diffsync.dense_merge``'s
    rule so kernel and host dense paths agree bit-for-bit."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        if op in ("sum", "subtract", "overwrite"):
            return dtype
        return jnp.float32
    if dtype in (jnp.float32, jnp.float64):
        return dtype
    return jnp.float32


def _merge(a0, b0, b1, op: str):
    if op == "sum":
        return a0 + (b1 - b0)
    if op == "subtract":
        return a0 - (b0 - b1)
    if op == "multiply":
        return a0 * jnp.where(b0 == 0, 1.0, b1 / b0)
    if op == "divide":
        return a0 / jnp.where(b1 == 0, 1.0,
                              jnp.where(b0 == 0, 1.0, b0 / b1))
    if op == "overwrite":
        return b1
    raise ValueError(op)


def _dm_kernel(a0_ref, b0_ref, b1_ref, a1_ref, dirty_ref, *, op: str):
    cdt = compute_dtype(a0_ref.dtype, op)
    a0 = a0_ref[...].astype(cdt)
    b0 = b0_ref[...].astype(cdt)
    b1 = b1_ref[...].astype(cdt)
    # dirty detection compares the raw stored values (exact for every
    # dtype), not the possibly-promoted compute values
    dirty_rows = jnp.any(b0_ref[...] != b1_ref[...],
                         axis=1, keepdims=True)               # (rows, 1)
    merged = _merge(a0, b0, b1, op)
    # clean chunks keep the main value untouched (sparse diff semantics)
    a1_ref[...] = jnp.where(dirty_rows, merged, a0).astype(a1_ref.dtype)
    dirty_ref[...] = dirty_rows


@functools.partial(jax.jit,
                   static_argnames=("op", "block_rows", "interpret"))
def diff_merge(a0, b0, b1, *, op: str = "sum",
               block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """a0/b0/b1: (n_chunks, chunk) f32/bf16 -> (a1, dirty (n_chunks, 1))."""
    n, c = a0.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0
    grid = (n // block_rows,)
    kernel = functools.partial(_dm_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, c), a0.dtype),
                   jax.ShapeDtypeStruct((n, 1), jnp.bool_)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a0, b0, b1)
