"""Gang workloads for trace-driven live execution (``Fabric.run_trace``).

The simulator's discrete-event loop decides *when and where* each trace
job runs (placement, priorities, preemption); these workloads are the
*what* — real jax computations stepped one control point at a time so
concurrent gangs interleave on one fabric:

* ``TrainWorkload`` — a data-parallel training gang (the step machinery
  of ``runtime.train_loop`` without its driver loop).  State = the train
  state pytree; bit-exact across migrate/preempt because the data
  pipeline is (seed, step)-keyed.
* ``ServeWorkload`` — a serving replica (``runtime.serve_loop``): prefill
  at first step, then one decoded token per step.  State = the serving
  state (params + KV caches + cursor), so the same snapshot machinery
  moves it.

``workload_factory`` maps trace jobs to workloads by ``Job.workload``
("train" | "serve", falling back on job kind: omp → serve, mpi → train)
— the default factory for tests, benchmarks and examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import collectives as coll
from repro.core.fabric import GangHandle, GangWorkload
from repro.core.simulator import Job
from repro.data import pipeline as dp
from repro.models import model as model_mod
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime.serve_loop import Request, ServeLoop
from repro.runtime.train_loop import (extra_batch_specs, make_dp_train_step,
                                      resolve_sync_mode)


class TrainWorkload(GangWorkload):
    """One training gang stepped at control-point granularity."""

    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 data_cfg: dp.DataConfig, total_steps: int = 4,
                 sync_mode: str = "hierarchical",
                 compress_frac: float = 0.05, seed: int = 0):
        self.cfg, self.opt_cfg, self.data_cfg = cfg, opt_cfg, data_cfg
        self.total_steps = total_steps
        self.sync_mode = sync_mode
        self.compress_frac = compress_frac
        self.seed = seed
        self.state = None
        self.resid = None
        self.steps_done = 0
        self.losses: list = []
        self._step_fn = None
        self._extras = extra_batch_specs(cfg, data_cfg.global_batch)

    def bind(self, handle: GangHandle) -> None:
        # the global batch must divide over the gang; trace jobs come in
        # arbitrary world sizes, so snap the batch to the nearest
        # divisible size (per-device share of the configured batch, at
        # least one row per device).  The world size is stable across
        # preempt/resume, so each job's data stream stays deterministic.
        world = len(handle.devices)
        per = max(1, self.data_cfg.global_batch // world)
        if self.data_cfg.global_batch != per * world:
            self.data_cfg = dataclasses.replace(self.data_cfg,
                                                global_batch=per * world)
            self._extras = extra_batch_specs(self.cfg,
                                             self.data_cfg.global_batch)
        mode = resolve_sync_mode(
            self.sync_mode, handle,
            self.state["params"] if self.state is not None else None)
        self._step_fn = make_dp_train_step(
            self.cfg, self.opt_cfg, handle.mesh, mode,
            self.compress_frac)
        if self.state is not None:
            self.resid = coll.init_residual_buffer(handle.mesh,
                                                   self.state["params"])

    def init_state(self, handle: GangHandle) -> None:
        key = jax.random.PRNGKey(self.seed)
        with jax.default_device(handle.devices[0]):
            state = model_mod.init_train_state(key, self.cfg, self.opt_cfg)
        rep = NamedSharding(handle.mesh, P())
        self.state = jax.tree.map(lambda x: jax.device_put(x, rep), state)
        self.resid = coll.init_residual_buffer(handle.mesh,
                                               self.state["params"])

    def run_step(self, handle: GangHandle) -> Dict[str, Any]:
        batch = dp.make_batch(self.data_cfg, self.steps_done, self._extras)
        axes = tuple(a for a in ("pod", "data")
                     if a in handle.mesh.axis_names)
        s = NamedSharding(handle.mesh, P(axes))
        batch = jax.tree.map(lambda x: jax.device_put(x, s), batch)
        self.state, metrics, self.resid = self._step_fn(self.state, batch,
                                                        self.resid)
        self.steps_done += 1
        loss = float(metrics["loss"])
        self.losses.append(loss)
        return {"loss": loss, "step": self.steps_done,
                "world": len(handle.devices)}


class ServeWorkload(GangWorkload):
    """One serving gang: prefill on the first step, then one token/step."""

    def __init__(self, cfg: ArchConfig,
                 requests: Optional[Sequence[Request]] = None,
                 prompt_len: int = 8, new_tokens: int = 4, batch: int = 2,
                 max_len: int = 32, seed: int = 0):
        self.cfg = cfg
        self.max_len = max_len
        self.seed = seed
        if requests is None:
            rng = np.random.default_rng(seed)
            requests = [Request(rid=i,
                                prompt=rng.integers(0, cfg.vocab, prompt_len,
                                                    dtype=np.int32),
                                max_new_tokens=new_tokens)
                        for i in range(batch)]
        self.requests = list(requests)
        # step 0 = prefill; then one decode step per generated token
        self.total_steps = 1 + max(r.max_new_tokens for r in self.requests)
        self.steps_done = 0
        self.state = None
        self.loop: Optional[ServeLoop] = None

    def bind(self, handle: GangHandle) -> None:
        if self.loop is None:
            params = jax.jit(lambda k: tf.init_params(k, self.cfg))(
                jax.random.PRNGKey(self.seed))
            self.loop = ServeLoop(self.cfg, params, max_len=self.max_len)
        # adopt the new placement (and any restored snapshot) in one move
        self.loop.attach(handle, state=self.state)
        self.state = self.loop.serve_state()

    def init_state(self, handle: GangHandle) -> None:
        self.state = self.loop.serve_state()

    def run_step(self, handle: GangHandle) -> Dict[str, Any]:
        if self.steps_done == 0:
            self.loop.start(self.requests)
        else:
            self.loop.decode_step()
        self.state = self.loop.serve_state()
        self.steps_done += 1
        return {"decoded": self.loop.stats.decoded_tokens,
                "step": self.steps_done,
                "outputs": [list(r.out) for r in self.requests]}


def workload_factory(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                     data_cfg: dp.DataConfig, train_steps: int = 3,
                     serve_tokens: int = 3
                     ) -> Callable[[Job], GangWorkload]:
    """Default ``Job -> GangWorkload`` mapping for ``Fabric.run_trace``:
    ``Job.workload`` wins; otherwise omp jobs serve, mpi jobs train."""

    def make(job: Job) -> GangWorkload:
        kind = job.workload or ("serve" if job.kind == "omp" else "train")
        if kind == "serve":
            return ServeWorkload(cfg, new_tokens=serve_tokens,
                                 prompt_len=data_cfg.seq_len,
                                 batch=min(2, data_cfg.global_batch),
                                 max_len=data_cfg.seq_len + serve_tokens + 1,
                                 seed=job.priority + 1)
        return TrainWorkload(cfg, opt_cfg, data_cfg,
                             total_steps=train_steps)
    return make
