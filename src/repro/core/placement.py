"""Policy-driven gang placement on a shared cluster (paper §3.4, §6.2).

This is the single code path behind every placement decision in the repo:
the discrete-event simulator (paper Fig 10/11/14), the live runtime's
sub-mesh carving / rescale / migrate control-point actions, and the
scheduler facade in ``core.scheduler``.  The split is:

* ``CostModel`` — the one job-time model every layer consumes::

      T = (W / Σ_h n_h·s_h) · (1 + beta_kind · chi)

  with per-host speed factors ``s_h`` (mixed host generations) and a
  per-job-kind cross-host penalty ``beta`` calibrated from the paper's
  Fig 14 microbenchmarks (compute-bound 0.4, network-bound 13.0).
  Policies rank candidate placements by it, the simulator's job rates
  integrate it, and the engine's migration/preemption plans cost moves
  with it — so simulated and live decisions stay placement-for-placement
  identical.

* ``PlacementPolicy`` — a pure function from a free-chip snapshot
  (``ClusterView``) to a gang placement ``[(host, n_chips)]``.  Shipped
  policies:

  - ``binpack``      Faabric's default: greedy most-free-first so the gang
                     spans as few hosts as possible (the seed behaviour);
                     on heterogeneous fleets "most free" is measured in
                     effective throughput ``free_h·s_h``.
  - ``spread``       round-robin chips over hosts (load balancing),
                     throughput-weighted on heterogeneous fleets.
  - ``fixed-slice``  the §6.2 k-containers-per-VM baselines: whole slices
                     of ``slice_size`` chips, never shared between jobs.
  - ``locality``     scores candidate placements by the full predicted
                     ``T`` of the cost model and picks the minimiser,
                     tie-breaking on chips stranded on touched hosts
                     (best-fit) so large contiguous blocks survive for
                     later gangs.  On homogeneous fleets ``Σ n_h·s_h``
                     is constant across candidates, so the score
                     degenerates to the slowdown ``(1 + beta·chi)``
                     exactly as before the CostModel refactor.

* ``PlacementEngine`` — owns the mutable cluster state: free-chip
  accounting, gang allocation, preemption-safe reservations (hold chips
  before binding a job so multi-step decisions are atomic), migration
  planning at barrier points, and adoption of externally-created
  placements (``bind``, used by the live runtime).  Hosts default to
  ``chips_per_host`` chips each; ``capacities`` overrides per-host chip
  counts (a ragged last host on the CPU fabric) and ``speeds`` carries
  per-host speed factors (mixed host generations).

* ``PreemptPolicy`` — victim selection when a high-priority arrival
  cannot be placed: evict the cheapest set of strictly-lower-priority
  gangs (checkpoint + requeue is the *caller's* job — the engine only
  plans).  Used by the simulator's priority traces and by
  ``core.fabric.Fabric`` for live preemption.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, List, Mapping, Optional, Sequence, Tuple, Union)

import numpy as np

Placement = List[Tuple[int, int]]          # [(host, n_chips)] sorted


def placement_cross_host_fraction(placement: Sequence[Tuple[int, int]]
                                  ) -> float:
    """chi = P[two random ranks sit on different hosts] — the collective
    slow-path fraction used by the simulator's time model."""
    n = sum(c for _, c in placement)
    if n <= 1:
        return 0.0
    return 1.0 - sum((c / n) ** 2 for _, c in placement)


def derive_capacities(n_chips: int, chips_per_host: int) -> List[int]:
    """Per-host chip capacities for a pool of ``n_chips`` devices: hosts
    are consecutive runs of ``chips_per_host`` chips, and the last host
    carries the ragged remainder.  The one place the host map is derived
    — ``Fabric`` and ``PlacementEngine.for_chips`` both use it."""
    assert n_chips > 0 and chips_per_host > 0
    hosts = -(-n_chips // chips_per_host)
    return [min(chips_per_host, n_chips - h * chips_per_host)
            for h in range(hosts)]


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
class CostModel:
    """The §6 job-time model ``T = (W / Σ_h n_h·s_h)·(1 + beta_kind·chi)``.

    Calibration (paper Fig 14, §6.4):

    ==============  =====  ==========================================
    job kind        beta   source
    ==============  =====  ==========================================
    mpi-compute      0.4   LAMMPS co-located vs 4+4-fragmented = 1.2x
    mpi-network     13.0   all-to-all fragmented = 7.5x
    omp              1.0   shared-memory intermediate
    ==============  =====  ==========================================

    ``speeds`` (per-host factors ``s_h``, 1.0 = current generation) turn
    the perfect-scaling term ``W/n`` into ``W / Σ_h n_h·s_h``; with no
    speeds (homogeneous fleet) every method reduces bit-exactly to the
    pre-heterogeneity formulas.  ``migrate_progress_cap`` is Fig 14's
    migration-worthwhile heuristic: past this progress fraction the
    snapshot transfer no longer pays for itself; ``migration_cost_s``
    is that snapshot-transfer cost (the simulator's MIGRATION_COST_S),
    which a heterogeneous migration's predicted saving must exceed.
    """

    DEFAULT_BETAS: Dict[str, float] = {"mpi-compute": 0.4,
                                       "mpi-network": 13.0, "omp": 1.0}

    def __init__(self, betas: Optional[Mapping[str, float]] = None,
                 default_beta: float = 0.4,
                 migrate_progress_cap: float = 0.8,
                 migration_cost_s: float = 2.0,
                 preempt_cost_s: float = 2.0):
        self.betas = dict(self.DEFAULT_BETAS if betas is None else betas)
        self.default_beta = default_beta
        self.migrate_progress_cap = migrate_progress_cap
        self.migration_cost_s = migration_cost_s
        self.preempt_cost_s = preempt_cost_s

    def beta(self, kind: Optional[str] = None) -> float:
        """Per-job-kind cross-host penalty; ``default_beta`` when the
        kind is unknown (e.g. a live gang with no trace kind)."""
        if kind is None:
            return self.default_beta
        return self.betas.get(kind, self.default_beta)

    def slowdown(self, placement: Sequence[Tuple[int, int]],
                 kind: Optional[str] = None) -> float:
        """``1 + beta_kind·chi`` for a placement."""
        return 1.0 + self.beta(kind) * placement_cross_host_fraction(
            placement)

    def effective_parallelism(self, placement: Sequence[Tuple[int, int]],
                              speeds: Optional[np.ndarray] = None,
                              active: Optional[int] = None) -> float:
        """``Σ_h n_h·s_h`` — chips weighted by host speed.  ``active``
        caps the working ranks below the allocated chips (an OpenMP job
        in an over-large container); the speed-weighted sum then scales
        by the active fraction."""
        n = sum(c for _, c in placement)
        if active is None:
            active = n
        if speeds is None:
            return float(active)
        eff = float(sum(c * float(speeds[h]) for h, c in placement))
        if active != n and n > 0:
            eff *= active / n
        return eff

    def predicted_time(self, work: float,
                       placement: Sequence[Tuple[int, int]],
                       kind: Optional[str] = None,
                       speeds: Optional[np.ndarray] = None,
                       active: Optional[int] = None) -> float:
        """``T = (W / Σ_h n_h·s_h)·(1 + beta_kind·chi)``."""
        eff = self.effective_parallelism(placement, speeds, active)
        if eff <= 0:
            return float("inf")
        return (work / eff) * self.slowdown(placement, kind)

    def score(self, placement: Sequence[Tuple[int, int]],
              kind: Optional[str] = None,
              speeds: Optional[np.ndarray] = None) -> float:
        """Per-unit-work predicted ``T`` — what policies rank candidate
        placements by (``W`` is constant across candidates, so it drops
        out of the argmin)."""
        return self.predicted_time(1.0, placement, kind, speeds)

    def active_workers(self, parallelism: int, alloc_n: int,
                       shared_memory: bool) -> int:
        """Working ranks on an allocation: OpenMP threads in one
        container cap at the container's chips (§6.2); MPI world sizes
        are fixed at submission."""
        return min(parallelism, alloc_n) if shared_memory else parallelism

    def migration_worthwhile(self, progress: float) -> bool:
        """Fig 14: consolidation pays off except near the finish line."""
        return progress <= self.migrate_progress_cap


@dataclasses.dataclass
class Allocation:
    job_id: str
    placement: Placement
    slice_size: int = 0                     # 0 = granular

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)

    @property
    def hosts(self) -> List[int]:
        return [h for h, _ in self.placement]

    def fragmentation(self) -> int:
        return len(self.placement)

    def cross_host_fraction(self) -> float:
        return placement_cross_host_fraction(self.placement)


class ClusterView:
    """Read-only free-chip snapshot handed to policies (keeps them pure).

    ``capacities`` carries per-host chip counts (ragged last host) and
    ``speeds`` per-host speed factors; ``speeds is None`` means a
    homogeneous fleet and keeps every policy on its exact pre-CostModel
    integer code path."""

    __slots__ = ("free", "chips_per_host", "capacities", "speeds")

    def __init__(self, free: np.ndarray, chips_per_host: int,
                 capacities: Optional[np.ndarray] = None,
                 speeds: Optional[np.ndarray] = None):
        self.free = free
        self.chips_per_host = chips_per_host
        self.capacities = (np.full(len(free), chips_per_host,
                                   dtype=np.int64)
                           if capacities is None
                           else np.asarray(capacities, dtype=np.int64))
        self.speeds = (None if speeds is None
                       else np.asarray(speeds, dtype=np.float64))

    @property
    def hosts(self) -> int:
        return len(self.free)

    @property
    def heterogeneous(self) -> bool:
        """True when per-host speeds actually differ — a uniform-speed
        fleet (even at s != 1) ranks placements exactly like the
        homogeneous case, so policies keep the degenerate path."""
        return self.speeds is not None and bool(
            (self.speeds != self.speeds[0]).any())

    def idle_chips(self) -> int:
        return int(self.free.sum())


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------
class PlacementPolicy:
    """A pure placement function; the engine commits the result.

    ``kind`` is the job kind from the trace (``Job.kind``) so policies
    that consult the cost model use the same per-job beta as the
    simulator's rate integration; None falls back to the model default.
    """

    name = "abstract"
    slice_size = 0                          # granular unless overridden

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        raise NotImplementedError

    def with_model(self, model: CostModel) -> "PlacementPolicy":
        """Bind an engine's cost model.  Policies that score with one
        return a bound copy (never mutating the shared ``POLICIES``
        singletons); stateless policies return self.  The engine calls
        this on every resolved policy so placement and execution always
        score with the *same* model — the one-model invariant."""
        return self


def _host_order(free: np.ndarray,
                speeds: Optional[np.ndarray] = None) -> np.ndarray:
    """Hosts by descending free capacity; on heterogeneous fleets by
    descending effective free throughput ``free_h·s_h``, tie-broken
    toward faster hosts (so equal-throughput fast hosts are preferred
    over one big slow host)."""
    if speeds is None:
        return np.argsort(free)[::-1]
    return np.lexsort((speeds, free * speeds))[::-1]


def _greedy_most_free(free: np.ndarray, n: int,
                      speeds: Optional[np.ndarray] = None
                      ) -> Optional[Placement]:
    """Most-free-first greedy: the gang spans as few hosts as possible
    (as few *effective-throughput-ordered* hosts on mixed fleets)."""
    order = _host_order(free, speeds)
    placement: Placement = []
    remaining = n
    for h in order:
        if free[h] == 0:
            continue
        take = min(int(free[h]), remaining)
        placement.append((int(h), take))
        remaining -= take
        if remaining == 0:
            break
    return sorted(placement) if remaining == 0 else None


class BinpackPolicy(PlacementPolicy):
    """Faabric's default: fewest hosts via greedy most-free-first.  On a
    heterogeneous fleet the greedy order is the cost model's effective
    throughput ``free_h·s_h`` — the homogeneous case degenerates to the
    original free-chip order bit-exactly."""

    name = "binpack"

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        speeds = view.speeds if view.heterogeneous else None
        return _greedy_most_free(view.free, n, speeds)


class SpreadPolicy(PlacementPolicy):
    """Round-robin chips over hosts (load balancing); on mixed fleets
    each chip lands on the host with the most effective free throughput."""

    name = "spread"

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        counts: Dict[int, int] = {}
        free = view.free.copy()
        hetero = view.heterogeneous
        remaining = n
        while remaining > 0:
            candidates = np.nonzero(free > 0)[0]
            if candidates.size == 0:
                return None
            weight = (free[candidates] * view.speeds[candidates]
                      if hetero else free[candidates])
            h = int(candidates[np.argmax(weight)])
            counts[h] = counts.get(h, 0) + 1
            free[h] -= 1
            remaining -= 1
        return sorted(counts.items())


class FixedSlicePolicy(PlacementPolicy):
    """Whole-slice allocation: ceil(n/slice) slices, each on one host.

    Emulates the paper's k-containers-per-VM baselines: a host holds
    ``chips_per_host // slice_size`` slices; slices are never shared
    between jobs, so a request is rounded up to whole slices (the
    fragmentation waste of Fig 10).
    """

    name = "fixed-slice"

    def __init__(self, slice_size: int):
        assert slice_size > 0
        self.slice_size = slice_size

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        slice_size = self.slice_size
        n_slices = -(-n // slice_size)
        placement: Dict[int, int] = {}
        need = n_slices
        free = view.free
        speeds = view.speeds if view.heterogeneous else None
        for h in _host_order(free, speeds):
            while free[h] - placement.get(int(h), 0) >= slice_size \
                    and need > 0:
                placement[int(h)] = placement.get(int(h), 0) + slice_size
                need -= 1
            if need == 0:
                break
        if need:
            return None
        return sorted(placement.items())


class LocalityScoredPolicy(PlacementPolicy):
    """Minimise the predicted job time ``T`` of the §6 cost model.

    Candidate placements are scored by the model's per-unit-work ``T``
    (``CostModel.score``): on a homogeneous fleet ``Σ n_h·s_h`` is the
    same for every candidate, so the score degenerates to the slowdown
    factor ``(1 + beta_kind·chi)`` — bit-identical to the pre-CostModel
    behaviour; on a mixed-generation fleet the score trades cross-host
    fragmentation against host speed *per job kind* (a network-bound
    job with beta 13 co-locates on a slow host, a compute-bound job
    with beta 0.4 splits across the fast generation).  Ties (e.g. every
    single-host placement of a given speed has chi = 0) break on chips
    *stranded* on touched hosts: best-fit keeps large free blocks
    intact, so later gangs fragment less — that second-order effect is
    what lowers the trace-wide mean chi versus binpack's worst-fit
    choice of the most-free host.
    """

    name = "locality"

    def __init__(self, beta: Optional[float] = None,
                 cost_model: Optional[CostModel] = None):
        # an explicitly-configured policy keeps its model through
        # with_model; only the default construction (the POLICIES
        # singleton, by-name resolution) is rebindable to an engine's
        self._custom = cost_model is not None or beta is not None
        # an explicit beta overrides every kind (the pre-CostModel
        # semantics: one scalar scored all placements), so the
        # calibration table is dropped, not merely re-defaulted
        self.cost_model = cost_model or (
            CostModel() if beta is None
            else CostModel(betas={}, default_beta=beta))

    @property
    def beta(self) -> float:
        return self.cost_model.default_beta

    def with_model(self, model: CostModel) -> "LocalityScoredPolicy":
        if self._custom or model is self.cost_model:
            return self
        bound = LocalityScoredPolicy(cost_model=model)
        bound._custom = False           # engine-bound, still rebindable
        return bound

    def _stranded(self, view: ClusterView, placement: Placement) -> int:
        return sum(int(view.free[h]) - c for h, c in placement)

    def _candidates(self, view: ClusterView, n: int) -> List[Placement]:
        free = view.free
        candidates: List[Placement] = []
        fits = np.nonzero(free >= n)[0]
        if fits.size:                        # best-fit single host
            h = int(fits[np.argmin(free[fits])])
            candidates.append([(h, n)])
        greedy = _greedy_most_free(free, n)
        if greedy is not None:
            candidates.append(greedy)
        exact = self._greedy_exact_fill(free, n)
        if exact is not None:
            candidates.append(exact)
        if view.heterogeneous:
            # speed-aware candidates: the fastest single host that fits,
            # and the effective-throughput greedy over the fast hosts
            if fits.size:
                hf = int(fits[np.argmax(view.speeds[fits])])
                candidates.append([(hf, n)])
            fast = _greedy_most_free(free, n, view.speeds)
            if fast is not None:
                candidates.append(fast)
        return candidates

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        candidates = self._candidates(view, n)
        if not candidates:
            return None
        if view.heterogeneous:
            model = self.cost_model
            return min(candidates, key=lambda p: (
                model.score(p, kind, view.speeds),
                self._stranded(view, p)))
        # homogeneous: Σ n_h·s_h is constant, so T reduces to the
        # slowdown — the exact pre-CostModel scoring key
        beta = self.cost_model.beta(kind)
        return min(candidates, key=lambda p: (
            1.0 + beta * placement_cross_host_fraction(p),
            self._stranded(view, p)))

    @staticmethod
    def _greedy_exact_fill(free: np.ndarray, n: int) -> Optional[Placement]:
        """Greedy most-free-first, but finish the remainder on the
        best-fit host (smallest free count that still covers it) — same
        chi as plain greedy when the chunk multiset matches, strictly
        fewer stranded chips otherwise."""
        avail = free.copy()
        placement: Placement = []
        remaining = n
        while remaining > 0:
            fits = np.nonzero(avail >= remaining)[0]
            if fits.size:
                h = int(fits[np.argmin(avail[fits])])
                placement.append((h, remaining))
                remaining = 0
                break
            h = int(np.argmax(avail))
            if avail[h] == 0:
                return None
            take = int(avail[h])
            placement.append((h, take))
            avail[h] = 0
            remaining -= take
        return sorted(placement)


POLICIES: Dict[str, PlacementPolicy] = {
    "binpack": BinpackPolicy(),
    "spread": SpreadPolicy(),
    "locality": LocalityScoredPolicy(),
}


def resolve_policy(policy: Union[str, PlacementPolicy, None],
                   default: Optional[PlacementPolicy] = None
                   ) -> PlacementPolicy:
    if policy is None:
        assert default is not None
        return default
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(f"unknown placement policy: {policy!r}") from None


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PreemptPolicy:
    """Victim selection for a high-priority arrival that cannot be placed.

    Victims are strictly-lower-priority gangs, evicted cheapest-first:
    lowest priority class first, and within a class the largest gang first
    (frees the most chips per eviction).  Greedy selection stops as soon
    as the arrival fits under the engine's placement policy; a prune pass
    then drops any victim the fit does not actually need — preferring to
    spare the *higher*-priority ones — so no gang is evicted needlessly.
    The plan is a pure decision — the caller performs the actual
    checkpoint + release + requeue.

    The fit probe runs the placement policy against the engine's real
    view (capacities, per-host speeds, the arrival's job kind), so a
    preemption planned in simulation lands identically on the live
    fabric.

    ``max_victims`` bounds the blast radius of one arrival (0 = unbounded).
    """

    max_victims: int = 0

    def plan(self, engine: "PlacementEngine", n: int, priority: int,
             priorities: Dict[str, int],
             policy: Union[str, PlacementPolicy, None] = None,
             kind: Optional[str] = None) -> Optional[List[str]]:
        """job_ids to evict so an ``n``-chip gang at ``priority`` places;
        ``None`` if no lower-priority victim set suffices, ``[]`` if it
        already fits without eviction."""
        pol = resolve_policy(policy, engine.default_policy).with_model(
            engine.cost_model)
        scratch = engine.free.copy()

        def fits() -> bool:
            return pol.place(engine.view_with(scratch), n,
                             kind=kind) is not None

        if fits():
            return []
        # cheapest-first victim order: priority asc, gang size desc, id
        victims = sorted(
            (a for a in engine.allocations.values()
             if priorities.get(a.job_id, 0) < priority),
            key=lambda a: (priorities.get(a.job_id, 0), -a.n, a.job_id))
        chosen: List[Allocation] = []
        for a in victims:
            for h, c in a.placement:
                scratch[h] += c
            chosen.append(a)
            if fits():
                break
        else:
            return None
        # prune needless victims, sparing higher-priority gangs first
        for a in sorted(chosen,
                        key=lambda a: (-priorities.get(a.job_id, 0), a.n,
                                       a.job_id)):
            for h, c in a.placement:
                scratch[h] -= c
            if fits():
                chosen.remove(a)        # not needed after all
            else:
                for h, c in a.placement:
                    scratch[h] += c
        if self.max_victims and len(chosen) > self.max_victims:
            return None
        return [a.job_id for a in chosen]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Reservation:
    """Chips held but not yet bound to a job.

    The preemption-safe handshake: ``reserve`` carves the chips out of the
    free pool atomically, so a multi-step decision (e.g. elastic grow:
    decide, snapshot, reshard) cannot lose the chips to a concurrent
    allocation; ``commit`` binds them to a job, ``cancel`` returns them.
    """

    placement: Placement
    slice_size: int = 0
    settled: bool = False                   # committed or cancelled

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)


class PlacementEngine:
    """Free-chip accounting + policy-driven gang allocation for a cluster
    of ``hosts`` hosts with ``chips_per_host`` chips each.  ``capacities``
    overrides per-host chip counts (e.g. a ragged last host); ``speeds``
    carries per-host speed factors for mixed host generations;
    ``cost_model`` is the shared job-time model policies and plans score
    against."""

    def __init__(self, hosts: int, chips_per_host: int,
                 policy: Union[str, PlacementPolicy] = "binpack",
                 capacities: Optional[Sequence[int]] = None,
                 speeds: Optional[Sequence[float]] = None,
                 cost_model: Optional[CostModel] = None):
        self.hosts = hosts
        self.chips_per_host = chips_per_host
        if capacities is None:
            self.capacities = np.full(hosts, chips_per_host, dtype=np.int64)
        else:
            assert len(capacities) == hosts
            self.capacities = np.asarray(capacities, dtype=np.int64)
            assert (self.capacities >= 0).all() \
                and (self.capacities <= chips_per_host).all()
        if speeds is None:
            self.speeds: Optional[np.ndarray] = None
        else:
            assert len(speeds) == hosts
            self.speeds = np.asarray(speeds, dtype=np.float64)
            assert (self.speeds > 0).all()
        self.cost_model = cost_model if cost_model is not None \
            else CostModel()
        self.free = self.capacities.copy()
        self.jobs_on_host: List[set] = [set() for _ in range(hosts)]
        self.default_policy = resolve_policy(policy).with_model(
            self.cost_model)
        self.allocations: Dict[str, Allocation] = {}

    @classmethod
    def for_chips(cls, n_chips: int, chips_per_host: int,
                  **kwargs) -> "PlacementEngine":
        """Engine for a flat pool of ``n_chips`` devices — host count and
        the ragged last host come from ``derive_capacities`` (the single
        shared derivation; ``core.fabric.Fabric`` builds through here)."""
        caps = derive_capacities(n_chips, chips_per_host)
        return cls(len(caps), chips_per_host, capacities=caps, **kwargs)

    # ---- capacity ----------------------------------------------------------
    @property
    def total_chips(self) -> int:
        return int(self.capacities.sum())

    @property
    def heterogeneous(self) -> bool:
        return self.speeds is not None and bool(
            (self.speeds != self.speeds[0]).any())

    def idle_chips(self) -> int:
        return int(self.free.sum())

    def idle_fraction(self) -> float:
        return self.idle_chips() / self.total_chips

    def idle_throughput(self) -> float:
        """Idle capacity in effective (speed-weighted) chips."""
        if self.speeds is None:
            return float(self.idle_chips())
        return float((self.free * self.speeds).sum())

    def view(self) -> ClusterView:
        return self.view_with(self.free)

    def view_with(self, free: np.ndarray) -> ClusterView:
        """A policy view over an alternative free map (scratch planning)
        that still carries this engine's capacities and speeds."""
        return ClusterView(free.copy(), self.chips_per_host,
                           self.capacities, self.speeds)

    # ---- reservation lifecycle ---------------------------------------------
    def reserve(self, n: int,
                policy: Union[str, PlacementPolicy, None] = None,
                kind: Optional[str] = None) -> Optional[Reservation]:
        pol = resolve_policy(policy, self.default_policy).with_model(
            self.cost_model)
        placement = pol.place(self.view(), n, kind=kind)
        if placement is None:
            return None
        for h, c in placement:
            self.free[h] -= c
        assert (self.free >= 0).all()
        return Reservation(placement, slice_size=pol.slice_size)

    def commit(self, res: Reservation, job_id: str) -> Allocation:
        assert not res.settled, "reservation already settled"
        res.settled = True
        for h, _ in res.placement:
            self.jobs_on_host[h].add(job_id)
        alloc = Allocation(job_id, sorted(res.placement),
                           slice_size=res.slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def cancel(self, res: Reservation) -> None:
        assert not res.settled, "reservation already settled"
        res.settled = True
        for h, c in res.placement:
            self.free[h] += c
        assert (self.free <= self.capacities).all()

    # ---- allocation ----------------------------------------------------------
    def allocate(self, job_id: str, n: int,
                 policy: Union[str, PlacementPolicy, None] = None,
                 kind: Optional[str] = None) -> Optional[Allocation]:
        res = self.reserve(n, policy, kind=kind)
        return None if res is None else self.commit(res, job_id)

    def bind(self, job_id: str, placement: Sequence[Tuple[int, int]],
             slice_size: int = 0) -> Allocation:
        """Adopt an externally-determined placement (the live runtime
        attaching the gang it was launched with)."""
        for h, c in placement:
            assert 0 < c <= self.free[h], \
                f"bind over-subscribes host {h}: {c} > {self.free[h]}"
            self.free[h] -= c
            self.jobs_on_host[h].add(job_id)
        alloc = Allocation(job_id, sorted(placement), slice_size=slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def release(self, alloc: Allocation) -> None:
        for h, c in alloc.placement:
            self.free[h] += c
            self.jobs_on_host[h].discard(alloc.job_id)
        self.allocations.pop(alloc.job_id, None)
        assert (self.free <= self.capacities).all()

    # ---- preemption -----------------------------------------------------------
    def preemption_plan(self, n: int, priority: int,
                        priorities: Dict[str, int],
                        policy: Union[str, PlacementPolicy, None] = None,
                        preempt: Optional[PreemptPolicy] = None,
                        kind: Optional[str] = None) -> Optional[List[str]]:
        """Plan victims (see ``PreemptPolicy.plan``) against the live
        allocation table; the caller checkpoints + releases + requeues."""
        return (preempt or PreemptPolicy()).plan(self, n, priority,
                                                 priorities, policy,
                                                 kind=kind)

    # ---- migration (defragmentation at barrier points) ------------------------
    def migration_plan(self, allocs: Sequence[Allocation],
                       kinds: Optional[Mapping[str, str]] = None,
                       remaining: Optional[Mapping[str, float]] = None
                       ) -> List[Tuple[str, Placement]]:
        """For each granular gang, try to find a better placement using
        currently-free chips (+ the chips the gang already holds).
        Returns [(job_id, new_placement)].

        Homogeneous fleet: consolidate fragmented gangs onto fewer hosts
        (the pre-CostModel behaviour, bit-identical).  Heterogeneous
        fleet: candidate moves are costed with the engine's ``CostModel``
        under the gang's job kind (``kinds``), so a gang also migrates
        onto faster hosts when that lowers its predicted ``T`` — the
        same criterion the simulator's rate integration uses.
        ``remaining`` (job_id -> seconds of work left under the current
        placement) makes that check cost-aware: the predicted saving on
        the remaining work must exceed ``CostModel.migration_cost_s``
        (the snapshot transfer the move will pay).  Without it (a
        caller-initiated live barrier migration) any strict improvement
        is emitted.

        Invariants: slice allocations are never migrated; a plan that
        does not strictly improve (fewer hosts / lower predicted T) is
        not emitted; plans are committed against a scratch free map so
        they never double-book chips among themselves.
        """
        plans = []
        free = self.free.copy()
        hetero = self.heterogeneous
        model, speeds = self.cost_model, self.speeds
        for alloc in allocs:
            if alloc.slice_size:
                continue
            if not hetero and alloc.fragmentation() <= 1:
                continue
            held = dict(alloc.placement)
            avail = free.copy()
            for h, c in held.items():
                avail[h] += c
            if hetero:
                kind = (kinds or {}).get(alloc.job_id)
                current = model.score(alloc.placement, kind, speeds)
                candidates = [p for p in (
                    _greedy_most_free(avail, alloc.n, speeds),
                    _greedy_most_free(avail, alloc.n))
                    if p is not None and p != alloc.placement]
                if not candidates:
                    continue
                best = min(candidates,
                           key=lambda p: model.score(p, kind, speeds))
                best_score = model.score(best, kind, speeds)
                if best_score >= current - 1e-12:
                    continue
                rem = (remaining or {}).get(alloc.job_id)
                if rem is not None:
                    # rate scales as 1/score, so the move shrinks the
                    # remaining time by rem*(1 - best/current); it must
                    # buy back the snapshot transfer it costs
                    saving = rem * (1.0 - best_score / current)
                    if saving <= model.migration_cost_s:
                        continue
                new_placement = best
            else:
                # can the gang fit on fewer hosts?
                new_placement = _greedy_most_free(avail, alloc.n)
                if new_placement is None \
                        or len(new_placement) >= alloc.fragmentation():
                    continue
            plans.append((alloc.job_id, new_placement))
            # commit against the scratch free map so plans don't overlap
            for h, c in held.items():
                free[h] += c
            for h, c in new_placement:
                free[h] -= c
        return plans

    def apply_migration(self, alloc: Allocation,
                        new_placement: Sequence[Tuple[int, int]]
                        ) -> Allocation:
        self.release(alloc)
        for h, c in new_placement:
            self.free[h] -= c
            self.jobs_on_host[h].add(alloc.job_id)
        assert (self.free >= 0).all()
        new = Allocation(alloc.job_id, sorted(new_placement))
        self.allocations[alloc.job_id] = new
        return new
