"""Unified block stack for all 10 assigned architectures.

The stack is ``n_periods`` repetitions of a *period* — a short heterogeneous
pattern of block kinds (see ``ArchConfig.period()``).  Parameters are stacked
per period-position, so a single ``lax.scan`` over periods covers dense,
MoE, hybrid (zamba2: 5 mamba + 1 shared-attention), ssm (xlstm: 1 sLSTM +
7 mLSTM), vlm (4 attn + 1 cross-attn) and audio (enc-dec) stacks.  With
``cfg.scan_layers=False`` the periods are unrolled (used by the dry-run so
XLA's cost analysis counts every layer's FLOPs exactly).

Block state (for decode) is likewise stacked per period-position.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import dense_init, matmul, mlp, init_mlp, rms_norm


# ---------------------------------------------------------------------------
# Per-kind block init
# ---------------------------------------------------------------------------
def init_block(key, kind: str, cfg) -> Dict[str, Any]:
    dtype = cfg.param_dtype()
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    ln = lambda: jnp.ones((d,), dtype)
    if kind == cb.ATTN or kind == cb.SHARED_ATTN:
        return {"ln1": ln(), "attn": attn.init_attention(ks[0], cfg),
                "ln2": ln(), "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.act, dtype)}
    if kind == cb.MOE:
        return {"ln1": ln(), "attn": attn.init_attention(ks[0], cfg),
                "ln2": ln(), "moe": moe_mod.init_moe(ks[1], cfg)}
    if kind == cb.CROSS_ATTN:
        # llama3.2-vision style: tanh-gated cross-attention + gated MLP.
        return {"ln1": ln(), "xattn": attn.init_attention(ks[0], cfg),
                "ln2": ln(), "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.act, dtype),
                "gate_attn": jnp.zeros((), jnp.float32),
                "gate_mlp": jnp.zeros((), jnp.float32)}
    if kind == cb.ENCDEC:
        return {"ln1": ln(), "attn": attn.init_attention(ks[0], cfg),
                "lnx": ln(), "xattn": attn.init_attention(ks[1], cfg),
                "ln2": ln(), "mlp": init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)}
    if kind == cb.MAMBA:
        return {"ln1": ln(), "mamba": ssm_mod.init_mamba(ks[0], cfg)}
    if kind == cb.MLSTM:
        return {"ln1": ln(), "mlstm": xlstm_mod.init_mlstm(ks[0], cfg)}
    if kind == cb.SLSTM:
        return {"ln1": ln(), "slstm": xlstm_mod.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def init_block_state(kind: str, cfg, batch: int, max_len: int, dtype,
                     window: int = 0):
    """Decode-time state for one block (unstacked)."""
    if kind in (cb.ATTN, cb.MOE, cb.SHARED_ATTN):
        return attn.init_kv_cache(cfg, batch, max_len, dtype, window=window)
    if kind == cb.CROSS_ATTN:
        hd = cfg.hd()
        return {"k": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, hd),
                               dtype)}
    if kind == cb.ENCDEC:
        hd = cfg.hd()
        c = attn.init_kv_cache(cfg, batch, max_len, dtype)
        c["xk"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype)
        c["xv"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype)
        return c
    if kind == cb.MAMBA:
        return ssm_mod.init_mamba_state(cfg, batch, dtype)
    if kind == cb.MLSTM:
        return xlstm_mod.init_mlstm_state(cfg, batch, dtype)
    if kind == cb.SLSTM:
        return xlstm_mod.init_slstm_state(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Per-kind block apply — full sequence (train / prefill)
# ---------------------------------------------------------------------------
def apply_block_seq(kind: str, p, x, cfg, ctx) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray, Any]:
    """x: (B,S,d) -> (x', aux_loss, state).

    ``state`` is the decode-time handover state (KV cache / SSM state) when
    ``ctx["collect_state"]`` is set; otherwise None (train path).
    """
    aux = jnp.zeros((), jnp.float32)
    pos = ctx["positions"]
    collect = ctx.get("collect_state", False)
    state = None
    if kind in (cb.ATTN, cb.SHARED_ATTN, cb.MOE):
        h, (k, v) = attn.attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), cfg, pos,
            causal=True, window=ctx.get("window", 0))
        if collect:
            state = {"k": k, "v": v}
        x = x + h
        if kind == cb.MOE:
            h, aux = moe_mod.moe_ffn(p["moe"],
                                     rms_norm(p["ln2"], x, cfg.norm_eps), cfg)
        else:
            h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + h, aux, state
    if kind == cb.CROSS_ATTN:
        h, (k, v) = attn.attention(
            p["xattn"], rms_norm(p["ln1"], x, cfg.norm_eps), cfg, pos,
            causal=False, kv_x=ctx["img"], use_rope=False)
        if collect:
            state = {"k": k, "v": v}
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h, aux, state
    if kind == cb.ENCDEC:
        h, (k, v) = attn.attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), cfg, pos,
            causal=True)
        x = x + h
        h, (xk, xv) = attn.attention(
            p["xattn"], rms_norm(p["lnx"], x, cfg.norm_eps), cfg, pos,
            causal=False, kv_x=ctx["enc"], use_rope=False)
        if collect:
            state = {"k": k, "v": v, "xk": xk, "xv": xv}
        x = x + h
        h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + h, aux, state
    if kind == cb.MAMBA:
        h, st = ssm_mod.mamba_forward(p["mamba"],
                                      rms_norm(p["ln1"], x, cfg.norm_eps),
                                      cfg)
        return x + h, aux, (st if collect else None)
    if kind == cb.MLSTM:
        h, st = xlstm_mod.mlstm_forward(p["mlstm"],
                                        rms_norm(p["ln1"], x, cfg.norm_eps),
                                        cfg)
        return x + h, aux, (st if collect else None)
    if kind == cb.SLSTM:
        h, st = xlstm_mod.slstm_forward(p["slstm"],
                                        rms_norm(p["ln1"], x, cfg.norm_eps),
                                        cfg)
        return x + h, aux, (st if collect else None)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Per-kind block apply — single-token decode
# ---------------------------------------------------------------------------
def apply_block_decode(kind: str, p, x, state, cfg, ctx):
    """x: (B,1,d) -> (x', new_state)."""
    pos = ctx["positions"]          # (B,1) absolute positions
    if kind in (cb.ATTN, cb.SHARED_ATTN, cb.MOE):
        h, state = attn.decode_attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), state, cfg, pos,
            window=ctx.get("window", 0))
        x = x + h
        if kind == cb.MOE:
            h, _ = moe_mod.moe_ffn(p["moe"],
                                   rms_norm(p["ln2"], x, cfg.norm_eps), cfg)
        else:
            h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + h, state
    if kind == cb.CROSS_ATTN:
        h, _ = attn.decode_attention(
            p["xattn"], rms_norm(p["ln1"], x, cfg.norm_eps), state, cfg, pos,
            kv_x=True, use_rope=False)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
        h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h, state
    if kind == cb.ENCDEC:
        self_cache = {"k": state["k"], "v": state["v"]}
        h, self_cache = attn.decode_attention(
            p["attn"], rms_norm(p["ln1"], x, cfg.norm_eps), self_cache, cfg,
            pos)
        x = x + h
        h, _ = attn.decode_attention(
            p["xattn"], rms_norm(p["lnx"], x, cfg.norm_eps),
            {"k": state["xk"], "v": state["xv"]}, cfg, pos, kv_x=True,
            use_rope=False)
        x = x + h
        h = mlp(p["mlp"], rms_norm(p["ln2"], x, cfg.norm_eps), cfg.act,
                cfg)
        return x + h, {**self_cache, "xk": state["xk"], "xv": state["xv"]}
    if kind == cb.MAMBA:
        h, state = ssm_mod.mamba_decode(
            p["mamba"], rms_norm(p["ln1"], x, cfg.norm_eps), state, cfg)
        return x + h, state
    if kind == cb.MLSTM:
        h, state = xlstm_mod.mlstm_decode(
            p["mlstm"], rms_norm(p["ln1"], x, cfg.norm_eps), state, cfg)
        return x + h, state
    if kind == cb.SLSTM:
        h, state = xlstm_mod.slstm_decode(
            p["slstm"], rms_norm(p["ln1"], x, cfg.norm_eps), state, cfg)
        return x + h, state
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------
def init_params(key, cfg) -> Dict[str, Any]:
    dtype = cfg.param_dtype()
    period = cfg.period()
    n_per = cfg.n_periods()
    kemb, khead, kblocks, kenc, kshared = jax.random.split(key, 5)

    params: Dict[str, Any] = {
        "embed": dense_init(kemb, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(khead, (cfg.d_model, cfg.vocab), dtype)

    # Stacked per-period-position block params.
    blocks = []
    pkeys = jax.random.split(kblocks, len(period))
    for pos_idx, kind in enumerate(period):
        keys = jax.random.split(pkeys[pos_idx], n_per)
        if kind == cb.SHARED_ATTN:
            blocks.append(None)  # shared weights live in params["shared"]
            continue
        stacked = jax.vmap(lambda k: init_block(k, kind, cfg))(keys)
        blocks.append(stacked)
    params["blocks"] = blocks
    if cb.SHARED_ATTN in period:
        params["shared"] = init_block(kshared, cb.SHARED_ATTN, cfg)

    if cfg.family == "audio":
        ekeys = jax.random.split(kenc, cfg.n_enc_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: init_block(k, cb.ATTN, cfg))(ekeys),
            "norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Encoder (audio): bidirectional attention over pre-embedded frames
# ---------------------------------------------------------------------------
def _sinusoid(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def encode(params, frames, cfg):
    """frames: (B, enc_seq, d) stub frontend output -> encoder states."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
    enc = params["encoder"]
    positions = jnp.arange(frames.shape[1])[None, :]
    ctx = {"positions": positions}

    def body(h, p):
        h2, _ = attn.attention(p["attn"], rms_norm(p["ln1"], h, cfg.norm_eps),
                               cfg, positions, causal=False, use_rope=False)
        h = h + h2
        h = h + mlp(p["mlp"], rms_norm(p["ln2"], h, cfg.norm_eps),
                    cfg.act, cfg)
        return h, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, p: body(h, p), x, enc["blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], enc["blocks"]))
    return rms_norm(enc["norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg, ctx: Optional[Dict[str, Any]] = None):
    """tokens: (B,S) int32 -> (logits (B,S,V), aux_loss, states).

    ``states`` is a list of stacked per-period-position decode states when
    ``ctx["collect_state"]`` (prefill), else None.
    """
    ctx = dict(ctx or {})
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    ctx.setdefault("positions", jnp.arange(s)[None, :])
    if cfg.family == "audio":
        ctx["enc"] = encode(params, ctx["frames"], cfg)
    collect = ctx.get("collect_state", False)

    period = cfg.period()
    scanned = tuple(p for p in params["blocks"] if p is not None)

    def period_body(carry, stacked):
        x, aux = carry
        it = iter(stacked)
        states = []
        for kind in period:
            p = params["shared"] if kind == cb.SHARED_ATTN else next(it)
            x, a, st = apply_block_seq(kind, p, x, cfg, ctx)
            aux = aux + a
            states.append(st)
        return (x, aux), (tuple(states) if collect else None)

    body = period_body
    if cfg.remat and not collect:
        # prevent_cse=False is only safe under scan (no cross-iteration CSE);
        # unrolled bodies need the default True or CSE undoes the remat.
        body = jax.checkpoint(period_body, prevent_cse=not cfg.scan_layers)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, aux), states = jax.lax.scan(body, (x, aux0), scanned)
    else:
        x, aux = x, aux0
        per_period = []
        for i in range(cfg.n_periods()):
            sl = jax.tree.map(lambda a: a[i], scanned)
            (x, aux), st = body((x, aux), sl)
            per_period.append(st)
        states = (jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
                  if collect else None)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if ctx.get("return_hidden"):
        return x, aux, (list(states) if collect else None)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = matmul(x, head)
    return logits, aux, (list(states) if collect else None)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def init_decode_state(cfg, batch: int, max_len: int, dtype, window: int = 0):
    """Stacked per-period-position decode state (pytree of (n_per, ...))."""
    n_per = cfg.n_periods()
    states = []
    for kind in cfg.period():
        one = init_block_state(kind, cfg, batch, max_len, dtype,
                               window=window)
        states.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_per,) + a.shape), one))
    return states


def decode_step(params, tokens, states, positions, cfg,
                ctx: Optional[Dict[str, Any]] = None):
    """One-token decode. tokens: (B,1); positions: (B,1) absolute.

    states: output of ``init_decode_state`` (possibly filled by prefill).
    Returns (logits (B,1,V), new_states).
    """
    ctx = dict(ctx or {})
    ctx["positions"] = positions
    x = jnp.take(params["embed"], tokens, axis=0)
    period = cfg.period()
    scanned_params = tuple(p for p in params["blocks"] if p is not None)
    scanned_states = tuple(states)

    def period_body(x, xs):
        ps, sts = xs
        it = iter(ps)
        new_sts = []
        for kind, st in zip(period, sts):
            p = params["shared"] if kind == cb.SHARED_ATTN else next(it)
            x, st2 = apply_block_decode(kind, p, x, st, cfg, ctx)
            new_sts.append(st2)
        return x, tuple(new_sts)

    if cfg.scan_layers:
        x, new_states = jax.lax.scan(
            period_body, x, (scanned_params, scanned_states))
    else:
        outs = []
        for i in range(cfg.n_periods()):
            ps = jax.tree.map(lambda a: a[i], scanned_params)
            sts = jax.tree.map(lambda a: a[i], scanned_states)
            x, st2 = period_body(x, (ps, sts))
            outs.append(st2)
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return matmul(x, head), list(new_states)
