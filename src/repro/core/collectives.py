"""Locality-aware collectives (paper §5.3, Fig 9) as shard_map programs.

Faabric's VM-leader all-reduce sends one message per remote VM per step and
uses fast in-memory queues within a VM.  The TPU mapping: the **pod** is the
VM (slow DCI/DCN links between pods ↔ cross-VM network), the intra-pod ICI
is the in-memory queue.  The two-level schedule becomes:

    reduce-scatter over the fast (intra-pod) axis      [each chip owns 1/n]
    all-reduce over the slow (cross-pod) axis          [shard-sized traffic]
    all-gather over the fast axis                      [redistribute]

which moves ``bytes/n_fast`` over the slow link instead of ``bytes`` —
the generalisation of "one leader message per VM".  An optional top-k
delta compression (``optim.compress``) shrinks the slow hop further
(beyond-paper, DESIGN.md §5).

All functions here are *per-device* (inside shard_map).  ``build_*`` helpers
wrap them in shard_map over a mesh for direct use.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat
from repro.core.compat import shard_map


# ---------------------------------------------------------------------------
# Pytree <-> padded flat vector (gradient bucketing)
# ---------------------------------------------------------------------------
def flatten_tree(tree, pad_to: int = 1):
    """Concatenate all leaves into one f32 vector, padded to a multiple of
    ``pad_to`` (bucketing: one collective for the whole tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    vec = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    pad = (-vec.size) % pad_to
    if pad:
        vec = jnp.pad(vec, (0, pad))
    return vec, (treedef, sizes, [l.shape for l in leaves],
                 [l.dtype for l in leaves])


def unflatten_tree(vec, spec):
    treedef, sizes, shapes, dtypes = spec
    out, off = [], 0
    for n, shp, dt in zip(sizes, shapes, dtypes):
        out.append(vec[off:off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Per-device collective bodies (call inside shard_map)
# ---------------------------------------------------------------------------
def hierarchical_psum(vec, fast_axis: str, slow_axis: Optional[str]):
    """Two-level all-reduce of a flat vector (paper Fig 9 schedule)."""
    vec = jax.lax.psum_scatter(vec, fast_axis, scatter_dimension=0,
                               tiled=True)
    if slow_axis is not None:
        vec = jax.lax.psum(vec, slow_axis)
    return jax.lax.all_gather(vec, fast_axis, axis=0, tiled=True)


def flat_psum(vec, axes: Sequence[str]):
    """Single flat all-reduce over all axes (the baseline schedule)."""
    return jax.lax.psum(vec, tuple(axes))


def compressed_hierarchical_psum(vec, fast_axis: str, slow_axis: str,
                                 frac: float, resid_shard=None):
    """Two-level all-reduce with top-k delta compression on the slow hop.

    After the intra-pod reduce-scatter, each chip owns a disjoint shard.
    Only the top-k fraction of that shard crosses the pod boundary
    (merge-op = sum on sparse (idx, val) diffs — the paper's byte-wise-diff
    protocol generalised to sparse deltas); the remainder stays local as an
    error-feedback residual (``resid_shard``) added to the next step's
    shard, preserving convergence.
    """
    shard = jax.lax.psum_scatter(vec, fast_axis, scatter_dimension=0,
                                 tiled=True)
    if resid_shard is not None:
        shard = shard + resid_shard
    k = max(1, int(shard.size * frac))
    mag = jnp.abs(shard)
    vals, idx = jax.lax.top_k(mag, k)
    sel = shard[idx]
    residual = shard.at[idx].set(0.0)
    # ship only (idx, val) over the slow link; sum-merge on arrival
    all_sel = jax.lax.all_gather(sel, slow_axis, axis=0)       # (pods, k)
    all_idx = jax.lax.all_gather(idx, slow_axis, axis=0)
    merged = jnp.zeros_like(shard).at[all_idx.reshape(-1)].add(
        all_sel.reshape(-1))
    out = jax.lax.all_gather(merged, fast_axis, axis=0, tiled=True)
    return out, residual


def ring_allreduce(vec, axis: str):
    """Bandwidth-optimal ring all-reduce via explicit collective-permutes
    (2*(n-1) steps: reduce-scatter ring + all-gather ring).  This is the
    ppermute mapping of the paper's p2p messaging layer."""
    n = compat.axis_size(axis)
    if n == 1:
        return vec
    me = jax.lax.axis_index(axis)
    chunks = vec.reshape(n, -1)
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(c, chunks):
        # at step s, rank r sends chunk (r - s) mod n
        send_idx = (me - c) % n
        recv_idx = (me - c - 1) % n
        sent = jax.lax.ppermute(chunks[send_idx], axis, perm_fwd)
        return chunks.at[recv_idx].add(sent)

    for s in range(n - 1):
        chunks = rs_step(s, chunks)

    def ag_step(c, chunks):
        send_idx = (me - c + 1) % n
        recv_idx = (me - c) % n
        sent = jax.lax.ppermute(chunks[send_idx], axis, perm_fwd)
        return chunks.at[recv_idx].set(sent)

    for s in range(n - 1):
        chunks = ag_step(s, chunks)
    return chunks.reshape(vec.shape)


# ---------------------------------------------------------------------------
# Mesh-level wrappers
# ---------------------------------------------------------------------------
def dp_axes(mesh: Mesh) -> Tuple[str, Optional[str]]:
    """(fast_axis, slow_axis) for the data-parallel dimension of a mesh."""
    names = mesh.axis_names
    slow = "pod" if "pod" in names else None
    return "data", slow


def padded_size(tree, n_fast: int) -> int:
    total = sum(l.size for l in jax.tree.leaves(tree))
    return total + (-total) % n_fast


def init_residual_buffer(mesh: Mesh, tree):
    """Zero error-feedback buffer: (n_pods, padded_flat_size) f32, sharded
    P('pod', 'data') so each chip holds its own scattered shard."""
    fast, slow = dp_axes(mesh)
    n_pods = mesh.shape[slow] if slow else 1
    n_total = n_pods * mesh.shape[fast]
    return jnp.zeros((n_pods, padded_size(tree, n_total)), jnp.float32)


def tree_sync_body(tree, mode: str, fast: str, slow: Optional[str],
                   n_total: int, compress_frac: Optional[float] = None,
                   resid_shard=None):
    """Per-device gradient sync of a pytree (call inside shard_map).

    Returns (mean tree, new residual shard or None)."""
    vec, spec = flatten_tree(tree, pad_to=n_total)  # divisible by n_fast too
    if mode == "flat":
        out, resid = flat_psum(vec, [a for a in (fast, slow) if a]), None
    elif mode == "ring":
        out = ring_allreduce(vec, fast)
        if slow is not None:
            out = jax.lax.psum(out, slow)
        resid = None
    elif mode == "hierarchical":
        out, resid = hierarchical_psum(vec, fast, slow), None
    elif mode == "compressed":
        assert slow is not None and compress_frac is not None
        out, resid = compressed_hierarchical_psum(
            vec, fast, slow, compress_frac, resid_shard=resid_shard)
    else:
        raise ValueError(mode)
    return unflatten_tree(out / n_total, spec), resid


def build_tree_allreduce(mesh: Mesh, mode: str = "hierarchical",
                         compress_frac: Optional[float] = None) -> Callable:
    """Returns f(tree, resid) -> (tree_mean, new_resid): all-reduce-mean a
    tree whose leaves carry a leading device axis of size n_devices (one
    private copy per device).  ``resid`` is the (n_pods, n_pad) error
    feedback buffer for mode='compressed' (pass None otherwise)."""
    fast, slow = dp_axes(mesh)
    axes = [a for a in (fast, slow) if a is not None]
    n_total = int(np.prod([mesh.shape[a] for a in axes]))

    def per_device(tree, resid):
        rs = resid[0] if resid is not None else None
        out, new_rs = tree_sync_body(tree, mode, fast, slow, n_total,
                                     compress_frac, rs)
        return out, (new_rs[None] if new_rs is not None else None)

    # every device holds its own (different) copy: specs are fully sharded
    spec_in = P(tuple(a for a in (("pod",) if slow else ()) + (fast,)))
    resid_spec = P(slow, fast) if slow else None

    def allreduce(tree, resid=None):
        return shard_map(per_device, mesh=mesh,
                         in_specs=(jax.tree.map(lambda _: spec_in, tree),
                                   resid_spec),
                         out_specs=(jax.tree.map(lambda _: spec_in, tree),
                                    (resid_spec if mode == "compressed"
                                     else None)),
                         check_vma=False)(tree, resid)

    return allreduce


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in an HLO dump — the
    ``collective term`` source for the roofline analysis."""
    import re
    sizes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
             "f8e5m2": 1, "s16": 2, "u16": 2}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    # count bytes of the OUTPUT shape of each collective instruction
    pat = re.compile(
        r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))"
        r"[^=]*?(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)", re.M)
    shape_pat = re.compile(r"(\w+)\[([\d,]*)\]")
    for m in pat.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in sizes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * sizes[dt]
        out[kind] += nbytes
    out["total"] = sum(out[k] for k in kinds)
    return out
