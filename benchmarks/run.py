"""Benchmark driver: one module per paper table/figure.

Prints ``bench,name,value,unit,paper_ref`` CSV lines; ``--only`` selects
one benchmark; results land in results/bench.csv plus one standardized
``results/BENCH_<name>.json`` per benchmark (schema below) so the perf
trajectory is machine-readable across PRs:

    {"bench": str, "schema": 3, "unix_time": float, "wall_s": float,
     "git_sha": str, "fleet": {...},
     "sections": {section: wall_s},
     "telemetry_summary": path, "trace": path,   # schema >= 3
     "metrics": {name: {"value": num, "unit": str, "note": str}}}

``git_sha`` is the commit the numbers were measured at and ``fleet``
the benchmark module's ``FLEET`` dict (hosts / chips-per-host /
scheduler config), so an artifact is attributable without the CSV.

Schema 3 additions (schema-2 artifacts stay readable — every consumer
treats the new keys as optional):

* each benchmark runs under a fresh ``core.telemetry`` recorder; its
  metrics summary lands at ``results/<prefix>_<bench>_telemetry.json``
  and — on ``--tiny`` (the CI bench-smoke step) — a Perfetto-loadable
  Chrome trace at ``results/<prefix>_<bench>_trace.json``.  Full-tier
  runs skip the trace file (a full bench_makespan timeline is tens of
  MB of JSON) but keep the summary.
* ``sections`` attributes the bench's wall time to metric-name prefixes
  (the part before the first "/"): each reported metric charges the
  time since the previous report to its section.

``--tiny`` runs every benchmark at smoke sizes (the CI bench-smoke
step): artifacts then land as ``results/SMOKE_<name>.json`` so the
committed full-size ``BENCH_*.json`` trajectory is never clobbered by a
smoke run, and each smoke artifact is asserted to carry metrics.
"""
from __future__ import annotations

import argparse
import csv
import importlib
import inspect
import json
import os
import subprocess
import sys
import time

from repro.core import telemetry

BENCHES = [
    "bench_makespan",         # Fig 10
    "bench_scaling",          # Fig 11
    "bench_shared_memory",    # Fig 12
    "bench_message_passing",  # Fig 13 / Fig 9
    "bench_migration",        # Fig 14
    "bench_scheduler_scale",  # Fig 11 fix: sharded + vectorized engine
    "bench_churn",            # fleet churn: reclaim/fail + Young/Daly
    "bench_serving",          # continuous batching + SLO autoscaling
    "bench_telemetry",        # predicted-vs-live divergence + Perfetto
]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
OUT = os.path.join(RESULTS_DIR, "bench.csv")


def git_sha() -> str:
    """Short SHA of the commit the numbers were measured at, with a
    ``-dirty`` marker when the working tree has uncommitted changes."""
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd)
        sha = out.stdout.strip()
        if not sha:
            return "unknown"
        status = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=10, cwd=cwd)
        return sha + ("-dirty" if status.stdout.strip() else "")
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_bench_json(bench: str, metrics, wall_s: float,
                     tiny: bool = False, fleet=None, sections=None,
                     telemetry_summary=None, trace=None) -> str:
    prefix = "SMOKE" if tiny else "BENCH"
    path = os.path.join(os.path.abspath(RESULTS_DIR),
                        f"{prefix}_{bench}.json")
    payload = {
        "bench": bench,
        "schema": 3,
        "unix_time": time.time(),
        "wall_s": round(wall_s, 2),
        "git_sha": git_sha(),
        "fleet": dict(fleet or {}),
        "sections": {k: round(v, 3)
                     for k, v in sorted((sections or {}).items())},
        "metrics": {name: {"value": value, "unit": unit, "note": note}
                    for name, value, unit, note in metrics},
    }
    if telemetry_summary:
        payload["telemetry_summary"] = telemetry_summary
    if trace:
        payload["trace"] = trace
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke sizes; artifacts go to SMOKE_*.json")
    args = ap.parse_args()
    results_dir = os.path.abspath(RESULTS_DIR)
    os.makedirs(results_dir, exist_ok=True)
    prefix = "SMOKE" if args.tiny else "BENCH"
    rows = []
    current = ""
    current_metrics = []
    sections = {}
    t_last = [0.0]
    # stdout is real CSV (notes may contain commas -> quoted), matching
    # the results/bench.csv writer exactly
    stdout_csv = csv.writer(sys.stdout)

    def report(name, value, unit="", note=""):
        now = time.time()
        section = str(name).split("/", 1)[0]
        sections[section] = sections.get(section, 0.0) + (now - t_last[0])
        t_last[0] = now
        rows.append((current, name, value, unit, note))
        current_metrics.append((name, value, unit, note))
        stdout_csv.writerow([current, name, value, unit, note])

    stdout_csv.writerow(["bench", "name", "value", "unit", "paper_ref"])
    for mod_name in ([args.only] if args.only else BENCHES):
        current = mod_name
        current_metrics = []
        sections = {}
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        t_last[0] = t0
        # fresh recorder per bench: its summary (and, at smoke tier,
        # the Perfetto trace) lands next to the artifact
        tel = telemetry.enable(telemetry.Telemetry())
        try:
            if "tiny" in inspect.signature(mod.run).parameters:
                mod.run(report, tiny=args.tiny)
            else:
                mod.run(report)
        finally:
            telemetry.disable()
        wall = time.time() - t0
        rows.append((mod_name, "bench_wall", round(wall, 1), "s", ""))
        summary_path = os.path.join(
            results_dir, f"{prefix}_{mod_name}_telemetry.json")
        tel.write_summary(summary_path)
        trace_path = None
        if args.tiny:
            trace_path = os.path.join(
                results_dir, f"{prefix}_{mod_name}_trace.json")
            tel.write_chrome_trace(trace_path)
        path = write_bench_json(mod_name, current_metrics, wall,
                                tiny=args.tiny,
                                fleet=getattr(mod, "FLEET", None),
                                sections=sections,
                                telemetry_summary=summary_path,
                                trace=trace_path)
        assert current_metrics, f"{mod_name} reported no metrics"
        print(f"# wrote {path}")
    if not args.tiny:
        with open(OUT, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["bench", "name", "value", "unit", "paper_ref"])
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
