"""jit'd wrapper: fused expert FFN over capacity-dispatched MoE inputs."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def expert_ffn(xe, w1, w2, w3, *, act: str = "silu",
               interpret: bool | None = None):
    """xe: (G, E, C, d) dispatched tokens -> (G, E, C, d).

    Reshapes to the kernel's (E, G*C, d) layout (experts outermost so one
    expert's weights load once per tile row)."""
    if interpret is None:
        interpret = _interpret_default()
    g, e, c, d = xe.shape
    x = jnp.swapaxes(xe, 0, 1).reshape(e, g * c, d)
    m = g * c
    bm = 128
    while m % bm:
        bm //= 2
    y = _k.expert_ffn(x, w1, w2, w3, act=act, block_m=bm,
                      interpret=interpret)
    return jnp.swapaxes(y.reshape(e, g, c, d), 0, 1)
