"""Batched serving runtime: continuous prefill + decode with KV caches.

Requests carry a prompt; the runtime decodes one token per step for every
in-flight request.  Serving gangs are Granule groups like training gangs:
attach a ``core.fabric.GangHandle`` and the replica's **serving state** —
params + decode caches + next-token cursor — lives replicated on the
gang's mesh.  That state is the snapshot, so migration, preemption and
bit-exact resume work identically to training (a KV cache is just more
shared state to diff — paper §4 applies unchanged).  Each decode step is
a barrier control point: ``decode_step`` returns between tokens, so a
driver can interleave several gangs on one fabric and move this one
mid-generation.

Two engines share the Request/ServeStats types:

* ``ServeLoop`` — the fixed-batch baseline: one equal-length batch,
  admitted together, drained to the slowest request before the next
  batch may start.
* ``ContinuousServeLoop`` — iteration-level (continuous) batching over a
  fixed-capacity **slot array**: static shapes (no jit recompiles, one
  prefill compile per power-of-two prompt bucket), an active-slot mask
  with per-slot cursors/positions, and ragged prompts.  A finished
  request frees its slot immediately; a queued request prefills into a
  free slot *mid-generation* — its prefill state is spliced into the
  slot's lane of the decode buffers while the other lanes keep
  decoding.  Snapshots carry the slot occupancy, so a partially-filled
  batch migrates / preempts / resumes bit-exactly.

Lane independence caveat: every decode op is per-lane *except* MoE
capacity-factor routing, where expert capacity couples the batch — token
streams then depend on batch composition in either engine (the same
reason ``test_decode_consistency`` pins MoE parity with a no-drop
capacity factor).  Determinism and bit-exact resume hold regardless: the
snapshot carries the exact lane contents, garbage included.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MAMBA, MLSTM, SLSTM, ArchConfig
from repro.core import telemetry
from repro.core.fabric import GangHandle
from repro.models import model as model_mod
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    priority: int = 0               # admission class (0 = highest)
    arrival: float = 0.0            # open-loop arrival time (virtual s)
    t_admit: Optional[float] = None  # when a slot/batch accepted it
    t_first: Optional[float] = None  # first decoded token emitted
    t_done: Optional[float] = None   # last token emitted (slot freed)


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    steps: int = 0
    admitted: int = 0
    finished: int = 0


class ServeLoop:
    """Fixed-batch serving of equal-length prompts (greedy decoding)."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 256,
                 window: int = 0, handle: Optional[GangHandle] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window = window
        self.handle: Optional[GangHandle] = None
        self._prefill = jax.jit(model_mod.make_prefill_step(cfg,
                                                            window=window))
        self._serve = jax.jit(model_mod.make_serve_step(cfg, window=window))
        self.stats = ServeStats()
        # in-flight decode batch (None when idle)
        self._reqs: Optional[List[Request]] = None
        self._states = None
        self._cur = None
        self._plen = 0
        self._t = 0
        self._max_new = 0
        if handle is not None:
            self.attach(handle)

    # ---- gang placement ----------------------------------------------------
    def attach(self, handle: GangHandle,
               state: Optional[Dict[str, Any]] = None) -> None:
        """Run this replica as a gang on a shared fabric: place params
        (and any in-flight decode state) replicated on the gang mesh.
        Re-attach after a migrate/rescale/resume to follow the new
        placement; ``state`` adopts a restored/resharded serving state in
        the same move."""
        self.handle = handle
        if state is not None:
            self.load_serve_state(state)
        else:
            self._place()

    def _replicated(self, tree):
        if self.handle is None or self.handle.mesh is None:
            return tree
        s = NamedSharding(self.handle.mesh, P())
        return jax.tree.map(lambda x: jax.device_put(x, s), tree)

    def _place(self) -> None:
        self.params = self._replicated(self.params)
        if self._reqs is not None:
            self._states = self._replicated(self._states)
            self._cur = self._replicated(self._cur)

    # ---- serving state = the snapshot (migration/preemption unit) ----------
    def serve_state(self) -> Dict[str, Any]:
        """Pytree capturing the replica mid-generation: params + decode
        caches + cursor, plus the host-side request bookkeeping — so the
        snapshot restores into a *fresh* ServeLoop, not just this one."""
        st: Dict[str, Any] = {"params": self.params}
        if self._reqs is not None:
            st["states"] = self._states
            st["cur"] = self._cur
            # int32 throughout: snapshot restore device_puts every leaf,
            # and with x64 disabled an int64 leaf would silently downcast
            # — breaking the bit-exact resume fingerprint
            st["decode"] = {
                "meta": np.asarray([self._plen, self._t, self._max_new],
                                   np.int32),
                "rids": np.asarray([r.rid for r in self._reqs], np.int32),
                "prompts": [np.asarray(r.prompt, np.int32)
                            for r in self._reqs],
                "max_new": np.asarray([r.max_new_tokens
                                       for r in self._reqs], np.int32),
                "outs": [np.asarray(r.out, np.int32) for r in self._reqs],
            }
        return st

    def load_serve_state(self, st: Dict[str, Any]) -> None:
        """Adopt a (restored or resharded) serving state; generation
        continues exactly where the snapshot was taken.  When this loop
        has no in-flight batch (fresh process / driver), the snapshot's
        request bookkeeping rebuilds it; an already-live batch keeps its
        own Request objects (same generation, callers hold references)."""
        self.params = st["params"]
        if "states" in st:
            self._states = st["states"]
            self._cur = st["cur"]
            dec = st.get("decode")
            if dec is not None:
                plen, t, max_new = (int(x) for x in np.asarray(dec["meta"]))
                self._plen, self._t, self._max_new = plen, t, max_new
                if self._reqs is None:
                    self._reqs = [
                        Request(rid=int(rid),
                                prompt=np.asarray(p, np.int32),
                                max_new_tokens=int(mn),
                                out=[int(x) for x in np.asarray(o)])
                        for rid, p, mn, o in zip(dec["rids"],
                                                 dec["prompts"],
                                                 dec["max_new"],
                                                 dec["outs"])]
        self._place()

    def _pad_states(self, states, prompt_len: int):
        """Grow prefill KV caches to max_len-sized decode buffers.

        Which leaves are seq-sized is decided against the
        ``init_decode_state`` template shapes, not a dimension
        heuristic — a recurrent state whose head axis happens to equal
        the prompt length must not be padded."""
        size = min(self.max_len, self.window) if self.window else self.max_len
        batch = jax.tree.leaves(states)[0].shape[1]
        template = jax.eval_shape(
            lambda: tf.init_decode_state(self.cfg, batch, self.max_len,
                                         self.cfg.param_dtype(),
                                         window=self.window))

        def pad(x, t):
            if x.shape == t.shape:
                return x
            if size <= x.shape[2]:
                return x[:, :, -size:]
            pad_spec = [(0, 0)] * x.ndim
            pad_spec[2] = (0, size - x.shape[2])
            return jnp.pad(x, pad_spec)
        return [jax.tree.map(pad, s, t) for s, t in zip(states, template)]

    # ---- decode lifecycle --------------------------------------------------
    def start(self, requests: Sequence[Request],
              extras: Optional[Dict[str, Any]] = None) -> None:
        """Admit + prefill a batch; decoding proceeds via decode_step."""
        reqs = list(requests)
        b = len(reqs)
        plen = len(reqs[0].prompt)
        assert all(len(r.prompt) == plen for r in reqs), "equal-length batch"
        tokens = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = self._replicated({"tokens": tokens, **(extras or {})})
        last_logits, states = self._prefill(self.params, batch)
        self.stats.prefill_tokens += b * plen
        self._reqs = reqs
        self._states = self._pad_states(states, plen)
        self._cur = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        self._plen = plen
        self._t = 0
        self._max_new = max(r.max_new_tokens for r in reqs)
        self._place()

    @property
    def done(self) -> bool:
        return self._reqs is None or self._t >= self._max_new

    def decode_step(self) -> bool:
        """One token for the whole batch; returns True while decoding.
        The step boundary is this gang's control point — between calls
        the replica may be migrated or snapshotted."""
        if self.done:
            return False
        reqs, t, b = self._reqs, self._t, len(self._reqs)
        live = 0
        for i, r in enumerate(reqs):
            if t < r.max_new_tokens:
                r.out.append(int(self._cur[i]))
                live += 1
        pos = jnp.full((b, 1), self._plen + t, jnp.int32)
        logits, self._states = self._serve(self.params, self._states,
                                           self._cur[:, None], pos)
        self._cur = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        # only requests still below their own max_new_tokens produced a
        # useful token this step — the lanes decoding past their budget
        # are pure fixed-batch overhead and must not inflate throughput
        self.stats.decoded_tokens += live
        self.stats.steps += 1
        self._t += 1
        if self.done:
            # drop the drained batch AND its device state — idle decode
            # buffers would otherwise pin device memory on a shared fabric
            self._reqs = None
            self._states = None
            self._cur = None
            return False
        return True

    def run(self, requests: Sequence[Request],
            extras: Optional[Dict[str, Any]] = None) -> List[Request]:
        reqs = list(requests)
        self.start(reqs, extras=extras)
        while self.decode_step():
            pass
        return reqs


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two >= n (min ``lo``): bounds prefill compiles."""
    b = lo
    while b < n:
        b *= 2
    return b


def make_ragged_prefill(cfg: ArchConfig, window: int = 0):
    """(params, batch, length) -> (last_logits (B,1,V), decode states).

    Like ``model.make_prefill_step`` but the prompt may be right-padded
    to a static bucket: logits come from the *true* last position
    (``length - 1``, a traced scalar) rather than the padded one.  Safe
    for attention-family states because ``decode_attention`` masks
    ``j <= pos`` per lane and every padded cache row is overwritten by a
    decode write before it first becomes attendable; recurrent blocks
    must be fed exact-length prompts (see ContinuousServeLoop)."""
    def prefill(params, batch, length):
        ctx = model_mod._ctx_from_batch(cfg, batch, collect_state=True,
                                        window=window, return_hidden=True)
        hidden, _, states = tf.forward(params, batch["tokens"], cfg, ctx)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        last = jax.lax.dynamic_slice_in_dim(hidden, length - 1, 1, axis=1)
        logits = jax.lax.dot_general(
            last, head, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return logits, states
    return prefill


class ContinuousServeLoop:
    """Iteration-level batching over a fixed-capacity slot array.

    ``slots`` lanes share one set of static-shape decode buffers
    (``tf.init_decode_state`` with batch = slots).  ``admit`` prefills
    one ragged prompt (bucketed to a power of two) and splices the
    resulting per-lane state into a free slot — mid-generation, while
    other lanes keep decoding.  ``decode_step`` advances every occupied
    lane one token with per-slot positions; a lane reaching its own
    ``max_new_tokens`` frees its slot immediately.  Inactive lanes carry
    stale garbage by design: every batched op is lane-independent and a
    splice rewrites the whole lane, so garbage never leaks into live
    requests (and the engine stays deterministic for bit-exact resume).

    The snapshot (``serve_state``) is params + buffers + cursor + the
    full slot bookkeeping (occupancy mask, per-slot cursors, ragged
    prompts, partial outputs, finished rids) — restoring into a fresh
    loop resumes a partially-occupied batch exactly.
    """

    def __init__(self, cfg: ArchConfig, params, slots: int = 4,
                 max_len: int = 256, window: int = 0,
                 handle: Optional[GangHandle] = None):
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.max_len = max_len
        self.window = window
        self.handle: Optional[GangHandle] = None
        self.stats = ServeStats()
        self._size = min(max_len, window) if window else max_len
        # recurrent state is a running reduction over the prompt — a
        # right-padded prefill would fold pad tokens into it, so those
        # configs prefill at exact length (one compile per length)
        self._exact_prefill = any(k in (MAMBA, MLSTM, SLSTM)
                                  for k in cfg.period())
        self._serve = jax.jit(model_mod.make_serve_step(cfg, window=window))
        self._admit_fns: Dict[int, Any] = {}   # prompt bucket -> jitted fn
        # host-side slot bookkeeping (rides in the snapshot)
        self._reqs: List[Optional[Request]] = [None] * self.slots
        self._plen = np.zeros(self.slots, np.int32)
        self._t = np.zeros(self.slots, np.int32)
        self._max_new = np.zeros(self.slots, np.int32)
        self._done_rids: List[int] = []
        # device-side slot state (lazy until the first admit)
        self._states = None
        self._cur = None
        if handle is not None:
            self.attach(handle)

    # ---- gang placement ----------------------------------------------------
    def attach(self, handle: GangHandle,
               state: Optional[Dict[str, Any]] = None) -> None:
        """Follow a (new) gang placement; ``state`` adopts a restored /
        resharded serving state in the same move (see ServeLoop)."""
        self.handle = handle
        if state is not None:
            self.load_serve_state(state)
        else:
            self._place()

    def _replicated(self, tree):
        if self.handle is None or self.handle.mesh is None:
            return tree
        s = NamedSharding(self.handle.mesh, P())
        return jax.tree.map(lambda x: jax.device_put(x, s), tree)

    def _place(self) -> None:
        self.params = self._replicated(self.params)
        if self._states is not None:
            self._states = self._replicated(self._states)
            self._cur = self._replicated(self._cur)

    # ---- slot accounting ---------------------------------------------------
    @property
    def active(self) -> int:
        return sum(1 for r in self._reqs if r is not None)

    @property
    def free_slots(self) -> int:
        return self.slots - self.active

    @property
    def done(self) -> bool:
        return self.active == 0

    def occupied_rids(self) -> List[int]:
        return [r.rid for r in self._reqs if r is not None]

    @property
    def done_rids(self) -> List[int]:
        return list(self._done_rids)

    def _occ(self) -> np.ndarray:
        return np.asarray([r is not None for r in self._reqs], bool)

    def _ensure_states(self) -> None:
        if self._states is None:
            self._states = self._replicated(tf.init_decode_state(
                self.cfg, self.slots, self.max_len,
                self.cfg.param_dtype(), window=self.window))
            self._cur = self._replicated(
                jnp.zeros((self.slots,), jnp.int32))

    # ---- admission: ragged prefill spliced into one lane -------------------
    def _admit_fn(self, bucket: int):
        fn = self._admit_fns.get(bucket)
        if fn is not None:
            return fn
        prefill = make_ragged_prefill(self.cfg, self.window)

        def admit(params, states, cur, batch, length, slot):
            logits, pre = prefill(params, batch, length)

            def splice(big, row):
                row = row[:, 0]                 # drop the batch-1 axis
                if big.ndim == 5 and row.shape[1] != big.shape[2]:
                    # KV-style leaf (P, B, S, kv, hd): grow the bucket-
                    # sized prefill cache to the lane's full buffer
                    pad = [(0, 0)] * row.ndim
                    pad[1] = (0, big.shape[2] - row.shape[1])
                    row = jnp.pad(row, pad)
                return big.at[:, slot].set(row.astype(big.dtype))

            new_states = jax.tree.map(splice, states, pre)
            tok = jnp.argmax(logits[0, 0], axis=-1).astype(jnp.int32)
            return new_states, cur.at[slot].set(tok)

        fn = jax.jit(admit)
        self._admit_fns[bucket] = fn
        return fn

    def admit(self, req: Request, now: Optional[float] = None,
              extras: Optional[Dict[str, Any]] = None) -> Optional[int]:
        """Prefill ``req`` into a free slot; returns the slot index or
        None when the batch is full.  Runs between decode steps — the
        other lanes' in-flight state is untouched."""
        slot = next((i for i in range(self.slots)
                     if self._reqs[i] is None), None)
        if slot is None:
            return None
        prompt = np.asarray(req.prompt, np.int32)
        plen = len(prompt)
        assert 0 < plen <= self._size, \
            f"prompt ({plen}) must fit the decode buffer ({self._size})"
        self._ensure_states()
        bucket = plen if self._exact_prefill \
            else min(self._size, _bucket(plen))
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :plen] = prompt
        batch = self._replicated({"tokens": jnp.asarray(tokens),
                                  **(extras or {})})
        fn = self._admit_fn(bucket)
        self._states, self._cur = fn(self.params, self._states, self._cur,
                                     batch, jnp.int32(plen),
                                     jnp.int32(slot))
        self._reqs[slot] = req
        self._plen[slot] = plen
        self._t[slot] = 0
        self._max_new[slot] = req.max_new_tokens
        self.stats.prefill_tokens += plen
        self.stats.admitted += 1
        if now is not None:
            req.t_admit = now
        tel = telemetry.get()
        if tel.enabled:
            tel.count("serve.admitted")
            tel.gauge("serve.slot_occupancy", self.active / self.slots,
                      t=now)
            if now is not None:
                tel.observe("serve.queue_wait_s", now - req.arrival)
        return slot

    def _free(self, slot: int) -> None:
        req = self._reqs[slot]
        if req is not None:
            self._done_rids.append(req.rid)
        self._reqs[slot] = None
        self._plen[slot] = 0
        self._t[slot] = 0
        self._max_new[slot] = 0
        self.stats.finished += 1

    # ---- decode ------------------------------------------------------------
    def decode_step(self, now: Optional[float] = None) -> int:
        """One token for every occupied slot; returns how many lanes
        decoded.  The step boundary is the gang's control point."""
        act = [i for i in range(self.slots) if self._reqs[i] is not None]
        if not act:
            return 0
        tel = telemetry.get()
        t_step = time.perf_counter() if tel.enabled else 0.0
        cur = np.asarray(self._cur)
        for i in act:
            r = self._reqs[i]
            if not r.out and now is not None:
                r.t_first = now
                if tel.enabled:
                    tel.observe("serve.ttft_s", now - r.arrival)
            r.out.append(int(cur[i]))
        pos = np.where(self._occ(), self._plen + self._t, 0)
        pos = jnp.asarray(pos[:, None].astype(np.int32))
        logits, self._states = self._serve(self.params, self._states,
                                           self._cur[:, None], pos)
        self._cur = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        for i in act:
            self._t[i] += 1
            if self._t[i] >= self._max_new[i]:
                r = self._reqs[i]
                if now is not None:
                    r.t_done = now
                    if tel.enabled and r.t_first is not None and r.out:
                        tel.observe("serve.per_token_s",
                                    (now - r.t_first)
                                    / max(1, len(r.out)))
                self._free(i)
        if tel.enabled:
            tel.count("serve.decoded_tokens", len(act))
            tel.gauge("serve.slot_occupancy", self.active / self.slots,
                      t=now)
            tel.span_at("serve.decode_step", t_step,
                        time.perf_counter(), track="serve",
                        clock="wall", lanes=len(act),
                        occupancy=self.active / self.slots)
        self.stats.decoded_tokens += len(act)
        self.stats.steps += 1
        return len(act)

    def run(self, requests: Sequence[Request]) -> List[Request]:
        """Closed-loop convenience: admit as capacity allows, decode to
        empty.  Open-loop drivers call admit/decode_step directly."""
        pending = list(requests)
        while pending or not self.done:
            while pending and self.admit(pending[0]) is not None:
                pending.pop(0)
            self.decode_step()
        return list(requests)

    # ---- serving state = the snapshot --------------------------------------
    def serve_state(self) -> Dict[str, Any]:
        st: Dict[str, Any] = {"params": self.params}
        if self._states is not None:
            occ = self._occ()
            st["states"] = self._states
            st["cur"] = self._cur
            # int32 bookkeeping: restore device_puts every leaf, and with
            # x64 disabled int64 would downcast and break the bit-exact
            # resume fingerprint
            st["slots"] = {
                "occ": occ.astype(np.int32),
                "plen": self._plen.copy(),
                "t": self._t.copy(),
                "max_new": self._max_new.copy(),
                "rids": np.asarray([r.rid if r is not None else -1
                                    for r in self._reqs], np.int32),
                "prompts": [np.asarray(r.prompt, np.int32) if r is not None
                            else np.zeros(0, np.int32)
                            for r in self._reqs],
                "outs": [np.asarray(r.out, np.int32) if r is not None
                         else np.zeros(0, np.int32) for r in self._reqs],
                "done_rids": np.asarray(self._done_rids, np.int32),
            }
        return st

    def load_serve_state(self, st: Dict[str, Any]) -> None:
        """Adopt a snapshot: device buffers verbatim plus the slot
        bookkeeping, reconstructing Request objects for every occupied
        lane.  Callers that own the original Request objects re-link
        them with ``adopt_requests`` (rolling their outputs back to the
        snapshot point — a restore after a hard fail must not keep
        post-checkpoint tokens)."""
        self.params = st["params"]
        if "states" not in st:
            # params-only snapshot (taken before the first admit): a
            # rollback to it restarts from an empty slot array — stale
            # in-flight lanes must not survive the restore
            self._states = None
            self._cur = None
            self._reqs = [None] * self.slots
            self._plen[:] = 0
            self._t[:] = 0
            self._max_new[:] = 0
            self._done_rids = []
        else:
            self._states = st["states"]
            self._cur = st["cur"]
            sl = st["slots"]
            occ = np.asarray(sl["occ"]).astype(bool)
            self._plen = np.asarray(sl["plen"]).copy()
            self._t = np.asarray(sl["t"]).copy()
            self._max_new = np.asarray(sl["max_new"]).copy()
            self._done_rids = [int(x) for x in np.asarray(sl["done_rids"])]
            self._reqs = [
                Request(rid=int(sl["rids"][i]),
                        prompt=np.asarray(sl["prompts"][i], np.int32),
                        max_new_tokens=int(sl["max_new"][i]),
                        out=[int(x) for x in np.asarray(sl["outs"][i])])
                if occ[i] else None
                for i in range(self.slots)]
        self._place()

    def adopt_requests(self, requests: Sequence[Request]) -> None:
        """Re-link caller-owned Request objects (matched by rid) into
        the freshly-restored slots, truncating their ``out`` lists to
        the snapshot's decoded prefix so generation resumes exactly."""
        by_rid = {r.rid: r for r in requests}
        for i, snap_req in enumerate(self._reqs):
            if snap_req is None:
                continue
            mine = by_rid.get(snap_req.rid)
            if mine is not None:
                mine.out[:] = list(snap_req.out)
                self._reqs[i] = mine
