"""Version-compat shims for jax APIs that moved between releases.

The repo targets the current jax API surface; older installs (e.g. the
0.4.x line baked into some images) expose the same functionality under
different names/keywords.  Every call site imports from here so the
divergence lives in exactly one file:

* ``shard_map``  — top-level ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old); the replication-check
  keyword was renamed ``check_rep`` -> ``check_vma``.
* ``make_mesh`` — the ``axis_types`` keyword (explicit-sharding API) does
  not exist on older releases; mesh axes there are implicitly Auto, which
  is exactly what every caller requests.
* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` (new) vs
  ``pltpu.TPUCompilerParams`` (old).
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence, Tuple

import jax

try:
    from jax import shard_map as _shard_map          # jax >= 0.6
except ImportError:                                   # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """``jax.shard_map`` with the replication-check keyword translated."""
    kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Tuple[Any, ...]] = None, **kw):
    """``jax.make_mesh`` tolerating the ``axis_types`` keyword.

    Older jax has no explicit-sharding axis types: axes are Auto, which
    matches the ``(AxisType.Auto,) * n`` every caller passes.
    """
    if _MAKE_MESH_HAS_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` on current jax; older releases expose the same
    number through the axis-env frame.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    from jax import core
    # axis_frame returns the size directly on some 0.4.x releases and an
    # AxisEnvFrame (with .size) on others
    frame = core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))


def tpu_compiler_params(**kwargs):
    """Construct Pallas-TPU compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
