"""Risk-aware placement + shrink-before-rollback (DESIGN.md §13).

Pillars:

* default-off bit-identity — a ``CostModel`` with no ``risk_tau_s`` and
  installed risk metadata never changes a decision (the risk-blind path
  is pinned action-for-action under churn, central and sharded);
* the risk term — short-lease and historically-flaky hosts are avoided
  by risk-sensitive kinds, weight-0 kinds keep the exact risk-blind
  placement, and the blast-group correlation counts one failure domain
  once;
* shrink-before-rollback — ``shrink_plan`` picks a shrink exactly when
  a fit exists, the simulator reshards stranded gangs instead of
  rolling them back (drain and hard-fail flavours), and a shrunk gang
  regrows to its submitted width once capacity returns;
* churn accounting properties — interleaved drain + hard-fail + join
  streams never leak or double-count chips in the central or sharded
  engine, with and without the risk/shrink machinery;
* drain-deadline retry schedule — deterministic capped-exponential
  backoff strictly inside the drain window.
"""
import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import simulator as S
from repro.core.elastic import shrink_worlds
from repro.core.fleet import (FleetController, FleetEvent,
                              HazardEstimator, blast_groups,
                              lease_expiries)
from repro.core.placement import (CostModel, PlacementEngine,
                                  ShardedPlacementEngine)


# ---------------------------------------------------------------------------
# default-off bit-identity
# ---------------------------------------------------------------------------
def test_risk_default_off_is_bit_identical_under_churn():
    jobs = S.mixed_trace(50, seed=9, arrival_rate=0.3,
                         priority_classes=[(0, 0.8), (5, 0.2)])
    events = F.churn_schedule("spot-heavy", 16, 8, 150.0, seed=3,
                              rate=0.03)
    for sched, shards in (("central", None), ("sharded", 4)):
        stock = S.Simulator(16, 8, "granular", migrate=True,
                            preempt=True, sched=sched,
                            shard_hosts=shards,
                            checkpoint_interval=8.0).run(
            list(jobs), fleet_events=events)
        # an explicit default CostModel carries risk_tau_s=None: the
        # engine must never build a RiskContext and every decision
        # stays on the risk-blind path
        off = S.Simulator(16, 8, "granular", migrate=True,
                          preempt=True, sched=sched,
                          shard_hosts=shards,
                          cost_model=CostModel(),
                          checkpoint_interval=8.0).run(
            list(jobs), fleet_events=events)
        assert off.actions == stock.actions
        assert off.makespan == stock.makespan
        assert off.shrinks == 0 and off.regrows == 0


def test_risk_metadata_without_opt_in_changes_nothing():
    # metadata installed but risk_tau_s unset: views carry risk=None
    eng = PlacementEngine(4, 8)
    eng.set_host_risk(lease_until_s=[5.0, np.inf, np.inf, np.inf],
                      hazards=[9.0, 0.0, 0.0, 0.0],
                      blast_groups=[0, 0, 2, 3])
    assert eng._risk_context() is None
    a = eng.allocate("a", 8)
    assert a is not None            # placement unaffected by metadata


# ---------------------------------------------------------------------------
# the risk term
# ---------------------------------------------------------------------------
def _risk_engine(lease=None, hazards=None, groups=None, hosts=4,
                 cph=8, policy="binpack", **cm_kwargs):
    cm_kwargs.setdefault("risk_tau_s", 20.0)
    eng = PlacementEngine(hosts, cph, policy=policy,
                          cost_model=CostModel(**cm_kwargs))
    eng.set_host_risk(lease_until_s=lease, hazards=hazards,
                      blast_groups=groups)
    return eng


def test_short_lease_host_avoided():
    # host 0's lease expires in 2s; an equal-capacity safe host exists
    eng = _risk_engine(lease=[2.0, np.inf, np.inf, np.inf])
    eng.risk_tick(0.0)
    a = eng.allocate("gang", 8, kind="mpi-compute")
    assert a is not None
    assert all(h != 0 for h, _ in a.placement)


def test_flaky_host_avoided_and_weight_zero_kind_ignores_risk():
    hazards = [0.5, 0.0, 0.0, 0.0]
    risky = _risk_engine(hazards=hazards)
    a = risky.allocate("gang", 8, kind="mpi-compute")
    assert all(h != 0 for h, _ in a.placement)
    # a weight-0 kind takes the exact risk-blind placement (binpack
    # ties break toward the highest index either way, so compare
    # against a genuinely risk-blind engine)
    blind = PlacementEngine(4, 8)
    soaker = _risk_engine(hazards=hazards,
                          risk_weights={"batch": 0.0})
    assert soaker.allocate("g", 8, kind="batch").placement \
        == blind.allocate("g", 8, kind="batch").placement


def test_blast_group_correlation_counts_domain_once():
    # hosts 0+1 share a failure domain at a high rate; hosts 2+3 are
    # independent at a moderate rate.  A 16-chip gang must span two
    # hosts: under the scored (locality) policy the correlated pair
    # contributes max() once, so it is cheaper than two independent
    # moderate hosts when 0.3 (one shared domain) < 0.2 + 0.2 (two)
    eng = _risk_engine(hazards=[0.3, 0.3, 0.2, 0.2],
                       groups=[0, 0, 2, 3], policy="locality")
    a = eng.allocate("gang", 16, kind="mpi-compute")
    assert {h for h, _ in a.placement} == {0, 1}
    # without the grouping the same rates pick the independent pair
    ung = _risk_engine(hazards=[0.3, 0.3, 0.2, 0.2],
                       groups=[0, 1, 2, 3], policy="locality")
    b = ung.allocate("gang", 16, kind="mpi-compute")
    assert {h for h, _ in b.placement} == {2, 3}


def test_risk_context_rates_combine_lease_and_hazard():
    cm = CostModel(risk_tau_s=10.0, risk_lease_floor_s=1.0)
    eng = PlacementEngine(3, 4, cost_model=cm)
    eng.set_host_risk(lease_until_s=[4.0, np.inf, 0.5],
                      hazards=[0.1, 0.2, 0.0])
    eng.risk_tick(2.0)
    ctx = eng._risk_context()
    rates = ctx.rates()
    # host 0: hazard + 1/(4-2); host 1: hazard only (inf lease -> 0);
    # host 2: lease already past -> floored at 1/risk_lease_floor_s
    assert rates[0] == pytest.approx(0.1 + 0.5)
    assert rates[1] == pytest.approx(0.2)
    assert rates[2] == pytest.approx(1.0)


def test_lease_and_blast_metadata_from_schedule():
    events = [FleetEvent(30.0, "reclaim", hosts=[1], drain_s=5.0),
              FleetEvent(40.0, "fail", hosts=[2, 3])]
    lease = lease_expiries(events, 5)
    assert lease[1] == 30.0                     # reclaim = lease term
    assert np.isinf(lease[2]) and np.isinf(lease[4])  # fails are not
    groups = blast_groups(events, 5)
    assert groups[2] == groups[3]               # co-failed -> one domain
    assert len({groups[0], groups[1], groups[2], groups[4]}) == 4


def test_hazard_estimator_learns_observed_failures():
    est = HazardEstimator(4, prior_events=0.25)
    r0 = est.rates(4, 10.0)
    assert np.allclose(r0, 0.25 / 10.0)         # uniform prior
    est.observe(FleetEvent(10.0, "fail", hosts=[1]))
    est.observe(FleetEvent(20.0, "reclaim", hosts=[1], drain_s=5.0))
    est.observe(FleetEvent(25.0, "join", capacities=[8]))  # not counted
    r = est.rates(4, 40.0)
    assert r[1] == pytest.approx(2.25 / 40.0)
    assert r[0] == pytest.approx(0.25 / 40.0)
    assert r[1] > r[0]
    # fleet growth: new hosts appear at the prior
    r5 = est.rates(5, 40.0)
    assert r5[4] == pytest.approx(0.25 / 40.0)


# ---------------------------------------------------------------------------
# shrink-before-rollback
# ---------------------------------------------------------------------------
def test_shrink_worlds_ladder():
    assert shrink_worlds(12) == [12, 8, 4]
    assert shrink_worlds(8) == [8, 4, 2]
    assert shrink_worlds(3) == [3, 2, 1]
    assert shrink_worlds(1) == [1]
    assert shrink_worlds(8, floor=1) == [8, 4, 2, 1]


def test_shrink_plan_picks_shrink_exactly_when_a_fit_exists():
    # 3 hosts x 4; a 8-chip gang spans two hosts, rest of the fleet
    # is full.  Draining both its hosts leaves 0 safe free chips: only
    # the gang's own safe chips (credit) can make a fit.
    eng = PlacementEngine(3, 4)
    g = eng.bind("g", [(0, 4), (1, 4)])
    eng.allocate("full", 4)                      # host 2
    eng.drain_hosts([0])
    # no credit, no free safe chips -> no world fits
    assert eng.shrink_plan(shrink_worlds(8)) is None
    # crediting the gang's safe host-1 chips fits the 4-world exactly
    keep = [(h, c) for h, c in g.placement if not eng.draining[h]]
    pl = eng.shrink_plan(shrink_worlds(8), credit=keep)
    assert pl is not None and sum(c for _, c in pl) == 4
    assert all(h == 1 for h, _ in pl)
    # a fit below the world floor is not taken: the ladder for 8 stops
    # at 2 (floor = n // 4), so a single surviving chip cannot host it
    eng2 = PlacementEngine(2, 8)
    eng2.bind("g", [(0, 7), (1, 1)])
    eng2.allocate("other", 7)                    # host 1 now full
    eng2.drain_hosts([0])
    keep2 = [(1, 1)]
    assert shrink_worlds(8) == [8, 4, 2]
    assert eng2.shrink_plan(shrink_worlds(8), credit=keep2) is None


def test_simulator_shrinks_on_drain_instead_of_rollback():
    # the gang spans both hosts; reclaiming host 1 leaves no room to
    # evacuate at full width but half-width fits on host 0
    jobs = [S.Job("g", "mpi-compute", 12, 480.0)]
    events = [FleetEvent(10.0, "reclaim", hosts=[1], drain_s=5.0)]
    blind = S.Simulator(2, 8, "granular", checkpoint_interval=5.0).run(
        list(jobs), fleet_events=list(events))
    assert blind.recoveries == 1                 # rollback without it
    r = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                    shrink_recovery=True).run(list(jobs),
                                              fleet_events=list(events))
    assert r.shrinks == 1 and r.recoveries == 0
    assert r.lost_work_s == 0.0                  # progress kept
    sh = next(a for a in r.actions if a.kind == "shrink")
    assert sh.payload["from"] == 12 and sh.payload["to"] == 8
    assert all(h == 0 for h, _ in sh.payload["placement"])
    assert len(r.finish_order) == 1


def test_simulator_shrinks_on_hard_fail_with_survivors():
    jobs = [S.Job("g", "mpi-compute", 12, 120.0)]
    events = [FleetEvent(10.0, "fail", hosts=[0])]
    r = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                    shrink_recovery=True).run(list(jobs),
                                              fleet_events=list(events))
    assert r.shrinks == 1 and r.recoveries == 0
    sh = next(a for a in r.actions if a.kind == "shrink")
    assert sh.payload["to"] == 8
    # no survivors (the whole gang died) -> checkpoint rollback stays
    whole = [S.Job("g", "mpi-compute", 8, 120.0)]
    r2 = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                     shrink_recovery=True).run(
        list(whole), fleet_events=[FleetEvent(10.0, "fail", hosts=[
            S.Simulator(2, 8, "granular").run(
                list(whole)).actions[0].payload["placement"][0][0]])])
    assert r2.shrinks == 0 and r2.recoveries == 1


def test_shrunk_gang_regrows_when_capacity_returns():
    jobs = [S.Job("g", "mpi-compute", 12, 300.0)]
    events = [FleetEvent(10.0, "fail", hosts=[0]),
              FleetEvent(20.0, "join", capacities=[8])]
    r = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                    shrink_recovery=True).run(list(jobs),
                                              fleet_events=list(events))
    assert r.shrinks == 1 and r.regrows == 1 and r.recoveries == 0
    rg = next(a for a in r.actions if a.kind == "regrow")
    assert rg.payload["from"] == 8 and rg.payload["to"] == 12
    assert rg.payload["t"] >= 20.0
    # the regrown gang finishes faster than one left shrunken: compare
    # against the same trace without the join
    stuck = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                        shrink_recovery=True).run(
        list(jobs), fleet_events=[FleetEvent(10.0, "fail", hosts=[0])])
    assert stuck.regrows == 0
    assert r.makespan < stuck.makespan


def test_rollback_after_shrink_requeues_full_width():
    # shrink at the first fail, then the surviving host dies too: the
    # recovery requeues the ORIGINAL job (full width) — shrink never
    # sticks past a rollback
    jobs = [S.Job("g", "mpi-compute", 12, 300.0)]
    events = [FleetEvent(10.0, "fail", hosts=[0]),
              FleetEvent(20.0, "fail", hosts=[1]),
              FleetEvent(25.0, "join", capacities=[8, 8])]
    r = S.Simulator(2, 8, "granular", checkpoint_interval=5.0,
                    shrink_recovery=True).run(list(jobs),
                                              fleet_events=list(events))
    assert r.shrinks == 1 and r.recoveries == 1
    resume = next(a for a in r.actions if a.kind == "resume")
    assert sum(c for _, c in resume.payload["placement"]) == 12
    assert len(r.finish_order) == 1


def test_shrink_gated_to_granular_mode():
    sim = S.Simulator(2, 8, "slices", slice_size=4,
                      shrink_recovery=True)
    assert sim.shrink_recovery is False
    assert S.Simulator(2, 8, "granular",
                       shrink_recovery=True).shrink_recovery is True


# ---------------------------------------------------------------------------
# churn accounting properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sched", ["central", "sharded"])
@pytest.mark.parametrize("trial", range(3))
def test_interleaved_churn_never_leaks_or_double_counts(sched, trial):
    # property-style: a random interleaving of drain + hard-fail + join
    # + allocate/bind/release/shrink keeps the free-chip ledger exact at
    # every step, in the central and sharded engines alike
    rng = np.random.default_rng([17, trial])
    if sched == "central":
        eng = PlacementEngine(8, 4, cost_model=CostModel(risk_tau_s=8.0))
    else:
        eng = ShardedPlacementEngine(8, 4, hosts_per_shard=2,
                                     cost_model=CostModel(
                                         risk_tau_s=8.0))
    eng.set_host_risk(hazards=np.zeros(8))
    allocs = {}
    for i in range(250):
        u = rng.random()
        if u < 0.30 and allocs:
            jid = sorted(allocs)[int(rng.integers(len(allocs)))]
            eng.release(allocs.pop(jid))
        elif u < 0.38 and eng.alive_hosts() > 4:
            cands = [h for h in range(eng.hosts)
                     if eng.capacities[h] > 0 and not eng.draining[h]]
            victim = int(cands[int(rng.integers(len(cands)))])
            if u < 0.34:
                for jid in eng.fail_hosts([victim]):
                    allocs.pop(jid)
            else:
                eng.drain_hosts([victim])
                # drain-flavour shrink for every stranded gang
                _, stranded = eng.evacuation_plan([victim])
                for jid in stranded:
                    al = allocs[jid]
                    keep = [(h, c) for h, c in al.placement
                            if not eng.draining[h]]
                    pl = eng.shrink_plan(shrink_worlds(al.n),
                                         credit=keep)
                    if pl is not None:
                        allocs[jid] = eng.apply_migration(al, pl)
        elif u < 0.44:
            joined = eng.add_hosts([int(rng.integers(1, 5))])
            assert all(not eng.draining[h] for h in joined)
        else:
            a = eng.allocate(f"j{i}", int(rng.integers(1, 9)))
            if a is not None:
                allocs[a.job_id] = a
        # the ledger invariants, checked after EVERY operation
        assert eng.idle_chips() == int(eng.free.sum())
        assert (eng.free >= 0).all()
        assert (eng.free <= eng.capacities).all()
        assert (eng.free[eng.draining] == 0).all()
        held = np.zeros(eng.hosts, dtype=np.int64)
        for al in allocs.values():
            for h, c in al.placement:
                held[h] += c
        assert (held + eng.free <= eng.capacities).all()
        live = ~eng.draining
        assert (held[live] + eng.free[live]
                == eng.capacities[live]).all()
        if sched == "sharded":
            for s, (lo, hi) in enumerate(eng.shard_bounds):
                assert eng._shard_idle[s] == eng.free[lo:hi].sum()
    for a in list(allocs.values()):
        eng.release(a)
    assert eng.idle_chips() == eng.total_chips


def test_simulated_interleaved_churn_conserves_jobs():
    # end-to-end: drains, fails and joins interleaved on one trace;
    # every job finishes exactly once, with and without risk + shrink
    jobs = S.mixed_trace(40, seed=21, arrival_rate=0.4)
    events = [FleetEvent(10.0, "reclaim", hosts=[3], drain_s=6.0),
              FleetEvent(12.0, "fail", hosts=[7]),
              FleetEvent(14.0, "join", capacities=[8]),
              FleetEvent(18.0, "reclaim", hosts=[5, 6], drain_s=4.0),
              FleetEvent(19.0, "fail", hosts=[0]),
              FleetEvent(30.0, "join", capacities=[8, 8, 8])]
    for cm, shrink in ((None, False),
                       (CostModel(risk_tau_s=8.0), True)):
        r = S.Simulator(8, 8, "granular", migrate=True,
                        cost_model=cm, checkpoint_interval=8.0,
                        shrink_recovery=shrink).run(
            list(jobs), fleet_events=list(events))
        assert sorted(r.finish_order) == sorted(j.job_id for j in jobs)
        assert len(r.finish_order) == len(set(r.finish_order))


# ---------------------------------------------------------------------------
# drain-deadline retry schedule
# ---------------------------------------------------------------------------
def test_retry_times_deterministic_backoff_inside_window():
    eng = PlacementEngine(4, 8)
    ctl = FleetController(eng)
    ev = FleetEvent(100.0, "reclaim", hosts=[1], drain_s=20.0)
    times = ctl.retry_times(ev, now=100.0)
    assert times == FleetController(PlacementEngine(4, 8)).retry_times(
        ev, now=100.0)                           # deterministic
    assert times and all(100.0 < t < 120.0 for t in times)
    assert times == sorted(times)
    gaps = np.diff([100.0] + times)
    # capped exponential: gaps grow (up to jitter) then plateau at the
    # cap; every gap stays within [base, cap * 1.25]
    assert gaps[0] >= ctl.retry_base_s
    assert max(gaps) <= ctl.retry_cap_s * 1.25 + 1e-9
    # a zero-length window schedules nothing
    assert ctl.retry_times(FleetEvent(5.0, "reclaim", hosts=[1],
                                      drain_s=0.0), now=5.0) == []


def test_retry_event_rescues_gang_mid_drain():
    # capacity frees up mid-drain (a short job finishes): the retry
    # pass evacuates the draining gang well before the deadline
    jobs = [S.Job("short", "mpi-compute", 8, 10.0),
            S.Job("long", "mpi-compute", 8, 400.0)]
    r = S.Simulator(2, 8, "granular").run(
        list(jobs), fleet_events=[FleetEvent(1.0, "reclaim",
                                             hosts=[0],
                                             drain_s=30.0)])
    assert r.evacuations == 1 and r.recoveries == 0
    ev = next(a for a in r.actions if a.kind == "evacuate")
    # rescued at a retry (after the ~11s finish), not at the 31s
    # deadline
    assert ev.payload["t"] < 31.0
