"""Checkpointing built on Granule snapshots (paper §3.4's fault-tolerance
sketch, implemented for real).

* **Full checkpoints**: the job-state snapshot serialised to disk
  (one ``.npz`` per checkpoint + a JSON manifest with step/fingerprint).
* **Incremental checkpoints**: chunk-diffs against the last full snapshot
  (``core.diffsync``) — the paper's byte-wise diff protocol as a
  checkpoint-size optimisation.  Restore = full + replay of diffs.
* **Async save**: serialisation happens on a background thread so the
  training loop only blocks for the device->host copy.
"""
from __future__ import annotations

import json
import os
import pickle
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core import diffsync, snapshot as snap_mod


class CheckpointManager:
    def __init__(self, directory: str, job_id: str = "job",
                 keep: int = 3, incremental_every: int = 0):
        """``incremental_every``: if > 0, only every k-th checkpoint is
        full; the rest are diffs against the last full one."""
        self.dir = directory
        self.job_id = job_id
        self.keep = keep
        self.incremental_every = incremental_every
        os.makedirs(directory, exist_ok=True)
        self._last_full: Optional[snap_mod.Snapshot] = None
        self._n_saved = 0
        self._pending: List[threading.Thread] = []
        self.stats: List[Dict[str, Any]] = []

    # ---- paths --------------------------------------------------------------
    def _path(self, step: int, kind: str) -> str:
        return os.path.join(self.dir, f"{self.job_id}-{step:08d}.{kind}")

    def _manifest_path(self) -> str:
        return os.path.join(self.dir, f"{self.job_id}-manifest.json")

    def _manifest(self) -> List[Dict[str, Any]]:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except FileNotFoundError:
            return []

    def _write_manifest(self, entries) -> None:
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(entries, f, indent=1)
        os.replace(tmp, self._manifest_path())

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = True) -> Dict[str, Any]:
        """Checkpoint the state pytree at ``step``."""
        t0 = time.time()
        snap = snap_mod.take(self.job_id, step, state)
        copy_s = time.time() - t0
        incremental = (self.incremental_every > 0
                       and self._last_full is not None
                       and self._n_saved % self.incremental_every != 0)

        if incremental:
            diffs = snap_mod.delta(self._last_full, state, op="overwrite")
            payload = {"kind": "diff", "base_step": self._last_full.step,
                       "diffs": diffs, "step": step,
                       "fingerprint": snap.fingerprint}
            path = self._path(step, "diff.pkl")
            nbytes = diffsync.diff_nbytes(diffs)
        else:
            payload = {"kind": "full", "state": snap.state, "step": step,
                       "fingerprint": snap.fingerprint}
            path = self._path(step, "full.pkl")
            nbytes = snap.nbytes
            self._last_full = snap
        self._n_saved += 1

        def _write():
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump(payload, f, protocol=4)
            os.replace(tmp, path)
            entries = self._manifest()
            entries.append({"step": step, "path": path,
                            "kind": payload["kind"],
                            "fingerprint": snap.fingerprint,
                            "nbytes": nbytes})
            self._write_manifest(entries)
            self._gc(entries)

        if blocking:
            _write()
        else:
            t = threading.Thread(target=_write, daemon=True)
            t.start()
            self._pending.append(t)
        stat = {"step": step, "bytes": nbytes, "incremental": incremental,
                "device_to_host_s": copy_s}
        self.stats.append(stat)
        return stat

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _gc(self, entries) -> None:
        """Keep the last ``keep`` full checkpoints + diffs newer than the
        oldest kept full one."""
        fulls = [e for e in entries if e["kind"] == "full"]
        if len(fulls) <= self.keep:
            return
        cutoff = fulls[-self.keep]["step"]
        kept, dropped = [], []
        for e in entries:
            (kept if e["step"] >= cutoff else dropped).append(e)
        for e in dropped:
            try:
                os.remove(e["path"])
            except FileNotFoundError:
                pass
        self._write_manifest(kept)

    # ---- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        entries = self._manifest()
        return entries[-1]["step"] if entries else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load state at ``step`` (default: latest).  Diff checkpoints are
        replayed on top of their base full checkpoint."""
        self.wait()
        entries = self._manifest()
        if not entries:
            raise FileNotFoundError("no checkpoints")
        if step is None:
            entry = entries[-1]
        else:
            entry = next(e for e in entries if e["step"] == step)
        with open(entry["path"], "rb") as f:
            payload = pickle.load(f)
        if payload["kind"] == "full":
            state = payload["state"]
        else:
            base = next(e for e in entries
                        if e["kind"] == "full"
                        and e["step"] == payload["base_step"])
            with open(base["path"], "rb") as f:
                base_payload = pickle.load(f)
            state = diffsync.apply_tree(base_payload["state"],
                                        payload["diffs"])
        snap = snap_mod.Snapshot(self.job_id, payload["step"], state,
                                 fingerprint=payload["fingerprint"])
        restored = snap_mod.restore(snap, shardings)
        return restored, payload["step"]
