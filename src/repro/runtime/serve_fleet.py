"""Virtual serve fleet: the SLO-autoscaling control loop at cluster
scale, in deterministic virtual time.

``ServeFleetSim`` runs the full serving control plane — open-loop
arrivals → ``AdmissionQueue`` → slot-granular virtual serve gangs →
``ServeAutoscaler`` grow/shrink/clone through a real
``PlacementEngine`` — without touching jax, so benchmarks can sweep
offered load and fleet sizes cheaply and the latency/SLO numbers are
exactly reproducible.  Gang capacity comes from
``CostModel.token_latency`` on the gang's *actual placement* (slowest
chip paces the decode step, cross-host slowdown charged per token), so
scaling decisions see the same physics placements are scored with.

``VirtualTrainTenant`` models the elastic training neighbour for the
combined train+serve story: when a serve spike needs chips the tenant
*drains* — it shrinks at its next control point, keeping every unit of
progress — instead of dying (preemption rolls back to the last
checkpoint).  When serve scales back in, the tenant grows again and
backfills the idle chips.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.elastic import ElasticPolicy
from repro.core.placement import CostModel, PlacementEngine
from repro.runtime.admission import (AdmissionQueue, LatencyWindow,
                                     ScaleAction, ServeAutoscaler, ServeSLO)
from repro.runtime.serve_loop import Request


class VirtualServeGang:
    """Slot-level capacity model of one continuous-batching serve gang:
    ``world * slots_per_chip`` slots, one decode step (a token for every
    occupied slot) every ``token_s`` of virtual time."""

    def __init__(self, gang_id: str, world: int, placement,
                 token_s: float, slots_per_chip: int = 1):
        self.gang_id = gang_id
        self.world = world
        self.placement = placement
        self.token_s = token_s
        self.slots_per_chip = slots_per_chip
        self.slots: List[Optional[Tuple[Request, int]]] = \
            [None] * (world * slots_per_chip)
        self.retiring = False
        self._credit = 0.0

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def free_slots(self) -> int:
        if self.retiring:
            return 0
        return len(self.slots) - self.active

    def resize(self, world: int, placement, token_s: float) -> None:
        """Adopt a rescaled placement.  Shrinking never drops an
        in-flight request: occupied lanes above the new capacity drain
        at the new (smaller) gang's pace and their slots retire as they
        free — the continuous engine's drain semantics."""
        self.world = world
        self.placement = placement
        self.token_s = token_s
        want = world * self.slots_per_chip
        occupied = [s for s in self.slots if s is not None]
        free = max(0, want - len(occupied))
        self.slots = occupied + [None] * free

    def admit(self, req: Request, now: float) -> bool:
        for i, s in enumerate(self.slots):
            if s is None and not self.retiring:
                self.slots[i] = (req, req.max_new_tokens)
                req.t_admit = now
                return True
        return False

    def advance(self, dt: float, now: float, queue: AdmissionQueue,
                window: LatencyWindow,
                finished: List[Request]) -> int:
        """Accumulate ``dt`` of decode credit; each whole step decodes
        every occupied lane one token and backfills freed lanes from
        the queue.  Returns tokens decoded."""
        if self.active == 0:
            self._credit = 0.0
            while queue.depth() and self.free_slots:
                self.admit(queue.pop(), now)
            if self.active == 0:
                return 0
        self._credit += dt / self.token_s
        decoded = 0
        while self._credit >= 1.0:
            self._credit -= 1.0
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                req, rem = s
                if req.t_first is None:
                    req.t_first = now
                req.out.append(0)
                decoded += 1
                rem -= 1
                if rem == 0:
                    req.t_done = now
                    window.record(req)
                    finished.append(req)
                    self.slots[i] = None
                else:
                    self.slots[i] = (req, rem)
            while queue.depth() and self.free_slots:
                self.admit(queue.pop(), now)
            if self.active == 0:
                break
        return decoded


class VirtualTrainTenant:
    """Elastic training neighbour sharing the fleet with serve gangs.

    Progress accrues in chip-seconds of effective parallelism.  A serve
    spike asks for chips via ``drain_to`` — the graceful path: the
    tenant shrinks at its control point with zero lost work.  The
    contrast mode ``preempt`` (kill) rolls progress back to the last
    checkpoint, measuring exactly what drain-not-die saves."""

    def __init__(self, job_id: str, engine: PlacementEngine, world: int,
                 min_world: int = 2, kind: str = "mpi-compute",
                 ckpt_interval_s: float = 8.0):
        self.job_id = job_id
        self.engine = engine
        self.kind = kind
        self.min_world = min_world
        self.max_world = world
        self.ckpt_interval_s = ckpt_interval_s
        self.alloc = engine.allocate(job_id, world, kind=kind)
        assert self.alloc is not None, "train tenant must place at t=0"
        self.progress = 0.0
        self.lost_work = 0.0
        self.backfilled_chip_s = 0.0
        self.last_ckpt_t = 0.0
        self.shrink_events: List[Tuple[float, int, int]] = []

    @property
    def world(self) -> int:
        return 0 if self.alloc is None else self.alloc.n

    def _rate(self) -> float:
        if self.alloc is None:
            return 0.0
        cm = self.engine.cost_model
        eff = cm.effective_parallelism(self.alloc.placement,
                                       self.engine.speeds)
        return eff / cm.slowdown(self.alloc.placement, self.kind)

    def advance(self, dt: float, now: float) -> None:
        self.progress += self._rate() * dt
        if now - self.last_ckpt_t >= self.ckpt_interval_s:
            self.last_ckpt_t = now

    def _reshape(self, new_world: int) -> bool:
        old = self.alloc
        self.engine.release(old)
        alloc = self.engine.allocate(self.job_id, new_world,
                                     kind=self.kind)
        if alloc is None:                       # revert, keep running
            self.alloc = self.engine.allocate(self.job_id, old.n,
                                              kind=self.kind)
            assert self.alloc is not None
            return False
        self.alloc = alloc
        return True

    def drain_to(self, now: float, new_world: int) -> bool:
        """Graceful shrink at a control point: every step so far is
        kept — the victim drains, it does not die."""
        new_world = max(self.min_world, new_world)
        if self.alloc is None or new_world >= self.world:
            return False
        old_world = self.world
        if self._reshape(new_world):
            self.shrink_events.append((now, old_world, new_world))
            return True
        return False

    def preempt(self, now: float, new_world: int) -> bool:
        """Kill-mode contrast: same chips freed, but progress since the
        last checkpoint is lost (what a non-draining preemption costs)."""
        rolled = (now - self.last_ckpt_t) * self._rate()
        if self.drain_to(now, new_world):
            self.progress -= rolled
            self.lost_work += rolled
            return True
        return False

    def try_backfill(self, now: float, policy: ElasticPolicy) -> bool:
        """Grow back into idle chips (the slack serve released)."""
        if self.alloc is None or self.world >= self.max_world:
            return False
        new = policy.decide_scaled(self.world, self.engine, 2.0,
                                   kind=self.kind)
        if new is None or new > self.max_world or new <= self.world:
            return False
        old_world = self.world
        if self._reshape(new):
            self.backfilled_chip_s += (new - old_world) * 1.0
            return True
        return False


@dataclasses.dataclass
class FleetReport:
    finished: int
    decoded_tokens: int
    elapsed_s: float
    tokens_per_s: float
    token_lat_p50: float
    token_lat_p99: float
    slo_target_s: float
    slo_attainment: float           # fraction of requests meeting target
    peak_world: int
    min_world: int
    n_actions: int
    grew: int
    shrank: int
    cloned: int
    timeline: List[Tuple[float, int, int, float]]  # (t, world, qdepth, p99)
    train_progress: float = 0.0
    train_lost_work: float = 0.0
    train_min_world: int = 0
    train_backfilled: float = 0.0


class ServeFleetSim:
    """Deterministic virtual-time fleet: open-loop arrivals feed serve
    gangs whose capacity the ``ServeAutoscaler`` manages through a real
    ``PlacementEngine``; optionally an elastic ``VirtualTrainTenant``
    contends for the same chips (drain-not-die on serve spikes,
    backfill on lulls)."""

    def __init__(self, hosts: int = 4, chips_per_host: int = 8,
                 cost_model: Optional[CostModel] = None,
                 policy: str = "binpack",
                 speeds: Optional[Sequence[float]] = None,
                 slo: Optional[ServeSLO] = None,
                 base_world: int = 2, min_world: int = 1,
                 max_world: int = 16, slots_per_chip: int = 1,
                 target_free: int = 0, cooldown_s: float = 2.0,
                 control_interval_s: float = 1.0, kind: str = "omp"):
        self.cost_model = cost_model or CostModel()
        self.engine = PlacementEngine(hosts, chips_per_host, policy=policy,
                                      speeds=speeds,
                                      cost_model=self.cost_model)
        self.policy = ElasticPolicy(min_world=min_world,
                                    max_world=max_world,
                                    target_free=target_free)
        self.slo = slo or ServeSLO()
        self.scaler = ServeAutoscaler(self.policy, self.engine,
                                      slo=self.slo,
                                      slots_per_chip=slots_per_chip,
                                      base_world=base_world,
                                      cooldown_s=cooldown_s, kind=kind)
        self.slots_per_chip = slots_per_chip
        self.base_world = base_world
        self.kind = kind
        self.control_interval_s = control_interval_s
        self.gangs: Dict[str, VirtualServeGang] = {}
        self.allocs: Dict[str, object] = {}
        self._next_gang = 0

    # ---- gang lifecycle through the engine ---------------------------------
    def _token_s(self, placement) -> float:
        return self.cost_model.token_latency(placement, self.kind,
                                             self.engine.speeds)

    def spawn_gang(self, world: int) -> Optional[VirtualServeGang]:
        gid = f"serve-{self._next_gang}"
        alloc = self.engine.allocate(gid, world, kind=self.kind)
        if alloc is None:
            return None
        self._next_gang += 1
        gang = VirtualServeGang(gid, alloc.n, alloc.placement,
                                self._token_s(alloc.placement),
                                self.slots_per_chip)
        self.gangs[gid] = gang
        self.allocs[gid] = alloc
        return gang

    def _rescale(self, gid: str, world: int) -> bool:
        gang, alloc = self.gangs[gid], self.allocs[gid]
        self.engine.release(alloc)
        new = self.engine.allocate(gid, world, kind=self.kind)
        if new is None:                          # revert
            self.allocs[gid] = self.engine.allocate(gid, alloc.n,
                                                    kind=self.kind)
            assert self.allocs[gid] is not None
            return False
        self.allocs[gid] = new
        gang.resize(new.n, new.placement, self._token_s(new.placement))
        return True

    def _retire(self, gid: str) -> None:
        gang = self.gangs[gid]
        gang.retiring = True
        if gang.active == 0:
            self.engine.release(self.allocs.pop(gid))
            del self.gangs[gid]

    def apply(self, act: ScaleAction) -> None:
        if act.kind == "clone":
            self.spawn_gang(act.world)
        elif act.kind == "grow":
            self._rescale(act.gang_id, act.world)
        elif act.kind == "shrink":
            if act.world <= 0:
                self._retire(act.gang_id)
            else:
                self._rescale(act.gang_id, act.world)

    # ---- the run loop ------------------------------------------------------
    def run(self, requests: Sequence[Request],
            train: Optional[VirtualTrainTenant] = None,
            train_mode: str = "drain",
            tick_s: float = 0.05) -> FleetReport:
        """Replay ``requests`` (arrival-stamped) to completion.  With a
        ``train`` tenant, a failed serve grow/clone asks the tenant for
        chips first (``train_mode``: "drain" keeps its progress,
        "preempt" rolls it back), and every comfortable control tick
        offers idle chips back (backfill)."""
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if not self.gangs:
            gang = self.spawn_gang(self.base_world)
            assert gang is not None, "fleet too small for base gang"
        queue = AdmissionQueue()
        window = LatencyWindow()
        finished: List[Request] = []
        now, i, decoded = 0.0, 0, 0
        next_control = 0.0
        timeline: List[Tuple[float, int, int, float]] = []
        peak_w, min_w = 0, 10 ** 9
        train_min_world = train.world if train is not None else 0
        grew = shrank = cloned = 0
        while i < len(reqs) or queue.depth() \
                or any(g.active for g in self.gangs.values()):
            now = round(now + tick_s, 9)
            while i < len(reqs) and reqs[i].arrival <= now:
                queue.push(reqs[i])
                i += 1
            for gid in sorted(self.gangs):
                decoded += self.gangs[gid].advance(tick_s, now, queue,
                                                   window, finished)
            if train is not None:
                train.advance(tick_s, now)
            for gid in [g for g, gang in self.gangs.items()
                        if gang.retiring and gang.active == 0]:
                self.engine.release(self.allocs.pop(gid))
                del self.gangs[gid]
            if now >= next_control:
                next_control = now + self.control_interval_s
                worlds = {g: gang.world
                          for g, gang in self.gangs.items()
                          if not gang.retiring}
                acts = self.scaler.decide(now, queue.depth(),
                                          window.p99, worlds)
                for act in acts:
                    if act.kind == "need":
                        # pool exhausted: reclaim chips from the
                        # elastic training tenant, then retry the grow.
                        # "drain" keeps the tenant's progress (it
                        # shrinks at this control point); "preempt" is
                        # the kill-mode contrast that rolls it back.
                        if train is None:
                            continue
                        want = max(train.min_world, train.world // 2)
                        gave = (train.drain_to(now, want)
                                if train_mode == "drain"
                                else train.preempt(now, want))
                        if gave and act.gang_id in self.gangs \
                                and self._rescale(act.gang_id,
                                                  act.world):
                            grew += 1
                        continue
                    before = len(self.gangs)
                    chips = sum(g.world for g in self.gangs.values())
                    self.apply(act)
                    after_chips = sum(g.world
                                      for g in self.gangs.values())
                    if act.kind == "clone" and len(self.gangs) > before:
                        cloned += 1
                    elif act.kind == "grow" and after_chips > chips:
                        grew += 1
                    elif act.kind == "shrink":
                        shrank += 1
                if train is not None:
                    train_min_world = min(train_min_world, train.world)
                    if not acts:
                        train.try_backfill(now, self.policy)
                total_world = sum(g.world for g in self.gangs.values())
                peak_w = max(peak_w, total_world)
                min_w = min(min_w, total_world)
                timeline.append((now, total_world, queue.depth(),
                                 window.p99 or 0.0))
        elapsed = max(now, 1e-9)
        done = [r for r in reqs if r.t_done is not None and r.out]
        lat = np.asarray([(r.t_done - r.arrival) / len(r.out)
                          for r in done]) if done else np.asarray([0.0])
        attain = float(np.mean(lat <= self.slo.target_p99_s)) \
            if done else 0.0
        return FleetReport(
            finished=len(done), decoded_tokens=decoded,
            elapsed_s=elapsed,
            tokens_per_s=decoded / elapsed,
            token_lat_p50=float(np.percentile(lat, 50)),
            token_lat_p99=float(np.percentile(lat, 99)),
            slo_target_s=self.slo.target_p99_s,
            slo_attainment=attain,
            peak_world=peak_w, min_world=min_w,
            n_actions=len(self.scaler.actions),
            grew=grew, shrank=shrank, cloned=cloned,
            timeline=timeline,
            train_progress=train.progress if train else 0.0,
            train_lost_work=train.lost_work if train else 0.0,
            train_min_world=train_min_world,
            train_backfilled=train.backfilled_chip_s if train else 0.0)
