"""FlashAttention-2 for TPU (Pallas): fused causal GQA attention.

TPU adaptation of the FA2 algorithm (DESIGN.md §6): the online-softmax
recurrence runs over KV blocks streamed HBM->VMEM; per-(batch, head,
q-block) running max / denominator / f32 accumulator live in VMEM scratch
that persists across the sequential k-block grid dimension.  Block shapes
are MXU-aligned (128x128 tiles); the attention matrix never touches HBM —
this removes the O(S^2) logits traffic that makes the reference path
memory-bound in the roofline analysis.

Grid: (B, H, S/bq, S/bk) with the last dimension sequential ("arbitrary"),
so scratch carries across k-blocks.  Causal/window masking happens
block-wise: fully-masked blocks are skipped via the index bounds, the
diagonal block applies an elementwise mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int,
               block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level skip: causal => k block cannot start after q block end;
    # window => k block cannot end before the window's left edge
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "scale", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    scale: float = 0.0,
                    interpret: bool = False):
    """q: (B, H, S, hd);  k, v: (B, KV, S, hd) with KV | H.

    Returns (B, H, S, hd).  GQA is expressed in the k/v index maps: head h
    reads kv head h // (H // KV).
    """
    b, h, s, hd = q.shape
    kv = k.shape[1]
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = scale or hd ** -0.5
    grid = (b, h, s // block_q, s // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki,
                                                          0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki,
                                                          0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
