"""Barrier-point migration of job state between device sets (paper §3.3).

The paper migrates a Granule by snapshotting its linear memory and restoring
it on the target VM.  The JAX adaptation: at a step-boundary control point
(a barrier — no in-flight collectives), snapshot the job-state pytree and
``jax.device_put`` it onto the new sub-mesh's shardings.  Two paths:

* ``migrate_via_snapshot`` — through host memory (cross-pod moves; the
  paper's snapshot-transfer path).  Supports *delta* migration: if the
  target already holds an older snapshot of the job (it ran there before),
  only chunk diffs travel (paper §4.1's diff protocol applied to moves).
* ``migrate_live``          — direct device-to-device resharding for
  intra-fabric moves (ICI transfer, no host hop).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core import diffsync, snapshot as snap_mod


def migrate_via_snapshot(job_id: str, step: int, state,
                         dst_shardings=None,
                         prior: Optional[snap_mod.Snapshot] = None
                         ) -> Tuple[Any, Dict[str, Any]]:
    """Snapshot -> (optional delta against prior) -> restore on target.

    Returns (new_state, stats).  ``prior``: snapshot of this job already
    resident at the target (delta migration).
    """
    t0 = time.time()
    snap = snap_mod.take(job_id, step, state)
    full_bytes = snap.nbytes
    moved_bytes = full_bytes
    if prior is not None and prior.job_id == job_id:
        diffs = diffsync.diff_tree(prior.state, snap.state, op="overwrite")
        moved_bytes = diffsync.diff_nbytes(diffs)
        snap = snap_mod.apply_delta(prior, diffs, step)
    new_state = snap_mod.restore(snap, dst_shardings)
    return new_state, {
        "full_bytes": full_bytes,
        "moved_bytes": moved_bytes,
        "delta": prior is not None,
        "seconds": time.time() - t0,
        "fingerprint": snap.fingerprint,
    }


def migrate_live(state, dst_shardings):
    """Direct device-to-device resharding (no host round-trip)."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state,
                        dst_shardings)


def verify_migration(before, after) -> bool:
    """Bit-exact check (paper's correctness requirement for migration)."""
    a = snap_mod.take("verify", 0, before, fingerprint=True)
    b = snap_mod.take("verify", 0, after, fingerprint=True)
    return a.fingerprint == b.fingerprint
