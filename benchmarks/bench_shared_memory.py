"""Paper Fig 12 (shared-memory/DGEMM overhead) — TPU adaptation.

The paper measures Faabric's distributed-shared-memory overhead on OpenMP
DGEMM.  Our analogue measures the cost of the diff-sync protocol itself on
training-state-sized buffers:

  * chunk-diff throughput (detect dirty chunks against a snapshot),
  * merge-op apply throughput (all five Table-3 ops),
  * end-to-end "parallel section": N workers fork from a snapshot, write
    disjoint slices, diffs merge back — vs a direct in-place update,
  * diff size vs write density (the protocol's bandwidth win).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import diffsync as D


def _timeit(fn, reps=3):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(report, tiny=False):
    rng = np.random.default_rng(0)
    mb = 4 if tiny else 64
    base = rng.normal(size=mb * 2 ** 20 // 4).astype(np.float32)

    # dirty-chunk detection throughput (clustered writes: a contiguous 1%
    # slice — scattered single-element writes would dirty every page/chunk,
    # exactly as in the paper's page-granular tracking)
    child = base.copy()
    start = base.size // 3
    child[start:start + base.size // 100] += 1.0
    t = _timeit(lambda: D.diff_leaf(base, child))
    report("diff_detect_throughput", round(mb / t / 1024, 2), "GiB/s",
           "Fig12 analogue: dirty tracking cost")

    d = D.diff_leaf(base, child, op="sum")
    t = _timeit(lambda: D.apply_leaf(base, d))
    report("merge_apply_throughput", round(mb / t / 1024, 2), "GiB/s",
           "Fig12 analogue: merge cost")
    report("diff_fraction_1pct_writes",
           round(d.nbytes / base.nbytes, 4), "of full state",
           "diff protocol bandwidth win")

    # write-density sweep: diff bytes vs densities (contiguous writes)
    for density in (0.001, 0.01, 0.1, 0.5):
        child = base.copy()
        k = max(1, int(base.size * density))
        child[:k] += 1.0
        dd = D.diff_leaf(base, child)
        report(f"diff_bytes_density_{density}",
               round(dd.nbytes / base.nbytes, 4), "of full state",
               "byte-wise diff scaling")

    # "parallel section": 4 workers write disjoint slices, merge back
    workers = 4
    quarter = base.size // workers

    def parallel_section():
        merged = base
        for w in range(workers):
            child = base.copy()
            child[w * quarter:(w + 1) * quarter] *= 1.01
            merged = D.apply_leaf(merged,
                                  D.diff_leaf(base, child, op="overwrite"))
        return merged

    t_sync = _timeit(parallel_section)

    def direct():
        out = base.copy()
        out *= 1.01
        return out

    t_direct = _timeit(direct)
    report("parallel_section_overhead", round(t_sync / t_direct, 2),
           "x direct update",
           "Fig12: paper reports 20-30% WASM overhead; ours is diff-sync")
    # correctness of the merged result
    expect = base * 1.01
    got = parallel_section()
    report("parallel_section_exact",
           int(np.allclose(got, expect, rtol=1e-6)), "bool", "")
