"""Discrete-event simulator of job traces on a shared cluster (paper §6).

The paper's evaluation is a *scheduling-policy* experiment: 100-job traces
of MPI (LAMMPS) and OpenMP (DGEMM) jobs on 32 8-vCPU VMs, comparing
Faabric's chip-granular Granule scheduling (+ barrier-point migration)
against fixed-slice container baselines.  That experiment is hardware-
independent given a job-time model; we reproduce it with a model calibrated
from the paper's own microbenchmarks:

* job time: the shared ``core.placement.CostModel``
  T = (W / sum_h n_h*s_h) * (1 + beta_kind * chi), with chi the cross-host
  pair fraction of the gang placement
  (``Allocation.cross_host_fraction``), per-host speed factors ``s_h``
  (mixed host generations — ``hetero_speeds`` builds the half-the-fleet-
  at-s=0.5 regime), and per-job-kind beta calibrated from Fig 14:
  compute-bound LAMMPS co-located vs 4+4-fragmented = 1.2x  -> beta = 0.4;
  network-bound all-to-all = 7.5x -> beta = 13.0.  The same model scores
  policy candidates, costs migration plans, and integrates job rates, so
  placement and execution agree by construction.
* runtime overhead: Faabric's shared-memory (OpenMP) jobs carry a 1.25x
  execution-time factor (paper §6.4: 20–30% WASM floating-point overhead).
* migration: at barrier control points a fragmented gang may be
  consolidated; cost = snapshot transfer (Fig 14: worth it except >80%
  progress for compute-bound jobs).
* centralised-scheduler latency: a per-decision cost proportional to the
  host count one decision scans, charged once per scheduling pass
  (reproduces the 128-VM degradation of Fig 11).  ``sched="sharded"``
  runs the ``ShardedPlacementEngine``: a decision scans one host-group
  shard (``SCHED_LATENCY_PER_HOST * hosts_per_shard``) plus
  ``SCHED_FORWARD_HOP_S`` per shard the summary index forwarded it to —
  the decentralised fix the paper leaves open.

Every placement goes through ``core.placement.PlacementEngine`` — the same
code path the live runtime uses — under a selectable policy (binpack /
spread / locality for granular mode; fixed-slice for the baselines).

Beyond the paper's all-jobs-at-t=0 FIFO replay, traces carry per-job
**arrival times** (e.g. Poisson arrivals) and **priority classes**; the
queue is ordered (priority desc, arrival, submission), and optional
**backfill** lets queued jobs jump past a blocked head-of-line job — the
shared-cluster, multi-tenant economics of §2.1.  With all arrivals at t=0,
uniform priority, and backfill off, the event loop is exactly the paper's
FIFO experiment.  ``preempt`` adds rFaaS-style lease reclamation: a
high-priority arrival that cannot be placed evicts lower-priority gangs
(``PlacementEngine.preemption_plan``) — the victim is checkpointed
(progress survives), requeued, and pays a snapshot restore cost when it
resumes.

Scheduling decisions are logged as ``core.control.Action`` records —
the same action vocabulary (checkpoint / migrate / rescale / preempt /
start / finish) the live runtime's control points consume, so a simulated
trace and a ``core.fabric.Fabric.run_trace`` execution of the same trace
can be diffed event-by-event.

**Fleet churn** (``core.fleet``): ``run(jobs, fleet_events=...)``
interleaves host joins, lease reclaims (drain for ``drain_s``, then the
host dies) and hard failures with the arrival trace.  Gangs on a
draining host evacuate through the shared evacuation planner (charged
like a migration); gangs on a failed host are requeued from their last
checkpoint — ``checkpoint_interval`` adds a periodic checkpoint cadence
(each costs ``CostModel.checkpoint_cost_s``), and the work since the
last checkpoint is counted in ``TraceResult.lost_work_s`` (the
Young/Daly cadence-vs-lost-work tradeoff of ``bench_churn``).  With no
churn schedule and no checkpoint interval the event loop is
bit-identical to the pre-churn simulator (pinned).

**Risk-aware churn** (DESIGN.md §13): when the engine's ``CostModel``
carries ``risk_tau_s`` the event loop feeds the placement layer's risk
metadata — per-host lease expiries and blast groups read off the churn
schedule at trace start (the contractual part a provider publishes),
plus an online ``fleet.HazardEstimator`` updated at every applied
fleet event — so every policy decision sees the same leases/hazards
the live runtime would.  ``shrink_recovery=True`` adds
shrink-before-rollback: a gang stranded by a drain reshards onto
surviving capacity at a smaller power-of-two world
(``elastic.shrink_worlds``) while its chips are still alive, retried
on the drain's backoff schedule (``FleetController.retry_times``)
through the deadline; a gang stranded by a hard fail shrinks onto the
survivors when it kept at least one live replica chip.  Only when no
shrink world fits does the checkpoint-rollback path run.  Both knobs
default off and the default paths stay bit-identical (pinned).

The event loop exposes overridable hooks (``_on_start`` / ``_on_advance``
/ ``_on_preempt`` / ``_on_migrate`` / ``_on_finish`` and the churn hooks
``_on_join`` / ``_on_drain`` / ``_on_hosts_down`` / ``_on_checkpoint``
/ ``_on_fail``) that are no-ops here; ``core.fabric`` subclasses them
to execute the trace against real gangs while virtual time drives
scheduling.

The simulator is deterministic given a seed.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import placement as placement_mod
from repro.core import telemetry
from repro.core.control import Action
from repro.core.fleet import (FleetController, FleetEvent,
                              HazardEstimator, blast_groups,
                              lease_expiries)
from repro.core.placement import (DEFAULT_SHARD_HOSTS, Allocation,
                                  CostModel, FixedSlicePolicy,
                                  PlacementEngine, PlacementPolicy,
                                  PreemptPolicy, ShardedPlacementEngine,
                                  resolve_policy)

# Fig 14 calibration now lives on core.placement.CostModel (one model for
# policies, simulator, and the live fabric); kept as a read-only copy for
# callers that still read the table directly (mutating it has no effect —
# recalibrate via CostModel(betas=...) instead).
BETA = dict(CostModel.DEFAULT_BETAS)
WASM_OVERHEAD_OMP = 1.25          # paper §6.4
OVERCOMMIT_PENALTY = 1.5          # threads > vCPUs in one container (§6.2)
# Default calibration of CostModel.migration_cost_s / preempt_cost_s;
# the event loop charges whatever the engine's model carries, so a
# custom model keeps the plan filter and the simulated charge in sync.
MIGRATION_COST_S = 2.0            # snapshot transfer at a barrier point
PREEMPT_COST_S = 2.0              # snapshot restore when a victim resumes
SCHED_LATENCY_PER_HOST = 0.004    # centralised scheduler cost (Fig 11)
# sharded scheduling (the Fig 11 fix): one decision scans one shard
# (SCHED_LATENCY_PER_HOST * hosts_per_shard) and pays this much per
# forwarding hop — a summary-index lookup + RPC to a peer shard, far
# cheaper than scanning the peer's hosts
SCHED_FORWARD_HOP_S = 0.002


@dataclasses.dataclass
class Job:
    job_id: str
    kind: str                     # mpi-compute | mpi-network | omp
    parallelism: int              # MPI world size / OMP_NUM_THREADS
    work: float                   # chip-seconds at perfect scaling
    arrival: float = 0.0          # submission time (0 = paper's replay)
    priority: int = 0             # higher runs first
    workload: str = ""            # live-execution payload: train | serve


@dataclasses.dataclass
class RunningJob:
    job: Job
    alloc: Allocation
    start: float
    progress: float = 0.0         # fraction of work done
    last_update: float = 0.0
    eff_parallelism: int = 0
    finish_event: int = -1        # heap token (lazy deletion)
    model: CostModel = dataclasses.field(default_factory=CostModel)
    speeds: Optional[np.ndarray] = None      # engine's per-host factors
    _rate: Optional[float] = None            # cache; placement-invariant
    # fleet churn: progress captured by the last checkpoint (what a
    # hard host failure rolls back to) and its heap token
    ckpt_progress: float = 0.0
    ckpt_event: int = -1
    # periodic checkpoints taken this run segment (index 0 = the
    # baseline at start): drives CostModel.checkpoint_cost's full-vs-
    # delta charging, reset by requeue so live GangHandle chains (which
    # rebase on fail/resume) and the simulator stay in lockstep
    ckpt_count: int = 0
    # shrink-before-rollback: the gang's current DP world when it has
    # been resharded below the submitted parallelism (None = full
    # width); a later rollback requeues the *original* Job, so shrink
    # never sticks past a recovery
    world: Optional[int] = None

    def rate(self) -> float:
        """Fraction of work per second under the current placement —
        the CostModel's T inverted: speed-weighted parallelism over
        work·(1 + beta_kind·chi)·runtime overheads.

        The value only changes when the placement does, so it is cached
        and invalidated by ``invalidate_rate()`` on migration — the
        event loop integrates progress for every running job at every
        event, and the old per-call recomputation dominated large-fleet
        replays (``reference_loops()`` restores it for A/B benchmarks).
        """
        if self._rate is not None and placement_mod._VECTORIZED:
            return self._rate
        j = self.job
        overhead = self.model.slowdown(self.alloc.placement, j.kind)
        runtime = WASM_OVERHEAD_OMP if (
            j.kind == "omp" and self.alloc.slice_size == 0) else 1.0
        world = self.world if self.world is not None else j.parallelism
        if world > self.alloc.n:             # overcommitted container
            runtime *= OVERCOMMIT_PENALTY
        eff = self.model.effective_parallelism(
            self.alloc.placement, self.speeds,
            active=self.eff_parallelism)
        self._rate = eff / (self.job.work * overhead * runtime)
        return self._rate

    def invalidate_rate(self) -> None:
        self._rate = None


@dataclasses.dataclass
class TraceResult:
    makespan: float
    exec_times: List[float]
    idle_samples: List[Tuple[float, float]]   # (time, idle_fraction)
    migrations: int
    waited: List[float]
    queue_drain_time: float = 0.0             # when the job queue emptied
    cross_host_fractions: List[float] = dataclasses.field(
        default_factory=list)                 # chi at placement, per job
    preemptions: int = 0
    finish_order: List[str] = dataclasses.field(default_factory=list)
    finish_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    actions: List[Action] = dataclasses.field(default_factory=list)
    # fleet churn: gangs requeued from a checkpoint after a host
    # failure, seconds of work rolled back, and graceful drain moves
    recoveries: int = 0
    lost_work_s: float = 0.0
    evacuations: int = 0
    # shrink-before-rollback: gangs saved by resharding onto surviving
    # capacity instead of rolling back to checkpoint, and shrunk gangs
    # restored to their submitted width once capacity returned
    shrinks: int = 0
    regrows: int = 0
    # migrations the straggler detector triggered (Actions with
    # payload reason="straggler") — 0 in pure simulation, which never
    # models stragglers, so pinned traces stay bit-compatible
    straggler_migrations: int = 0

    def makespans(self, jobs: Sequence[Job]) -> Dict[str, float]:
        """Per-job makespan (finish - arrival) for the jobs that finished."""
        return {j.job_id: self.finish_times[j.job_id] - j.arrival
                for j in jobs if j.job_id in self.finish_times}

    def mean_cross_host_fraction(self) -> float:
        if not self.cross_host_fractions:
            return 0.0
        return float(np.mean(self.cross_host_fractions))

    def idle_cdf(self, backlogged_only: bool = True) -> np.ndarray:
        """Time-weighted idle-fraction samples for CDF plotting.

        ``backlogged_only`` restricts to the period with queued jobs —
        idle chips then are pure fragmentation waste (the paper's Fig 10
        metric); the drain-down tail would otherwise dominate."""
        samples = self.idle_samples
        if backlogged_only and self.queue_drain_time > 0:
            samples = [s for s in samples
                       if s[0] <= self.queue_drain_time] or samples[:1]
        if len(samples) < 2:
            return np.asarray([samples[0][1]] if samples else [0.0])
        ts = np.array([t for t, _ in samples])
        vals = np.array([v for _, v in samples])
        w = np.diff(ts, append=ts[-1])
        order = np.argsort(vals)
        return np.repeat(vals[order], np.maximum(
            (w[order] / max(ts[-1], 1e-9) * 1000).astype(int), 1))


ARRIVAL_REGIMES = ("poisson", "diurnal", "burst")


def arrival_times(n: int, rate: float, seed: int,
                  regime: str = "poisson", diurnal_amp: float = 0.8,
                  diurnal_period: float = 0.0, burst_factor: float = 4.0,
                  burst_duty: float = 0.15) -> np.ndarray:
    """``n`` open-loop arrival timestamps at mean offered load ``rate``.

    Regimes (all deterministic given ``seed``, mean rate ≈ ``rate``):

    * ``poisson`` — homogeneous: exponential inter-arrival gaps (the
      exact draw sequence ``_assign_arrivals`` has always used).
    * ``diurnal`` — non-homogeneous Poisson, intensity
      ``rate * (1 + amp*sin(2*pi*t/period))`` (day/night swing), sampled
      by Lewis-Shedler thinning.  ``diurnal_period`` defaults to the
      span ``n`` arrivals cover at ``rate``, i.e. one full "day" per
      trace.
    * ``burst`` — baseline load with periodic burst episodes:
      ``burst_factor`` x rate for ``burst_duty`` of each cycle, rebalanced
      below baseline otherwise so the mean stays ``rate`` (flash-crowd
      traffic; the autoscaler stress regime).
    """
    rng = np.random.default_rng([seed, 1])
    if regime == "poisson":
        t, out = 0.0, []
        for _ in range(n):
            t += float(rng.exponential(1.0 / rate))
            out.append(t)
        return np.asarray(out)
    if regime == "diurnal":
        period = diurnal_period or n / max(rate, 1e-9)
        lam_max = rate * (1.0 + diurnal_amp)

        def lam(t):
            return rate * (1.0 + diurnal_amp
                           * np.sin(2.0 * np.pi * t / period))
    elif regime == "burst":
        period = n / max(rate, 1e-9) / 8.0     # several bursts per trace
        low = max(0.05, (1.0 - burst_factor * burst_duty)
                  / max(1e-9, 1.0 - burst_duty))
        lam_max = rate * burst_factor

        def lam(t):
            frac = (t / period) % 1.0
            return rate * (burst_factor if frac < burst_duty else low)
    else:
        raise ValueError(f"unknown arrival regime {regime!r}")
    # thinning: candidate gaps at lam_max, accept with lam(t)/lam_max
    t, out = 0.0, []
    while len(out) < n:
        t += float(rng.exponential(1.0 / lam_max))
        if rng.uniform() * lam_max <= lam(t):
            out.append(t)
    return np.asarray(out)


def generate_trace(n_jobs: int, kind: str, seed: int,
                   chips_per_host: int = 8,
                   arrival_rate: float = 0.0,
                   priority_classes: Optional[Sequence[Tuple[int, float]]]
                   = None, arrival_regime: str = "poisson") -> List[Job]:
    """Paper §6.2 traces: parallelism uniform over [2, 2*chips] for MPI
    (world sizes up to 2 VMs) and [2, chips] for OpenMP.

    ``arrival_rate`` > 0 draws open-loop arrivals from
    ``arrival_times`` under ``arrival_regime`` (poisson / diurnal /
    burst); 0 keeps the paper's all-at-t=0 replay.  ``priority_classes``
    is [(priority, weight)] to sample per-job priority classes.  All
    draws use rng streams separate from the job-size draws, so the base
    trace is identical across regimes.
    """
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        if kind.startswith("mpi"):
            n = int(rng.integers(2, 2 * chips_per_host + 1))
            work = 400.0
        else:
            n = int(rng.integers(2, chips_per_host + 1))
            work = 240.0
        jobs.append(Job(f"{kind}-{i}", kind, n, work))
    return _assign_arrivals(jobs, seed, arrival_rate, priority_classes,
                            arrival_regime)


def _assign_arrivals(jobs: List[Job], seed: int, arrival_rate: float,
                     priority_classes,
                     arrival_regime: str = "poisson") -> List[Job]:
    """Stamp one open-loop arrival process / priority draw over a whole
    trace (rng streams separate from the job-size draws)."""
    if arrival_rate > 0:
        times = arrival_times(len(jobs), arrival_rate, seed,
                              regime=arrival_regime)
        for job, t in zip(jobs, times):
            job.arrival = float(t)
    if priority_classes:
        pri_rng = np.random.default_rng([seed, 2])
        pris = [p for p, _ in priority_classes]
        w = np.asarray([w for _, w in priority_classes], dtype=np.float64)
        picks = pri_rng.choice(len(pris), size=len(jobs), p=w / w.sum())
        for job, k in zip(jobs, picks):
            job.priority = pris[int(k)]
    return jobs


def mixed_trace(n_jobs: int, seed: int, chips_per_host: int = 8,
                arrival_rate: float = 0.0,
                priority_classes: Optional[Sequence[Tuple[int, float]]]
                = None,
                kinds: Sequence[str] = ("mpi-compute", "omp",
                                        "mpi-network"),
                arrival_regime: str = "poisson") -> List[Job]:
    """Interleaved mpi-compute / mpi-network / omp trace — the fragmented
    multi-tenant mix used by the policy-sweep benchmarks.  Arrivals and
    priorities are drawn once over the merged trace, so ``arrival_rate``
    is the aggregate rate (not per job kind).  ``kinds`` reweights the
    interleave (repeat a kind to double its share) — e.g. the
    network-heavy beta-sensitivity line of the bench_makespan hetero
    sweep."""
    per = -(-n_jobs // len(kinds))
    parts = [generate_trace(per, k, seed + i, chips_per_host)
             for i, k in enumerate(kinds)]
    jobs = [parts[i % len(kinds)][i // len(kinds)] for i in range(n_jobs)]
    for i, j in enumerate(jobs):           # unique ids after interleave
        j.job_id = f"mix-{i}-{j.job_id}"
    return _assign_arrivals(jobs, seed, arrival_rate, priority_classes,
                            arrival_regime)


def hetero_speeds(hosts: int, slow_fraction: float = 0.5,
                  slow: float = 0.5, fast: float = 1.0) -> np.ndarray:
    """Mixed-generation host regime for the trace experiments: the first
    ``slow_fraction`` of the fleet is an older host generation at
    per-chip speed ``slow``, the rest run at ``fast`` — e.g. half the
    hosts at s=0.5.  Feed the result to ``Simulator(speeds=...)``,
    ``PlacementEngine(speeds=...)`` or ``Fabric(speeds=...)`` so
    ``generate_trace``/``mixed_trace`` jobs exercise the heterogeneous
    cost-model path end-to-end."""
    n_slow = int(round(hosts * slow_fraction))
    return np.asarray([slow] * n_slow + [fast] * (hosts - n_slow),
                      dtype=np.float64)


class Simulator:
    """Event-driven execution of a job trace on a shared cluster."""

    def __init__(self, hosts: int, chips_per_host: int, mode: str,
                 slice_size: int = 0, migrate: bool = True,
                 barrier_interval: float = 5.0,
                 policy: Union[str, PlacementPolicy] = "binpack",
                 backfill: bool = False,
                 preempt: Union[bool, PreemptPolicy, None] = False,
                 engine: Optional[PlacementEngine] = None,
                 speeds: Optional[Sequence[float]] = None,
                 cost_model: Optional[CostModel] = None,
                 sched: str = "central",
                 shard_hosts: Union[int, str, None] = None,
                 steal_budget: int = 0,
                 checkpoint_interval: Optional[float] = None,
                 shrink_recovery: bool = False):
        """mode: 'granular' (Faabric) or 'slices' (fixed baseline).

        ``policy`` selects the granular placement policy (binpack /
        spread / locality); 'slices' mode always uses fixed slices.
        ``backfill`` lets queued jobs that fit run past a blocked
        head-of-line job (capacity only shrinks while the head waits, so
        no skipped job could have run sooner).
        ``preempt`` enables priority preemption for a blocked
        head-of-line job (granular mode only): ``True`` for the default
        ``PreemptPolicy``, or a configured instance.
        ``speeds`` / ``cost_model`` configure a heterogeneous fleet
        (per-host speed factors, e.g. ``hetero_speeds``) and the shared
        job-time model; both land on the built engine.
        ``sched`` selects the scheduler architecture: 'central' (one
        engine scanning every host — the Fig 11 degradation) or
        'sharded' (``ShardedPlacementEngine`` over host groups of
        ``shard_hosts``; a decision scans one shard and pays
        ``SCHED_FORWARD_HOP_S`` per forwarding hop).  ``shard_hosts``
        may be ``"auto"`` (adaptive shard sizing that re-balances under
        churn) and ``steal_budget`` caps cross-shard split/escalation
        attempts per queue pump (0 = unbounded).
        ``checkpoint_interval`` adds a periodic per-gang checkpoint
        cadence (each charged ``CostModel.checkpoint_cost_s``) — what a
        fleet-churn hard failure rolls a gang back to; None keeps the
        pre-churn behaviour (failures roll back to the last preemption
        checkpoint or job start).
        ``shrink_recovery`` (granular mode) turns on
        shrink-before-rollback: gangs stranded by a drain or hard fail
        first try to reshard onto surviving capacity at a
        ``elastic.shrink_worlds`` world size and only roll back to
        checkpoint when no shrink fits (see the module docstring);
        False keeps the rollback-only recovery path bit-identical.
        ``engine`` adopts an externally-owned (fresh) ``PlacementEngine``
        instead of building one — used by ``core.fabric`` so live
        execution and prediction share one accounting code path; the
        engine's hosts/capacities/speeds/cost-model — and its
        centralised-vs-sharded architecture — override the
        ``hosts``/``chips_per_host``/``speeds``/``cost_model``/``sched``
        args.
        """
        if mode == "slices":
            pol: PlacementPolicy = FixedSlicePolicy(slice_size)
        else:
            pol = policy
        # the trace policy is carried per-call, never written into the
        # engine: an adopted (fabric-owned) engine keeps its own default
        self.policy = resolve_policy(pol)
        if engine is None:
            if sched == "sharded":
                engine = ShardedPlacementEngine(
                    hosts, chips_per_host,
                    hosts_per_shard=shard_hosts or DEFAULT_SHARD_HOSTS,
                    steal_budget=steal_budget,
                    policy=pol, speeds=speeds, cost_model=cost_model)
            else:
                assert sched == "central", f"unknown sched mode {sched!r}"
                engine = PlacementEngine(hosts, chips_per_host,
                                         policy=pol, speeds=speeds,
                                         cost_model=cost_model)
        else:
            assert engine.idle_chips() == engine.total_chips, \
                "adopted engine must be idle at trace start"
        self.engine = engine
        # the event loop owns the steal-budget lifecycle: reset once per
        # queue pump (not per decision — the budget caps a whole pass)
        engine.external_budget_reset = True
        self.model = engine.cost_model
        self.mode = mode
        self.slice_size = slice_size
        self.migrate = migrate and mode == "granular"
        if preempt and mode == "granular":
            self.preempt: Optional[PreemptPolicy] = (
                preempt if isinstance(preempt, PreemptPolicy)
                else PreemptPolicy())
        else:
            self.preempt = None
        self.barrier_interval = barrier_interval
        self.backfill = backfill
        self.checkpoint_interval = checkpoint_interval
        # slice allocations never migrate, so they never shrink either
        self.shrink_recovery = shrink_recovery and mode == "granular"
        # per-decision scheduler latency: the host count one decision
        # scans — the whole fleet for a centralised engine, one shard
        # for a sharded one (+ forwarding hops charged per decision).
        # Refreshed per pump: adaptive resharding under churn changes
        # the shard size mid-trace.
        self.sched_latency = SCHED_LATENCY_PER_HOST * engine.sched_hosts

    # ---- live-execution hooks (no-ops; see core.fabric) --------------------
    def _on_start(self, rj: RunningJob, resumed: bool) -> None:
        pass

    def _on_advance(self, now: float) -> None:
        pass

    def _on_preempt(self, rj: RunningJob) -> None:
        pass

    def _on_migrate(self, rj: RunningJob) -> None:
        pass

    def _on_finish(self, rj: RunningJob) -> None:
        pass

    # fleet-churn hooks (core.fleet events; see LiveTraceRunner)
    def _on_join(self, ev: FleetEvent, new_hosts: List[int]) -> None:
        pass

    def _on_drain(self, ev: FleetEvent) -> None:
        pass

    def _on_hosts_down(self, hosts: Sequence[int]) -> None:
        pass

    def _on_checkpoint(self, rj: RunningJob) -> None:
        pass

    def _on_fail(self, rj: RunningJob, hosts: Sequence[int]) -> None:
        pass

    def _on_shrink(self, rj: RunningJob,
                   survivors: Sequence[Tuple[int, int]]) -> None:
        """A shrink-before-rollback move (or its inverse, a regrow back
        to the submitted width) was applied: ``rj.alloc`` already
        carries the new placement and ``survivors`` the chips that
        still hold a live replica to reshard from (the gang's safe
        chips mid-drain, its surviving chips after a hard fail, or its
        whole shrunken placement on a regrow)."""

    # ---- placement --------------------------------------------------------
    def _try_place(self, job: Job) -> Optional[Allocation]:
        if self.mode != "granular" and job.kind == "omp":
            # shared-memory baseline: exactly one container
            return self.engine.allocate(job.job_id, self.slice_size,
                                        policy=self.policy, kind=job.kind)
        return self.engine.allocate(job.job_id, job.parallelism,
                                    policy=self.policy, kind=job.kind)

    def _eff_parallelism(self, job: Job, alloc: Allocation) -> int:
        # threads overcommit a single container (paper §6.2)
        shared_memory = self.mode != "granular" and job.kind == "omp"
        return self.model.active_workers(job.parallelism, alloc.n,
                                         shared_memory)

    # ---- main loop ----------------------------------------------------------
    def run(self, jobs: List[Job],
            fleet_events: Optional[Sequence[FleetEvent]] = None
            ) -> TraceResult:
        # queue key: (priority desc, arrival, submission order)
        seq = {j.job_id: i for i, j in enumerate(jobs)}

        def qkey(j: Job):
            return (-j.priority, j.arrival, seq[j.job_id])

        queue: List[Job] = sorted((j for j in jobs if j.arrival <= 0),
                                  key=qkey)
        arrivals = sorted((j for j in jobs if j.arrival > 0), key=qkey)
        running: Dict[str, RunningJob] = {}
        heap: List[Tuple[float, int, int, str]] = []
        token = 0
        now = 0.0
        exec_times, waited = [], []
        idle_samples: List[Tuple[float, float]] = []
        chis: List[float] = []
        actions: List[Action] = []
        migrations = preemptions = 0
        recoveries = evacuations = shrinks = regrows = 0
        lost_work = 0.0
        # progress of checkpointed (preempted) jobs awaiting resume
        suspended: Dict[str, float] = {}
        first_start: Dict[str, float] = {}
        finish_order: List[str] = []
        finish_times: Dict[str, float] = {}
        ARRIVE, FINISH, FLEET, DEADLINE, CKPT, RETRY = 0, 1, 2, 3, 4, 5
        for j in arrivals:
            token += 1
            heapq.heappush(heap, (j.arrival, token, ARRIVE, j.job_id))
        pending_arrivals = {j.job_id: j for j in arrivals}
        # fleet churn: events interleave with arrivals on the same heap
        # (at equal timestamps arrivals run first — they were pushed
        # first); the controller owns lease/drain/fail semantics
        schedule = sorted(fleet_events or [], key=lambda e: e.t)
        controller = FleetController(self.engine)
        for i, ev in enumerate(schedule):
            token += 1
            heapq.heappush(heap, (max(0.0, ev.t), token, FLEET, i))
        # risk-aware placement: seed the contractual lease/topology
        # metadata off the schedule (reclaims are sold lease terms,
        # multi-host events reveal blast domains) and estimate hazards
        # online as events are applied — identical in the live runner,
        # which inherits this loop, so predictions stay in parity
        risk_aware = self.model.risk_aware
        hazard_est: Optional[HazardEstimator] = None
        if risk_aware:
            self.engine.set_host_risk(
                lease_until_s=lease_expiries(schedule, self.engine.hosts),
                blast_groups=blast_groups(schedule, self.engine.hosts))
            hazard_est = HazardEstimator(self.engine.hosts)
        if self.shrink_recovery:
            # lazy: core.elastic pulls in jax, which the simulator
            # otherwise never needs
            from repro.core.elastic import shrink_worlds

        def progress_to(t: float):
            # runs for every running job at every event: read the
            # cached per-placement rate directly (reference mode keeps
            # the pre-PR per-call recomputation)
            if placement_mod._VECTORIZED:
                for rj in running.values():
                    r = rj._rate
                    rj.progress += (r if r is not None else rj.rate()) \
                        * (t - rj.last_update)
                    rj.last_update = t
            else:
                for rj in running.values():
                    rj.progress += rj.rate() * (t - rj.last_update)
                    rj.last_update = t

        def schedule_finish(rj: RunningJob):
            nonlocal token
            remaining = max(0.0, 1.0 - rj.progress)
            t_fin = now + remaining / rj.rate()
            token += 1
            rj.finish_event = token
            heapq.heappush(heap, (t_fin, token, FINISH, rj.job.job_id))

        def schedule_ckpt(rj: RunningJob):
            nonlocal token
            if self.checkpoint_interval is None:
                return
            token += 1
            rj.ckpt_event = token
            heapq.heappush(heap, (now + self.checkpoint_interval, token,
                                  CKPT, rj.job.job_id))

        def start_job(job: Job, alloc: Allocation):
            rj = RunningJob(job, alloc, start=now, last_update=now,
                            eff_parallelism=self._eff_parallelism(
                                job, alloc),
                            model=self.model, speeds=self.engine.speeds)
            resumed = job.job_id in suspended
            if resumed:
                # checkpointed progress survives; the snapshot restore
                # costs like a migration
                rj.progress = max(0.0, suspended.pop(job.job_id)
                                  - self.model.preempt_cost_s * rj.rate())
            running[job.job_id] = rj
            if job.job_id not in first_start:
                first_start[job.job_id] = now
                waited.append(now - max(0.0, job.arrival))
            chis.append(alloc.cross_host_fraction())
            actions.append(Action("resume" if resumed else "start",
                                  {"job": job.job_id, "t": now,
                                   "placement": list(alloc.placement)}))
            schedule_finish(rj)
            # a fresh start / restored snapshot IS the baseline
            # checkpoint a later host failure rolls back to
            rj.ckpt_progress = rj.progress
            schedule_ckpt(rj)
            self._on_start(rj, resumed)

        def preempt_for(job: Job) -> bool:
            """Evict lower-priority gangs so the blocked head job fits."""
            priorities = {jid: r.job.priority for jid, r in running.items()}
            plan = self.engine.preemption_plan(
                job.parallelism, job.priority, priorities,
                policy=self.policy, preempt=self.preempt, kind=job.kind)
            if not plan:
                return False
            nonlocal preemptions
            for jid in plan:
                rj = running.pop(jid)
                suspended[jid] = rj.progress   # checkpoint (snapshot)
                self.engine.release(rj.alloc)
                rj.finish_event = -1           # cancel pending finish
                bisect.insort(queue, rj.job, key=qkey)
                preemptions += 1
                actions.append(Action("preempt",
                                      {"job": jid, "t": now,
                                       "by": job.job_id,
                                       "progress": round(rj.progress, 6)}))
                self._on_preempt(rj)
            return True

        def kinds_of() -> Dict[str, str]:
            return {jid: r.job.kind for jid, r in running.items()}

        def fail_jobs(jids: List[str], hosts: Sequence[int]):
            """Requeue gangs that lost chips to a host failure: progress
            rolls back to the last checkpoint, the work since then is
            lost, and the existing suspend/resume machinery (snapshot
            restore cost on resume) brings them back."""
            nonlocal recoveries, lost_work
            for jid in jids:
                rj = running.pop(jid)
                rate = rj.rate()
                lost = (max(0.0, rj.progress - rj.ckpt_progress) / rate
                        if rate > 0 else 0.0)
                lost_work += lost
                suspended[jid] = rj.ckpt_progress
                rj.finish_event = -1
                rj.ckpt_event = -1
                bisect.insort(queue, rj.job, key=qkey)
                recoveries += 1
                actions.append(Action("recover",
                                      {"job": jid, "t": now,
                                       "progress": round(
                                           rj.ckpt_progress, 6),
                                       "lost_s": round(lost, 6)}))
                self._on_fail(rj, hosts)

        def apply_evacuations(plans: List[Tuple[str, list]]):
            """Graceful drain moves: the evacuation planner's decisions,
            applied through the same migration machinery (and charged
            the same snapshot-transfer cost)."""
            nonlocal evacuations
            for jid, new_pl in plans:
                r = running[jid]
                r.alloc = self.engine.apply_migration(r.alloc, new_pl)
                r.invalidate_rate()        # placement changed
                r.progress = max(
                    0.0,
                    r.progress - self.model.migration_cost_s * r.rate())
                evacuations += 1
                actions.append(Action("evacuate",
                                      {"job": jid, "t": now,
                                       "placement": list(new_pl)}))
                self._on_migrate(r)
                schedule_finish(r)

        def apply_shrink(rj: RunningJob, pl: list,
                         survivors: List[Tuple[int, int]],
                         rebind: bool):
            """Commit one shrink-before-rollback move: the gang
            reshards onto ``pl`` (possibly a smaller power-of-two
            world), keeps all its progress, and pays one snapshot
            transfer like a migration.  ``rebind`` distinguishes the
            hard-fail flavour (the engine already dropped the
            allocation) from the mid-drain one (still allocated)."""
            nonlocal shrinks
            old_n = rj.alloc.n
            if rebind:
                rj.alloc = self.engine.bind(rj.job.job_id, pl)
            else:
                rj.alloc = self.engine.apply_migration(rj.alloc, pl)
            # the gang now runs as a world of alloc.n ranks (a DP
            # reshard, not an overcommit); rollback requeues the
            # original Job, so the submitted width is never lost
            rj.world = rj.alloc.n
            rj.eff_parallelism = rj.alloc.n
            rj.invalidate_rate()
            rj.progress = max(
                0.0,
                rj.progress - self.model.migration_cost_s * rj.rate())
            shrinks += 1
            actions.append(Action("shrink",
                                  {"job": rj.job.job_id, "t": now,
                                   "from": old_n, "to": rj.alloc.n,
                                   "placement": list(pl)}))
            self._on_shrink(rj, survivors)
            schedule_finish(rj)

        def shrink_stranded(jids: List[str]):
            """Shrink-before-rollback, drain flavour: a stranded gang's
            draining hosts are still alive, so it can reshard onto safe
            capacity at a smaller world with nothing lost.  Its own
            chips on non-draining hosts count as landing room."""
            for jid in jids:
                rj = running.get(jid)
                if rj is None or rj.alloc.slice_size:
                    continue
                keep = [(h, c) for h, c in rj.alloc.placement
                        if not self.engine.draining[h]]
                pl = self.engine.shrink_plan(
                    shrink_worlds(rj.alloc.n), credit=keep,
                    policy=self.policy, kind=rj.job.kind)
                if pl is not None:
                    apply_shrink(rj, pl, keep, rebind=False)

        def shrink_failed(jids: List[str],
                          hosts: Sequence[int]) -> List[str]:
            """Shrink-before-rollback, hard-fail flavour: the hosts are
            gone (allocations already dropped), so a gang reshards only
            if at least one chip survived to hold a live replica.
            Returns the job_ids with no fitting shrink world — those
            still roll back to checkpoint."""
            dead = {int(h) for h in hosts}
            rollback: List[str] = []
            for jid in jids:
                rj = running.get(jid)
                pl = None
                survivors: List[Tuple[int, int]] = []
                if rj is not None and not rj.alloc.slice_size:
                    survivors = [(h, c) for h, c in rj.alloc.placement
                                 if h not in dead]
                    if survivors:
                        pl = self.engine.shrink_plan(
                            shrink_worlds(rj.alloc.n),
                            policy=self.policy, kind=rj.job.kind)
                if pl is None:
                    rollback.append(jid)
                    continue
                apply_shrink(rj, pl, survivors, rebind=True)
            return rollback

        def regrow_shrunk():
            """A shrink never sticks: once capacity returns (a join, a
            finish), a shrunk gang refits back to its submitted width —
            the inverse move, crediting its current chips as landing
            room and paying one more snapshot transfer.  Runs at the
            head of each scheduling pass so stranded-then-shrunk gangs
            reclaim width before new arrivals soak up the capacity."""
            nonlocal regrows
            for jid in sorted(running):
                rj = running[jid]
                if rj.world is None or rj.world >= rj.job.parallelism:
                    continue
                pl = self.engine.shrink_plan(
                    [rj.job.parallelism], credit=rj.alloc.placement,
                    policy=self.policy, kind=rj.job.kind)
                if pl is None:
                    continue
                old_n = rj.alloc.n
                survivors = list(rj.alloc.placement)
                rj.alloc = self.engine.apply_migration(rj.alloc, pl)
                rj.world = None
                rj.eff_parallelism = self._eff_parallelism(rj.job,
                                                           rj.alloc)
                rj.invalidate_rate()
                rj.progress = max(
                    0.0,
                    rj.progress - self.model.migration_cost_s
                    * rj.rate())
                regrows += 1
                actions.append(Action("regrow",
                                      {"job": jid, "t": now,
                                       "from": old_n,
                                       "to": rj.alloc.n,
                                       "placement": list(pl)}))
                self._on_shrink(rj, survivors)
                schedule_finish(rj)

        def pump_queue():
            # one scheduling pass: the per-decision scan latency accrues
            # ONCE per pump (decisions in a pass share one scan of the
            # fleet/shard state), not once per queued job — the old
            # per-start bump compounded under a deep backlog and pushed
            # the clock far past queued finish events.  Forwarding hops
            # (sharded engine) are genuinely serial per decision and are
            # charged per started job.
            nonlocal now
            # fleet churn: cross-shard steal attempts budget per pass,
            # and adaptive resharding may have changed the shard size
            self.engine.reset_steal_budget()
            if risk_aware:
                # lease clocks tick down: decisions in this pass see
                # remaining lease time as of now
                self.engine.risk_tick(now)
            if self.shrink_recovery:
                regrow_shrunk()
            self.sched_latency = (SCHED_LATENCY_PER_HOST
                                  * self.engine.sched_hosts)
            charged = False
            i = 0
            while i < len(queue):
                job = queue[i]
                alloc = self._try_place(job)
                if alloc is None and i == 0 and self.preempt is not None \
                        and preempt_for(job):
                    alloc = self._try_place(job)
                if alloc is None:
                    if not self.backfill:
                        break
                    i += 1                     # backfill past blocked head
                    continue
                if not charged:
                    now += self.sched_latency
                    charged = True
                now += SCHED_FORWARD_HOP_S * self.engine.decision_hops
                start_job(queue.pop(i), alloc)
            idle_samples.append((now, self.engine.idle_fraction()))

        pump_queue()
        drain_time = 0.0
        while heap:
            t, tok, kind, job_id = heapq.heappop(heap)
            if kind == ARRIVE:
                job = pending_arrivals.pop(job_id)
                now = max(now, t)
                progress_to(now)
                self._on_advance(now)
                bisect.insort(queue, job, key=qkey)
                pump_queue()
                if not pending_arrivals and not queue \
                        and drain_time == 0.0:
                    drain_time = now           # backlog ended mid-arrivals
                continue
            if kind == FLEET:                  # job_id = schedule index
                ev = schedule[job_id]
                now = max(now, t)
                progress_to(now)
                self._on_advance(now)
                out = controller.apply(ev, now, kinds=kinds_of())
                if risk_aware:
                    # after apply: a join's fresh hosts are sized in
                    hazard_est.observe(ev)
                    self.engine.set_host_risk(
                        hazards=hazard_est.rates(self.engine.hosts, now))
                if ev.kind == "join":
                    actions.append(Action("join",
                                          {"t": now,
                                           "hosts": list(out.joined),
                                           "chips": int(sum(
                                               ev.capacities))}))
                    self._on_join(ev, out.joined)
                    pump_queue()               # new capacity may unblock
                elif ev.kind == "fail":
                    actions.append(Action("host-fail",
                                          {"t": now,
                                           "hosts": sorted(
                                               int(h)
                                               for h in ev.hosts)}))
                    self._on_hosts_down(ev.hosts)
                    failed = out.failed
                    if self.shrink_recovery:
                        failed = shrink_failed(failed, ev.hosts)
                    fail_jobs(failed, ev.hosts)
                    pump_queue()               # survivors' chips freed
                else:                          # reclaim: drain begins
                    actions.append(Action("drain",
                                          {"t": now,
                                           "hosts": sorted(
                                               int(h)
                                               for h in ev.hosts),
                                           "deadline": round(
                                               out.deadline, 6)}))
                    self._on_drain(ev)
                    apply_evacuations(out.evacuations)
                    if self.shrink_recovery and out.stranded:
                        shrink_stranded(out.stranded)
                    token += 1
                    heapq.heappush(heap, (out.deadline, token,
                                          DEADLINE, job_id))
                    # evacuation retries through the drain window on
                    # the controller's backoff schedule: capacity that
                    # frees mid-drain rescues gangs before the deadline
                    for rt in controller.retry_times(ev, now):
                        token += 1
                        heapq.heappush(heap, (rt, token, RETRY, job_id))
                continue
            if kind == RETRY:                  # job_id = schedule index
                ev = schedule[job_id]
                # stale once the drain resolved: the deadline already
                # retired the hosts, or nothing still runs on them
                doomed = {int(h) for h in ev.hosts
                          if self.engine.draining[int(h)]}
                if not doomed or not any(
                        any(h in doomed for h, _ in r.alloc.placement)
                        for r in running.values()):
                    continue
                now = max(now, t)
                progress_to(now)
                self._on_advance(now)
                out = controller.expire(ev, kinds=kinds_of())
                apply_evacuations(out.evacuations)
                if self.shrink_recovery and out.stranded:
                    shrink_stranded(out.stranded)
                continue
            if kind == DEADLINE:               # job_id = schedule index
                ev = schedule[job_id]
                now = max(now, t)
                progress_to(now)
                self._on_advance(now)
                # last-chance evacuation (capacity may have freed since
                # the drain began), then the lease is gone: whatever
                # still holds chips requeues from its checkpoint
                out = controller.expire(ev, kinds=kinds_of())
                apply_evacuations(out.evacuations)
                if self.shrink_recovery and out.stranded:
                    # last call with the hosts still alive: a reshard
                    # now keeps progress a rollback would throw away
                    shrink_stranded(out.stranded)
                self._on_hosts_down(ev.hosts)
                failed = controller.fail(ev.hosts)
                actions.append(Action("retire",
                                      {"t": now,
                                       "hosts": sorted(
                                           int(h) for h in ev.hosts),
                                       "failed": list(failed)}))
                if self.shrink_recovery:
                    # chips freed by the retirement itself may fit a
                    # shrink for gangs that kept a surviving replica
                    failed = shrink_failed(failed, ev.hosts)
                fail_jobs(failed, ev.hosts)
                pump_queue()
                continue
            if kind == CKPT:
                rj = running.get(job_id)
                if rj is None or rj.ckpt_event != tok:
                    continue                   # stale (finished/failed)
                t = max(now, t)
                progress_to(t)
                now = t
                self._on_advance(now)
                # the gang pauses for the snapshot save, then the saved
                # progress becomes the failure rollback point; with
                # delta checkpointing configured, non-rebase saves ship
                # chunk diffs and charge the cheaper delta cost
                rj.ckpt_count += 1
                rj.progress = max(
                    0.0,
                    rj.progress
                    - self.model.checkpoint_cost(rj.ckpt_count)
                    * rj.rate())
                rj.ckpt_progress = rj.progress
                actions.append(Action("checkpoint",
                                      {"job": job_id, "t": now,
                                       "progress": round(
                                           rj.progress, 6)}))
                self._on_checkpoint(rj)
                schedule_finish(rj)
                schedule_ckpt(rj)
                continue
            rj = running.get(job_id)
            if rj is None or rj.finish_event != tok:
                continue                            # stale event
            # monotone clock: scheduler-latency bumps during a pump can
            # push `now` past an already-queued finish timestamp
            t = max(now, t)
            progress_to(t)
            now = t
            self._on_advance(now)
            # numerical slack: the job is done
            self.engine.release(rj.alloc)
            del running[job_id]
            exec_times.append(now - first_start[job_id])
            finish_order.append(job_id)
            finish_times[job_id] = now
            actions.append(Action("finish", {"job": job_id, "t": now}))
            self._on_finish(rj)
            # barrier-point migration: consolidate fragmented gangs
            # (only gangs the cost model says can still pay the
            # snapshot cost); plans are costed under each gang's kind
            if self.migrate and running:
                candidates = [r.alloc for r in running.values()
                              if self.model.migration_worthwhile(
                                  r.progress)]
                kinds = {a.job_id: running[a.job_id].job.kind
                         for a in candidates}
                remaining = {
                    a.job_id: max(0.0, 1.0 - running[a.job_id].progress)
                    / running[a.job_id].rate() for a in candidates}
                for jid, new_pl in self.engine.migration_plan(
                        candidates, kinds=kinds, remaining=remaining):
                    r = running[jid]
                    progress_to(now)
                    r.alloc = self.engine.apply_migration(r.alloc, new_pl)
                    r.invalidate_rate()        # placement changed
                    r.progress = max(
                        0.0,
                        r.progress - self.model.migration_cost_s * r.rate())
                    migrations += 1
                    actions.append(Action("migrate",
                                          {"job": jid, "t": now,
                                           "placement": list(new_pl)}))
                    self._on_migrate(r)
                    schedule_finish(r)
            had_queue = bool(queue)
            pump_queue()
            if had_queue and not queue and not pending_arrivals \
                    and drain_time == 0.0:
                drain_time = now
        result = TraceResult(
            makespan=now, exec_times=exec_times,
            idle_samples=idle_samples, migrations=migrations,
            waited=waited, queue_drain_time=drain_time,
            cross_host_fractions=chis,
            preemptions=preemptions,
            finish_order=finish_order,
            finish_times=finish_times, actions=actions,
            recoveries=recoveries, lost_work_s=lost_work,
            evacuations=evacuations, shrinks=shrinks,
            regrows=regrows,
            straggler_migrations=sum(
                1 for a in actions if a.kind == "migrate"
                and a.payload.get("reason") == "straggler"))
        tel = telemetry.get()
        if tel.enabled:
            # render the whole virtual-clock schedule as spans/instants
            # (same schema the live wall-clock spans use) and fold the
            # headline aggregates into the metrics summary
            tel.record_actions(actions, clock="virtual")
            tel.count("sim.runs")
            tel.count("sim.actions", len(actions))
            tel.gauge("sim.makespan_s", now)
            tel.gauge("sim.migrations", migrations)
            tel.gauge("sim.preemptions", preemptions)
        return result


def run_baselines(jobs: List[Job], hosts: int, chips_per_host: int = 8,
                  migrate: bool = True,
                  policy: Union[str, PlacementPolicy] = "binpack",
                  backfill: bool = False,
                  speeds: Optional[Sequence[float]] = None
                  ) -> Dict[str, TraceResult]:
    """Faabric vs the paper's fixed-slice baselines (1/2/4/8 ctr per VM)."""
    out = {}
    out["faabric"] = Simulator(hosts, chips_per_host, "granular",
                               migrate=migrate, policy=policy,
                               backfill=backfill, speeds=speeds).run(jobs)
    for k in (1, 2, 4, 8):
        slice_size = chips_per_host // k
        out[f"{k}-ctr-per-vm"] = Simulator(
            hosts, chips_per_host, "slices", slice_size=slice_size,
            backfill=backfill, speeds=speeds).run(jobs)
    return out
