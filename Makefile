# Tier-1 verification and fast iteration targets.
PY ?= python

.PHONY: check quick

# the repo's tier-1 gate (see ROADMAP.md)
check:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast subset for scheduler/placement/simulator iteration
quick:
	PYTHONPATH=src $(PY) -m pytest -q -k "placement or scheduler or simulator"
