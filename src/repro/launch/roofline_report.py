"""Aggregate results/dryrun/*.json into the §Dry-run / §Roofline tables
(markdown) used by EXPERIMENTS.md."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "granite-moe-1b-a400m", "phi3.5-moe-42b-a6.6b", "llama3.2-1b",
    "llama3.2-3b", "glm4-9b", "minitron-4b", "zamba2-2.7b", "xlstm-1.3b",
    "whisper-small", "llama-3.2-vision-11b"]


def load(results_dir: str) -> List[Dict]:
    recs = []
    for path in glob.glob(os.path.join(results_dir, "*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_sec(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compiles | fits 16GB | peak GB | "
            "deploy compile s |",
            "|---|---|---|---|---|---|---|"]
    key = lambda r: (ARCH_ORDER.index(r["arch"]),
                     SHAPE_ORDER.index(r["shape"]), r["mesh"])
    for r in sorted(recs, key=key):
        if not r.get("applicable", True):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP | — | — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{'yes' if r.get('fits_hbm_16gb') else 'NO'} | "
            f"{r['memory']['peak_per_device_gb']} | "
            f"{r.get('deploy_compile_s', '—')} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | compute | memory | collective | bottleneck | "
            "MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    key = lambda r: (ARCH_ORDER.index(r["arch"]),
                     SHAPE_ORDER.index(r["shape"]))
    for r in sorted([r for r in recs if r["mesh"] == "16x16"], key=key):
        if not r.get("applicable", True) or "roofline" not in r:
            continue
        rl = r["roofline"]
        t = rl["terms_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_sec(t['compute'])} | "
            f"{fmt_sec(t['memory'])} | {fmt_sec(t['collective'])} | "
            f"**{rl['bottleneck']}** | {rl['model_flops']:.2e} | "
            f"{rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']*100:.1f}% |")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("applicable", True)]
    skips = [r for r in recs if not r.get("applicable", True)]
    fits = [r for r in ok if r.get("fits_hbm_16gb")]
    lines = [
        f"cells: {len(recs)} total = {len(ok)} compiled + "
        f"{len(skips)} skipped (long_500k on full-attention archs)",
        f"fits 16GB HBM: {len(fits)}/{len(ok)}",
    ]
    worst = sorted((r for r in ok if "roofline" in r),
                   key=lambda r: r["roofline"]["roofline_fraction"])[:3]
    for r in worst:
        lines.append(f"worst roofline: {r['arch']}/{r['shape']} "
                     f"{r['roofline']['roofline_fraction']*100:.1f}% "
                     f"({r['roofline']['bottleneck']}-bound)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    args = ap.parse_args()
    recs = load(args.results)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per assigned cell)\n")
    print(roofline_table(recs))
    print("\n## Summary\n")
    print(summary(recs))


if __name__ == "__main__":
    main()
