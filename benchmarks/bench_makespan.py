"""Paper Fig 10: 100-job traces (mpi + omp) on a 32-host shared cluster.

Reports makespan per policy, median idle-chip fraction, and job execution
time percentiles — Faabric's chip-granular Granule scheduling vs the
fixed-slice (k-containers-per-VM) baselines — then sweeps the
``PlacementEngine`` policies (binpack / spread / locality), a
mixed-generation (heterogeneous per-host speed) fleet scored through the
shared ``CostModel``, and the multi-tenant arrival regimes (Poisson
arrivals, priority classes, backfill) that extend the §6 experiment past
all-jobs-at-t=0 FIFO.
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator as S


def run(report, tiny=False):
    njobs = 24 if tiny else 100
    hosts_fig10 = 8 if tiny else 32
    sweep_hosts = 8 if tiny else 16
    hetero_seeds = range(2) if tiny else range(5)
    for kind, paper_note in (("mpi-compute", "Fig10a mpi"),
                             ("omp", "Fig10b omp")):
        jobs = S.generate_trace(njobs, kind, seed=0)
        res = S.run_baselines(jobs, hosts=hosts_fig10)
        fa = res["faabric"].makespan
        for name, r in res.items():
            report(f"makespan/{kind}/{name}", round(r.makespan, 1), "s",
                   paper_note)
            report(f"idle_median/{kind}/{name}",
                   round(float(np.median(r.idle_cdf())), 3), "frac",
                   paper_note)
            report(f"exec_p50/{kind}/{name}",
                   round(float(np.percentile(r.exec_times, 50)), 1), "s",
                   paper_note)
        for name, r in res.items():
            if name != "faabric":
                report(f"faabric_vs/{kind}/{name}",
                       round((r.makespan - fa) / r.makespan * 100, 1),
                       "% lower makespan", paper_note)
        report(f"migrations/{kind}", res["faabric"].migrations, "count",
               paper_note)

    # ---- placement-policy sweep on a fragmented mixed trace ----------------
    jobs = S.mixed_trace(njobs, seed=7)
    for policy in ("binpack", "spread", "locality"):
        r = S.Simulator(sweep_hosts, 8, "granular", migrate=False,
                        policy=policy).run(jobs)
        report(f"policy/{policy}/makespan", round(r.makespan, 1), "s",
               "policy sweep, mixed 100-job trace")
        report(f"policy/{policy}/mean_chi",
               round(r.mean_cross_host_fraction(), 3), "frac",
               "cross-host fraction at placement")

    # ---- arrival regimes: Poisson load, priorities, backfill ---------------
    for rate, regime in ((0.5, "poisson-heavy"), (0.2, "poisson-light")):
        jobs = S.generate_trace(njobs, "mpi-compute", seed=3,
                                arrival_rate=rate,
                                priority_classes=[(0, 0.8), (5, 0.2)])
        for backfill in (False, True):
            r = S.Simulator(sweep_hosts, 8, "granular",
                            backfill=backfill).run(jobs)
            tag = "backfill" if backfill else "fifo"
            report(f"arrivals/{regime}/{tag}/makespan",
                   round(r.makespan, 1), "s", "multi-tenant arrivals")
            report(f"arrivals/{regime}/{tag}/mean_wait",
                   round(float(np.mean(r.waited)), 1), "s",
                   "multi-tenant arrivals")

    # ---- heterogeneous fleet: mixed host generations -----------------------
    # half the 16 hosts are an older generation at s=0.5; policies score
    # through the shared CostModel T = (W / sum n_h*s_h)(1 + beta_kind*chi),
    # so locality trades cross-host fragmentation against host speed per
    # job kind.  Makespans are averaged over 5 trace seeds.
    speeds = S.hetero_speeds(sweep_hosts, slow_fraction=0.5, slow=0.5)
    means = {}
    for policy in ("binpack", "spread", "locality"):
        runs = [S.Simulator(sweep_hosts, 8, "granular", migrate=True,
                            policy=policy, speeds=speeds).run(
                                S.mixed_trace(njobs, seed=s))
                for s in hetero_seeds]
        means[policy] = float(np.mean([r.makespan for r in runs]))
        report(f"hetero/{policy}/mean_makespan", round(means[policy], 1),
               "s", "mixed-generation fleet, half the hosts at s=0.5")
        report(f"hetero/{policy}/mean_chi",
               round(float(np.mean([r.mean_cross_host_fraction()
                                    for r in runs])), 3), "frac",
               "cross-host fraction at placement")
    report("hetero/locality_vs_binpack",
           round((means["binpack"] - means["locality"])
                 / means["binpack"] * 100, 2), "% lower makespan",
           "CostModel-scored locality on a mixed-speed fleet")

    # beta-sensitivity: double the network-bound share (beta 13 jobs
    # dominate, so co-location pressure rises fleet-wide)
    net_heavy = ("mpi-network", "mpi-compute", "mpi-network", "omp")
    for policy in ("binpack", "locality"):
        runs = [S.Simulator(sweep_hosts, 8, "granular", migrate=True,
                            policy=policy, speeds=speeds).run(
                    S.mixed_trace(njobs, seed=s, kinds=net_heavy))
                for s in hetero_seeds]
        report(f"hetero_net_heavy/{policy}/mean_makespan",
               round(float(np.mean([r.makespan for r in runs])), 1), "s",
               "mixed-generation fleet, network-heavy job mix")

    # ---- collective-priced placement vs scalar beta ------------------------
    # both runs share one collective-priced CostModel as the *physics*
    # (job rates follow model.slowdown = 1 + collective_time/compute);
    # the policies differ only in how they *score* candidates: the
    # scalar-beta policy keeps the legacy 1 + 13·chi proxy, the
    # collective-priced policy scores with the same collective_time the
    # simulator charges — so it sees what beta can't (balanced vs
    # ragged splits, per-kind message sizes).
    from repro.core import placement as P

    def collective_model():
        return P.CostModel(
            collective_bytes={"mpi-network": 64 << 20,
                              "mpi-compute": 4 << 20, "omp": 1 << 18},
            step_compute_s=0.01)

    # 8 hosts keeps the trace split-heavy (jobs up to 16 chips must
    # span hosts), which is where schedule-aware scoring matters;
    # migration is on because balanced splits strand chips that only
    # later rebalancing can reclaim
    coll_means = {}
    for tag, policy in (("scalar_beta", P.LocalityScoredPolicy(beta=13.0)),
                        ("collective", "locality")):
        runs = [S.Simulator(8, 8, "granular", migrate=True,
                            policy=policy,
                            cost_model=collective_model()).run(
                    S.mixed_trace(njobs, seed=s, kinds=net_heavy))
                for s in hetero_seeds]
        coll_means[tag] = float(np.mean([r.makespan for r in runs]))
        report(f"collective_priced/{tag}/mean_makespan",
               round(coll_means[tag], 1), "s",
               "net-heavy trace, collective-priced physics")
    report("collective_priced/improvement",
           round((coll_means["scalar_beta"] - coll_means["collective"])
                 / coll_means["scalar_beta"] * 100, 2),
           "% lower makespan",
           "collective_time-scored vs scalar-beta locality")

    # ---- priority preemption: high-priority latency vs churn ---------------
    def trace():
        return S.generate_trace(njobs, "mpi-compute", seed=11,
                                arrival_rate=0.4,
                                priority_classes=[(0, 0.85), (5, 0.15)])

    for preempt in (False, True):
        r = S.Simulator(sweep_hosts, 8, "granular",
                        preempt=preempt).run(trace())
        hi = [j for j in trace() if j.priority > 0]
        ms = r.makespans(hi)
        tag = "preempt" if preempt else "no-preempt"
        report(f"preemption/{tag}/hi_pri_mean_makespan",
               round(float(np.mean(list(ms.values()))), 1), "s",
               "priority classes / rFaaS-style reclamation")
        report(f"preemption/{tag}/makespan", round(r.makespan, 1), "s",
               "priority classes")
        report(f"preemption/{tag}/evictions", r.preemptions, "count",
               "checkpoint + requeue + resume")

    # ---- placement-engine micro-benchmark: decisions/sec ------------------
    # before = the pre-PR loop implementation (reference_loops), after =
    # the vectorized hot path with cached summaries; full sweep lives in
    # bench_scheduler_scale
    from benchmarks import bench_scheduler_scale as BS
    from repro.core import placement as P
    micro_hosts = 32 if tiny else 128
    k_dec = 200 if tiny else 1500
    eng = P.PlacementEngine(micro_hosts, 8)
    BS._saturate(eng)
    with P.reference_loops():
        before = BS._decision_rate(eng, k_dec)
    eng = P.PlacementEngine(micro_hosts, 8)
    BS._saturate(eng)
    after = BS._decision_rate(eng, k_dec)
    report(f"engine_decisions_per_sec/{micro_hosts}h/before",
           round(before, 0), "dec/s", "pre-PR loop hot path")
    report(f"engine_decisions_per_sec/{micro_hosts}h/after",
           round(after, 0), "dec/s", "vectorized + cached summaries")
    report(f"engine_decisions_per_sec/{micro_hosts}h/speedup",
           round(after / before, 2), "x", "placement hot path")
