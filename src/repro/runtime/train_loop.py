"""The Faabric training runtime: gang execution with control points.

This is the *executable* (CPU-fabric / real-TPU) counterpart of the pjit
production path: a data-parallel gang of Granules — one per device — each
running the full model replica on its batch slice, synchronising gradients
with the paper's hierarchical (pod-leader) collective schedule via
shard_map, and passing through a **control point** at every step boundary
where the runtime may checkpoint, recover from failure, migrate, or
elastically rescale the gang (paper §3.2/§3.3).

Multi-tenancy: the runtime is a thin driver over a ``core.fabric``
``GangHandle`` — the shared ``Fabric`` owns the device pool and the
``PlacementEngine``, so several gangs (train or serve) can coexist on one
fabric and this gang's rescale/migrate decisions go through the same
accounting every other tenant uses.  Control-point actions arrive as
``core.control.Action`` records (checkpoint / migrate / rescale /
recover) — the same vocabulary the trace simulator logs.

Fault tolerance (paper §3.4, implemented): failure -> gang restart from the
latest snapshot; the deterministic (seed, step)-keyed data pipeline makes
recovery bit-exact.  Straggler mitigation: EWMA step-time detector triggers
a migrate action.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core import collectives as coll
from repro.core import compat
from repro.core import control as ctl
from repro.core import elastic as elastic_mod
from repro.core.fabric import Fabric, GangHandle
from repro.data import pipeline as dp
from repro.models import model as model_mod
from repro.optim import adamw


@dataclasses.dataclass
class RuntimeConfig:
    total_steps: int = 20
    # hierarchical | flat | ring | compressed | auto ("auto" asks the
    # fabric CollectiveTuner for the best schedule for this gang's
    # placement topology and gradient size, re-resolved after every
    # migrate/rescale)
    sync_mode: str = "hierarchical"
    compress_frac: float = 0.05
    checkpoint_every: int = 10
    ckpt_dir: str = "/tmp/repro-ckpt"
    chips_per_host: int = 4           # CPU-fabric host granularity
    incremental_ckpt_every: int = 0
    # fault injection: {step: description}; a failure at step s is detected
    # at the step-s control point and triggers gang restart from the latest
    # checkpoint.
    inject_failures: Dict[int, str] = dataclasses.field(default_factory=dict)
    # elastic schedule: {step: new_world_size}
    rescale_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    pods: int = 1                     # >1: two-level gang (pod, data) mesh
    # gang placement policy on the host fabric (binpack/spread/locality)
    placement_policy: str = "binpack"
    # free-chip-driven elastic policy, consulted at every control point;
    # None = only the explicit rescale_at schedule fires
    elastic: Optional[elastic_mod.ElasticPolicy] = None
    # trace job kind of this gang (mpi-compute/mpi-network/omp); routes
    # the per-kind beta of the shared CostModel into elastic grow probes
    # so they place exactly like a trace placement would
    job_kind: Optional[str] = None


def params_nbytes(tree) -> int:
    """Bytes of one flattened-f32 gradient sync of ``tree`` — the
    message size the CollectiveTuner buckets by."""
    return 4 * sum(l.size for l in jax.tree.leaves(tree))


def resolve_sync_mode(mode: str, handle: GangHandle,
                      params=None) -> str:
    """Concrete schedule for ``make_dp_train_step``: "auto" asks the
    fabric tuner for the gang's current placement/size dispatch."""
    if mode != "auto":
        return mode
    nbytes = params_nbytes(params) if params is not None else None
    return handle.best_sync_mode(nbytes)


def make_dp_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                       mesh: Mesh, mode: str,
                       compress_frac: Optional[float] = None) -> Callable:
    """Gang train step: per-device grads + explicit Faabric-style sync."""
    loss_fn = model_mod.make_loss_fn(cfg)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)
    fast, slow = coll.dp_axes(mesh)
    axes = [a for a in (fast, slow) if a is not None]
    n_total = int(np.prod([mesh.shape[a] for a in axes]))

    def per_device(params, batch, resid):
        (_, metrics), grads = gfn(params, batch)
        rs = resid[0] if mode == "compressed" else None
        synced, new_rs = coll.tree_sync_body(
            grads, mode, fast, slow, n_total, compress_frac, rs)
        metrics = jax.tree.map(
            lambda m: jax.lax.pmean(m, tuple(axes)), metrics)
        return synced, metrics, (new_rs[None] if new_rs is not None
                                 else jnp.zeros((1, 1), jnp.float32))

    dp_spec = P(tuple(a for a in (("pod",) if slow else ()) + (fast,)))
    resid_spec = P(slow, fast) if slow else P(None, fast)

    def train_step(state, batch, resid):
        grads, metrics, new_resid = compat.shard_map(
            per_device, mesh=mesh,
            in_specs=(P(), jax.tree.map(
                lambda _: dp_spec, batch), resid_spec),
            out_specs=(P(), P(), resid_spec),
            check_vma=False)(state["params"], batch, resid)
        params, opt, om = adamw.apply(grads, state["opt"], state["params"],
                                      opt_cfg)
        return ({"params": params, "opt": opt}, {**metrics, **om},
                new_resid)

    return jax.jit(train_step, donate_argnums=(0, 2))


def extra_batch_specs(cfg: ArchConfig, global_batch: int) -> Dict[str, Any]:
    """Modality extras (audio frames / vision tokens) for a batch."""
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct(
            (global_batch, cfg.enc_seq, cfg.d_model), cfg.param_dtype())}
    if cfg.family == "vlm":
        return {"img": jax.ShapeDtypeStruct(
            (global_batch, cfg.n_img_tokens, cfg.d_model),
            cfg.param_dtype())}
    return {}


class FaabricTrainRuntime:
    """End-to-end training driver: a thin loop over one ``GangHandle``.

    The handle owns placement (devices, mesh, GranuleGroup) on a shared
    ``Fabric``; this class owns the training semantics — step function,
    data, checkpoints, and what to do with each control-point ``Action``.
    Pass ``fabric`` to share one fabric between several runtimes/serving
    gangs; by default the runtime builds a private fabric over all local
    devices and binds a whole-fabric gang (the single-tenant special
    case).
    """

    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 data_cfg: dp.DataConfig, rt: RuntimeConfig,
                 devices: Optional[Sequence[Any]] = None,
                 job_id: str = "job0", fabric: Optional[Fabric] = None,
                 priority: int = 0):
        self.cfg, self.opt_cfg, self.data_cfg, self.rt = (cfg, opt_cfg,
                                                          data_cfg, rt)
        self.job_id = job_id
        self.fabric = fabric if fabric is not None else Fabric(
            chips_per_host=rt.chips_per_host, policy=rt.placement_policy)
        gang_devices = list(devices if devices is not None
                            else self.fabric.devices)
        self.handle: GangHandle = self.fabric.bind(
            job_id, gang_devices, priority=priority, pods=rt.pods,
            policy=rt.placement_policy, kind=rt.job_kind)
        self.ckpt = CheckpointManager(
            rt.ckpt_dir, job_id=job_id,
            incremental_every=rt.incremental_ckpt_every)
        # control points consult the elastic probe, so `rescale` arrives
        # as an Action — the same vocabulary the simulator logs
        self.control = ctl.ControlPointRunner(
            checkpoint_every=rt.checkpoint_every,
            elastic_probe=self._elastic_probe)
        self.handle.control = self.control
        self._probe_step = 0
        self.log: List[Dict[str, Any]] = []
        self._step_fn = None
        self._extras = extra_batch_specs(self.cfg,
                                         self.data_cfg.global_batch)

    # ---- placement views (owned by the handle) -------------------------------
    @property
    def devices(self) -> List[Any]:
        return self.handle.devices

    @property
    def mesh(self) -> Mesh:
        return self.handle.mesh

    @property
    def group(self):
        return self.handle.group

    @property
    def engine(self):
        return self.fabric.engine

    def _shardings(self, state):
        rep = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda _: rep, state)

    def _build(self, state=None):
        self.sync_mode = resolve_sync_mode(
            self.rt.sync_mode, self.handle,
            state["params"] if state is not None else None)
        self._step_fn = make_dp_train_step(
            self.cfg, self.opt_cfg, self.mesh, self.sync_mode,
            self.rt.compress_frac)

    def _place_batch(self, batch):
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        s = NamedSharding(self.mesh, P(axes))
        return jax.tree.map(lambda x: jax.device_put(x, s), batch)

    def init_state(self, seed: int = 0):
        key = jax.random.PRNGKey(seed)
        with jax.default_device(self.devices[0]):
            state = model_mod.init_train_state(key, self.cfg, self.opt_cfg)
        return jax.device_put(state, self._shardings(state))

    # ---- control-point actions --------------------------------------------------
    def _elastic_probe(self, world: int) -> Optional[int]:
        """Next world size, or None: the explicit schedule first, then the
        free-chip-driven policy (through the shared engine)."""
        step = self._probe_step
        if step in self.rt.rescale_at:
            # cap at what is actually placeable on the *shared* fabric:
            # this gang's chips plus the currently-idle ones (other
            # tenants' allocations are not ours to take)
            return min(self.rt.rescale_at[step],
                       world + self.fabric.engine.idle_chips())
        if self.rt.elastic is not None:
            return self.rt.elastic.decide(world, self.fabric.engine,
                                          kind=self.rt.job_kind)
        return None

    def _recover(self, state, step):
        """Gang restart from the latest checkpoint (paper §3.4)."""
        restored, ck_step = self.ckpt.restore(
            shardings=self._shardings(state))
        return restored, ck_step

    def _migrate_gang(self, state):
        """Straggler response: live-migrate the gang (paper §3.3) through
        the handle — engine-planned consolidation, or a rank rotation
        when the gang already spans the minimum host count.  The
        GranuleGroup is re-addressed in place, so buffered control-plane
        messages and the migration epoch survive the move (Fig 8)."""
        state, _ = self.handle.migrate(state)
        self._build(state)
        return state

    def _rescale(self, state, resid, new_world: int):
        """Grow/shrink the gang to ``new_world`` chips via the handle:
        chips are released to the shared pool and the placement engine
        carves the new sub-mesh under the configured policy (§2.1)."""
        state = self.handle.rescale(state, new_world)
        self._build(state)
        resid = coll.init_residual_buffer(self.mesh, state["params"])
        return state, resid

    # ---- main loop ----------------------------------------------------------------
    def run(self, seed: int = 0, state=None):
        rt = self.rt
        if state is None:
            state = self.init_state(seed)
        self._build(state)
        resid = coll.init_residual_buffer(self.mesh, state["params"])
        # checkpoint step semantics: "state before running step k"
        self.ckpt.save(0, state, blocking=True)
        step = 0
        losses = {}
        recoveries = rescales = migrations = straggler_migrations = 0
        while step < rt.total_steps:
            # ---- control point A: failure detection before the step ----
            if step in rt.inject_failures and recoveries < 8:
                rt.inject_failures.pop(step, None)
                state, step = self._recover(state, step)
                recoveries += 1
                resid = coll.init_residual_buffer(self.mesh,
                                                  state["params"])
                continue
            t0 = time.time()
            batch = dp.make_batch(self.data_cfg, step, self._extras)
            batch = self._place_batch(batch)
            state, metrics, resid = self._step_fn(state, batch, resid)
            step_time = time.time() - t0
            loss = float(metrics["loss"])
            losses[step] = loss
            self.log.append({"step": step, "loss": loss,
                             "time": step_time,
                             "world": len(self.devices)})
            # ---- control point B (barrier: the grad sync is complete) ----
            self._probe_step = step + 1
            actions = self.handle.control_point(step + 1, step_time)
            for act in actions:
                if act.kind == "checkpoint":
                    self.ckpt.save(step + 1, state, blocking=False)
                elif act.kind == "migrate":
                    state = self._migrate_gang(state)
                    migrations += 1
                    if act.payload.get("reason") == "straggler":
                        straggler_migrations += 1
                elif act.kind == "rescale":
                    state, resid = self._rescale(state, resid,
                                                 act.payload["to"])
                    rescales += 1
            step += 1
        self.ckpt.wait()
        return state, {"losses": [losses[s] for s in sorted(losses)],
                       "recoveries": recoveries, "rescales": rescales,
                       "migrations": migrations,
                       "straggler_migrations": straggler_migrations,
                       "log": self.log}

    def release(self) -> None:
        """Return the gang's chips to the shared fabric."""
        self.handle.release()
