"""Snapshots of job state (paper §3.1).

Faabric snapshots a Granule's WebAssembly linear memory; the TPU adaptation
snapshots the *full training-job state pytree* — params, optimizer moments,
data cursor, step and PRNG key — which recovers a job bit-exactly together
with the deterministic data pipeline.

Snapshots are host-side (numpy) so they survive device failure, can be
diffed (``core.diffsync``), shipped cross-VM (migration), and written to
disk (checkpointing).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import diffsync


def _fingerprint(leaves) -> str:
    h = hashlib.sha256()
    for leaf in leaves:
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class Snapshot:
    """Point-in-time copy of a job's state (the WASM-memory analogue)."""
    job_id: str
    step: int
    state: Any                      # host pytree (numpy leaves)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    fingerprint: str = ""
    wall_time: float = 0.0

    @property
    def nbytes(self) -> int:
        return int(sum(np.asarray(l).nbytes
                       for l in jax.tree_util.tree_leaves(self.state)))


def take(job_id: str, step: int, state, meta: Optional[Dict] = None,
         fingerprint: bool = True) -> Snapshot:
    """Snapshot device state to host memory."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    fp = _fingerprint(jax.tree_util.tree_leaves(host)) if fingerprint else ""
    return Snapshot(job_id=job_id, step=step, state=host,
                    meta=dict(meta or {}), fingerprint=fp,
                    wall_time=time.time())


def restore(snap: Snapshot, shardings=None):
    """Restore a snapshot onto devices.

    ``shardings``: optional pytree of jax.sharding.Sharding matching the
    state structure (the new placement after migration/elastic resize);
    None restores to the default device.
    """
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, snap.state)
    return jax.tree.map(lambda x, s: jax.device_put(x, s),
                        snap.state, shardings)


def delta(parent: Snapshot, child_state, op: str = "overwrite"):
    """Chunk-diff live state against a parent snapshot (incremental
    checkpoint / delta migration payload)."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), child_state)
    return diffsync.diff_tree(parent.state, host, op=op)


def apply_delta(parent: Snapshot, diffs, step: int) -> Snapshot:
    merged = diffsync.apply_tree(parent.state, diffs)
    return Snapshot(job_id=parent.job_id, step=step, state=merged,
                    meta=dict(parent.meta),
                    fingerprint=_fingerprint(
                        jax.tree_util.tree_leaves(merged)),
                    wall_time=time.time())


def verify(a: Snapshot, b: Snapshot) -> bool:
    """Bit-exact equality of two snapshots (migration safety check)."""
    la = jax.tree_util.tree_leaves(a.state)
    lb = jax.tree_util.tree_leaves(b.state)
    return (len(la) == len(lb)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))
