"""Unified model API: ``build(cfg)`` returns init / loss / train_step /
prefill_step / serve_step plus shape specs for every assigned input shape.

This is the single entry point used by the launcher, the dry-run, the
runtime loops, the benchmarks and the tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.layers import (fused_unembed_xent,
                                 fused_unembed_xent_scan)
from repro.optim import adamw

# zamba2's shared attention block uses this sliding window for the
# long_500k shape (sub-quadratic adaptation, DESIGN.md §4).
LONG_CONTEXT_WINDOW = 4096


# ---------------------------------------------------------------------------
# Parameter counting (exact: derived from init shapes via eval_shape)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda k: tf.init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    for path, leaf in flat:
        n = leaf.size
        if active_only and cfg.n_experts:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if "moe" in keys and any(k in ("w1", "w2", "w3") for k in keys):
                n = n * cfg.top_k // cfg.n_experts
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# Batch context plumbing
# ---------------------------------------------------------------------------
def _ctx_from_batch(cfg, batch, **extra):
    ctx = dict(extra)
    if cfg.family == "audio":
        ctx["frames"] = batch["frames"]
    if cfg.family == "vlm":
        ctx["img"] = batch["img"]
    return ctx


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------
def init_train_state(key, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    params = tf.init_params(key, cfg)
    return {"params": params, "opt": adamw.init(params)}


def make_loss_fn(cfg: ArchConfig) -> Callable:
    xent_fn = (fused_unembed_xent_scan if cfg.deploy
               else fused_unembed_xent)

    def loss_fn(params, batch):
        ctx = _ctx_from_batch(cfg, batch, return_hidden=True)
        hidden, aux, _ = tf.forward(params, batch["tokens"], cfg, ctx)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        xent = xent_fn(hidden, head, batch["labels"])
        loss = xent + aux
        return loss, {"loss": loss, "xent": xent, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    grad_accum: int = 1, grad_pspecs=None,
                    batch_pspecs=None) -> Callable:
    """(state, batch) -> (state, metrics).

    ``grad_accum`` splits the global batch into that many microbatches,
    accumulating grads in f32 (unrolled loop: exact HLO FLOP accounting).
    ``grad_pspecs``: optional PartitionSpec tree pinning the accumulator's
    sharding to the params' (the scan carry otherwise risks replication).
    ``batch_pspecs``: PartitionSpec tree of the incoming batch; pins the
    microbatch stack to (None, *batch_spec) — otherwise GSPMD may split the
    data axis across the accumulation dimension.
    """
    loss_fn = make_loss_fn(cfg)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads,
            grad_pspecs)

    def accum_unrolled(params, batch):
        b = batch["tokens"].shape[0]
        mb = b // grad_accum
        grads = metrics = None
        for i in range(grad_accum):
            sl = jax.tree.map(lambda x: x[i * mb:(i + 1) * mb], batch)
            (_, m), g = gfn(params, sl)
            g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            metrics = m if metrics is None else jax.tree.map(
                jnp.add, metrics, m)
        return grads, metrics

    def accum_scan(params, batch):
        # deploy mode: microbatch loop as lax.scan (buffer reuse)
        def split(x):
            b = x.shape[0]
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
        mbs = jax.tree.map(split, batch)
        if batch_pspecs is not None:
            from jax.sharding import PartitionSpec as P
            mbs = jax.tree.map(
                lambda x, spec: jax.lax.with_sharding_constraint(
                    x, P(None, *tuple(spec))),
                mbs, batch_pspecs)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

        def body(carry, mb):
            grads, metrics = carry
            (_, m), g = gfn(params, mb)
            grads = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), grads, g)
            grads = _constrain(grads)
            metrics = jax.tree.map(jnp.add, metrics, m)
            return (grads, metrics), None

        zero_m = {"loss": 0.0, "xent": 0.0, "aux_loss": 0.0}
        zero_m = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), zero_m)
        (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), mbs)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (_, metrics), grads = gfn(params, batch)
        else:
            accum = accum_scan if cfg.deploy else accum_unrolled
            grads, metrics = accum(params, batch)
            grads = jax.tree.map(lambda a: a / grad_accum, grads)
            metrics = jax.tree.map(lambda a: a / grad_accum, metrics)
        new_params, new_opt, om = adamw.apply(grads, state["opt"], params,
                                              opt_cfg)
        return {"params": new_params, "opt": new_opt}, {**metrics, **om}

    return train_step


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ArchConfig, window: int = 0) -> Callable:
    """(params, batch) -> (last_logits (B,1,V), decode states).

    Unembeds ONLY the last position — the (B, S, V) logits tensor of a 32k
    prefill would otherwise dominate HBM (§Perf)."""
    def prefill_step(params, batch):
        ctx = _ctx_from_batch(cfg, batch, collect_state=True, window=window,
                              return_hidden=True)
        hidden, _, states = tf.forward(params, batch["tokens"], cfg, ctx)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = jax.lax.dot_general(
            hidden[:, -1:], head, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return logits, states
    return prefill_step


def make_serve_step(cfg: ArchConfig, window: int = 0) -> Callable:
    """(params, states, tokens (B,1), positions (B,1)) ->
    (logits (B,1,V), new states)."""
    def serve_step(params, states, tokens, positions):
        return tf.decode_step(params, tokens, states, positions, cfg,
                              {"window": window})
    return serve_step


def decode_window(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Sliding window used by attention blocks for this (arch, shape)."""
    if shape.name == "long_500k" and cfg.family == "hybrid":
        return LONG_CONTEXT_WINDOW
    return cfg.window if shape.name == "long_500k" else 0


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for every (arch x shape) cell
# ---------------------------------------------------------------------------
def batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                with_labels: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    specs = {"tokens": sds((b, s), jnp.int32)}
    if with_labels:
        specs["labels"] = sds((b, s), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.param_dtype())
    if cfg.family == "vlm":
        specs["img"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                           cfg.param_dtype())
    return specs


def train_state_specs(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, opt_cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_specs(cfg: ArchConfig):
    return _param_shapes(cfg)


def decode_state_specs(cfg: ArchConfig, shape: ShapeConfig):
    window = decode_window(cfg, shape)
    return jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                     cfg.param_dtype(), window=window))


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    return {"tokens": sds((b, 1), jnp.int32),
            "positions": sds((b, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# build(): one object carrying everything the launcher needs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init_params: Callable
    init_train_state: Callable
    loss_fn: Callable
    make_train_step: Callable
    make_prefill_step: Callable
    make_serve_step: Callable


def build(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(
        cfg=cfg,
        init_params=functools.partial(tf.init_params, cfg=cfg),
        init_train_state=functools.partial(init_train_state, cfg=cfg),
        loss_fn=make_loss_fn(cfg),
        make_train_step=functools.partial(make_train_step, cfg),
        make_prefill_step=functools.partial(make_prefill_step, cfg),
        make_serve_step=functools.partial(make_serve_step, cfg),
    )
