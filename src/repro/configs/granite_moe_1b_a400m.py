"""granite-moe-1b-a400m: 24L d1024 16H (GQA kv=8) d_ff=512/expert, MoE 32e top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    vocab=49155,
    n_experts=32,
    top_k=8,
    rope_theta=10_000.0,
)
