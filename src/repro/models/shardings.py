"""Parameter/batch/state PartitionSpecs for the production meshes.

Rules are path-based and divisibility-aware: a dim is sharded over the
``model`` axis only when the logical structure allows it (e.g. KV-head
projections replicate when n_kv_heads < TP, as in MaxText); everything else
falls back to replication rather than relying on GSPMD to guess.

FSDP (ZeRO-3 style): when ``cfg.fsdp`` is set, the largest remaining
unsharded dim of every large param is additionally sharded over the
``data`` axis (within-pod only — cross-pod parameter gathering would ride
the slow DCI links, so pods keep full replicas; this is the sharding-level
expression of the paper's locality principle).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _keys_of(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "name", None)
        if k is None and hasattr(p, "idx"):
            k = str(p.idx)
        out.append(str(k))
    return tuple(out)


def _logical_rule(keys: Tuple[str, ...], shape: Tuple[int, ...],
                  cfg: ArchConfig, tp: int) -> Tuple[Optional[str], ...]:
    """PartitionSpec entries for the *logical* (unstacked) param."""
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    div = lambda n: n % tp == 0
    rep = (None,) * len(shape)

    if name == "embed":
        if div(cfg.vocab):
            return ("model", None)
        return (None, "model") if div(cfg.d_model) else rep
    if name == "lm_head":
        if div(cfg.vocab):
            return (None, "model")
        return ("model", None) if div(cfg.d_model) else rep

    # attention: shard the head dim when it divides TP; otherwise shard
    # the FLAT (H*hd) dim when that divides — the weights and optimizer
    # state stay distributed and GSPMD reshards the (small) activations at
    # the head reshape (llama3b 24H, whisper 12H, GQA kv<16).
    if name in ("wq",) and parent in ("attn", "xattn"):
        return (None, "model") if (div(cfg.n_heads)
                                   or div(shape[-1])) else rep
    if name in ("wk", "wv") and parent in ("attn", "xattn"):
        return (None, "model") if (div(cfg.n_kv_heads)
                                   or div(shape[-1])) else rep
    if name == "wo" and parent in ("attn", "xattn"):
        return ("model", None) if (div(cfg.n_heads)
                                   or div(shape[0])) else rep

    # dense mlp
    if parent == "mlp" and name in ("w1", "w3"):
        return (None, "model") if div(shape[-1]) else rep
    if parent == "mlp" and name == "w2":
        return ("model", None) if div(shape[0]) else rep

    # MoE (expert parallelism over the model axis)
    if parent == "moe" and name in ("w1", "w2", "w3"):
        return ("model", None, None) if div(cfg.n_experts) else rep
    if parent == "moe" and name == "router":
        return rep

    # Mamba2
    if parent == "mamba":
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_headdim
        if name in ("in_z", "in_x"):
            return (None, "model") if div(d_inner) else rep
        if name == "in_dt":
            return (None, "model") if div(h) else rep
        if name == "conv_x":
            return (None, "model") if div(d_inner) else rep
        if name in ("dt_bias", "a_log", "d_skip"):
            return ("model",) if div(h) else rep
        if name == "norm_w":
            return ("model",) if div(d_inner) else rep
        if name == "out_proj":
            return ("model", None) if div(d_inner) else rep
        return rep                      # in_b/in_c/conv_b/conv_c

    # mLSTM
    if parent == "mlstm":
        du = int(cfg.xlstm_proj_factor * cfg.d_model)
        hd = du // cfg.n_heads
        if name in ("up_x", "up_z", "conv_w"):
            return (None, "model") if div(du) else rep
        if name in ("wq", "wk"):
            # shard on hd_k: score matrices psum (B,q,q,H — small) instead
            # of gathering (B,S,H,hd) activations per chunk (§Perf #9)
            return (None, None, "model") if div(hd) else rep
        if name == "wv":
            return (None, None, "model") if div(hd) else rep
        if name in ("skip", "norm_w"):
            return ("model",) if div(du) else rep
        if name == "down":
            return ("model", None) if div(du) else rep
        return rep                      # wq/wk/wi/wf/bi/bf

    # sLSTM: scanned recurrence, small — replicate
    return rep


def _with_fsdp(spec: Tuple[Optional[str], ...], shape: Tuple[int, ...],
               dp: int, min_size: int = 2 ** 16) -> Tuple[Optional[str], ...]:
    """Shard the largest unsharded dim over 'data' if divisible."""
    if int(np.prod(shape)) < min_size or "data" in spec:
        return spec
    best, best_dim = None, 0
    for i, (s, d) in enumerate(zip(spec, shape)):
        if s is None and d % dp == 0 and d > best_dim:
            best, best_dim = i, d
    if best is None:
        return spec
    out = list(spec)
    out[best] = "data"
    return tuple(out)


def param_pspecs(cfg: ArchConfig, param_shapes, mesh: Mesh):
    """Pytree of PartitionSpec matching the params structure."""
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = []
    for path, leaf in flat:
        keys = _keys_of(path)
        stacked = keys[0] in ("blocks", "encoder")
        shape = tuple(leaf.shape)
        logical = shape[1:] if stacked else shape
        spec = _logical_rule(keys, logical, cfg, tp)
        if cfg.fsdp and dp > 1:
            full = ((None,) + spec) if stacked else spec
            full_shape = shape
            spec = _with_fsdp(full, full_shape, dp)
            specs.append(P(*spec))
            continue
        if stacked:
            spec = (None,) + spec
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_pspecs(cfg: ArchConfig, state_shapes, mesh: Mesh):
    """Train-state specs.

    Optimizer moments additionally shard over ``data`` (ZeRO-1): unlike
    FSDP'd *weights* they are touched once per step at the update, so
    there is no per-layer gather for XLA to hoist; the update itself runs
    sharded and new params all-gather once.  This is what keeps the
    9B-class train cells inside 16 GB without blanket FSDP."""
    pspecs = param_pspecs(cfg, state_shapes["params"], mesh)
    dp = mesh.shape.get("data", 1)
    flat_p, treedef = jax.tree_util.tree_flatten(pspecs)
    flat_s = jax.tree_util.tree_leaves(state_shapes["params"])
    opt_specs = []
    for spec, leaf in zip(flat_p, flat_s):
        full = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        opt_specs.append(P(*_with_fsdp(full, tuple(leaf.shape), dp))
                         if dp > 1 else spec)
    ospecs = jax.tree_util.tree_unflatten(treedef, opt_specs)
    return {"params": pspecs,
            "opt": {"m": ospecs, "v": ospecs, "step": P()}}


def _dp_if_divisible(mesh: Mesh, batch: int):
    dpx = dp_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dpx])) if dpx else 1
    return dpx if (n > 1 and batch % n == 0) else ()


def batch_pspecs(cfg: ArchConfig, batch_shapes, mesh: Mesh):
    out = {}
    for k, v in batch_shapes.items():
        dpx = _dp_if_divisible(mesh, v.shape[0])
        out[k] = P(dpx, *([None] * (len(v.shape) - 1)))
    return out


def decode_state_pspecs(cfg: ArchConfig, state_shapes, mesh: Mesh):
    """Specs for stacked decode states (leading dim = n_periods).

    KV caches shard batch over dp and kv-heads over model when divisible;
    with kv < TP the cache *sequence* dim shards over model instead
    (flash-decoding style: XLA distributes the softmax over key shards).
    SSM/xLSTM states shard their head/value dims over model.
    """
    tp = mesh.shape.get("model", 1)
    d_inner = cfg.ssm_expand * cfg.d_model if cfg.ssm_state else 0
    ssm_h = d_inner // cfg.ssm_headdim if cfg.ssm_state else 0
    du = int(cfg.xlstm_proj_factor * cfg.d_model)
    mhd = du // cfg.n_heads

    def leaf_spec(path, leaf):
        keys = _keys_of(path)
        name = keys[-1]
        nd = len(leaf.shape)
        dpx = _dp_if_divisible(mesh, leaf.shape[1])
        if name in ("k", "v", "xk", "xv"):       # (P,B,S,kv,hd)
            if cfg.n_kv_heads % tp == 0:
                return P(None, dpx, None, "model", None)
            if leaf.shape[2] % tp == 0:          # shard cache sequence
                return P(None, dpx, "model", None, None)
            return P(None, dpx, None, None, None)
        if name == "ssm":                        # (P,B,H,Pd,N)
            h_ax = "model" if ssm_h and ssm_h % tp == 0 else None
            return P(None, dpx, h_ax, None, None)
        if name == "conv_x":                     # (P,B,K,d_inner)
            ax = "model" if d_inner and d_inner % tp == 0 else None
            return P(None, dpx, None, ax)
        if name in ("conv_b", "conv_c"):
            return P(None, dpx, None, None)
        if name == "c" and nd == 5:              # (P,B,H,hdv,hdk)
            ax = "model" if mhd % tp == 0 else None
            return P(None, dpx, None, ax, None)
        if name == "conv" and nd == 4:           # (P,B,K,du)
            ax = "model" if du % tp == 0 else None
            return P(None, dpx, None, ax)
        # n (P,B,H,hdk), m (P,B,H), slstm states (P,B,d)
        return P(None, dpx, *([None] * (nd - 2)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
