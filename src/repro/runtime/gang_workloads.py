"""Gang workloads for trace-driven live execution (``Fabric.run_trace``).

The simulator's discrete-event loop decides *when and where* each trace
job runs (placement, priorities, preemption); these workloads are the
*what* — real jax computations stepped one control point at a time so
concurrent gangs interleave on one fabric:

* ``TrainWorkload`` — a data-parallel training gang (the step machinery
  of ``runtime.train_loop`` without its driver loop).  State = the train
  state pytree; bit-exact across migrate/preempt because the data
  pipeline is (seed, step)-keyed.
* ``ServeWorkload`` — a continuously-batched serving replica
  (``runtime.serve_loop.ContinuousServeLoop``): every step admits due
  arrivals into free slots (mid-generation joins), then decodes one
  token for each occupied lane.  State = the serving state (params +
  slot buffers + cursors + slot bookkeeping), so the same snapshot
  machinery moves a partially-occupied batch.

``workload_factory`` maps trace jobs to workloads by ``Job.workload``
("train" | "serve", falling back on job kind: omp → serve, mpi → train)
— the default factory for tests, benchmarks and examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import collectives as coll
from repro.core.fabric import GangHandle, GangWorkload
from repro.core.simulator import Job
from repro.data import pipeline as dp
from repro.models import model as model_mod
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime.serve_loop import ContinuousServeLoop, Request
from repro.runtime.train_loop import (extra_batch_specs, make_dp_train_step,
                                      resolve_sync_mode)


class TrainWorkload(GangWorkload):
    """One training gang stepped at control-point granularity."""

    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 data_cfg: dp.DataConfig, total_steps: int = 4,
                 sync_mode: str = "hierarchical",
                 compress_frac: float = 0.05, seed: int = 0):
        self.cfg, self.opt_cfg, self.data_cfg = cfg, opt_cfg, data_cfg
        self.total_steps = total_steps
        self.sync_mode = sync_mode
        self.compress_frac = compress_frac
        self.seed = seed
        self.state = None
        self.resid = None
        self.steps_done = 0
        self.losses: list = []
        self._step_fn = None
        self._extras = extra_batch_specs(cfg, data_cfg.global_batch)

    def bind(self, handle: GangHandle) -> None:
        # the global batch must divide over the gang; trace jobs come in
        # arbitrary world sizes, so snap the batch to the nearest
        # divisible size (per-device share of the configured batch, at
        # least one row per device).  The world size is stable across
        # preempt/resume, so each job's data stream stays deterministic.
        world = len(handle.devices)
        per = max(1, self.data_cfg.global_batch // world)
        if self.data_cfg.global_batch != per * world:
            self.data_cfg = dataclasses.replace(self.data_cfg,
                                                global_batch=per * world)
            self._extras = extra_batch_specs(self.cfg,
                                             self.data_cfg.global_batch)
        mode = resolve_sync_mode(
            self.sync_mode, handle,
            self.state["params"] if self.state is not None else None)
        self._step_fn = make_dp_train_step(
            self.cfg, self.opt_cfg, handle.mesh, mode,
            self.compress_frac)
        if self.state is not None:
            self.resid = coll.init_residual_buffer(handle.mesh,
                                                   self.state["params"])

    def init_state(self, handle: GangHandle) -> None:
        key = jax.random.PRNGKey(self.seed)
        with jax.default_device(handle.devices[0]):
            state = model_mod.init_train_state(key, self.cfg, self.opt_cfg)
        rep = NamedSharding(handle.mesh, P())
        self.state = jax.tree.map(lambda x: jax.device_put(x, rep), state)
        self.resid = coll.init_residual_buffer(handle.mesh,
                                               self.state["params"])

    def run_step(self, handle: GangHandle) -> Dict[str, Any]:
        batch = dp.make_batch(self.data_cfg, self.steps_done, self._extras)
        axes = tuple(a for a in ("pod", "data")
                     if a in handle.mesh.axis_names)
        s = NamedSharding(handle.mesh, P(axes))
        batch = jax.tree.map(lambda x: jax.device_put(x, s), batch)
        self.state, metrics, self.resid = self._step_fn(self.state, batch,
                                                        self.resid)
        self.steps_done += 1
        loss = float(metrics["loss"])
        self.losses.append(loss)
        return {"loss": loss, "step": self.steps_done,
                "world": len(handle.devices)}


class ServeWorkload(GangWorkload):
    """One continuously-batched serving gang.

    ``Request.arrival`` is expressed in *steps*: each ``run_step`` first
    admits every due request a free slot can take — mid-generation
    joins, so the batch is usually partially occupied — then decodes one
    token for all occupied lanes.  ``done`` is demand-driven: the gang
    finishes when every request has all its tokens, not at a fixed step
    count.  Admission is a pure function of (slot state, steps_done),
    so a rollback to an earlier snapshot replays the same joins and the
    same tokens — bit-exact resume with mixed occupied/free slots.
    """

    def __init__(self, cfg: ArchConfig,
                 requests: Optional[Sequence[Request]] = None,
                 prompt_len: int = 8, new_tokens: int = 4, batch: int = 2,
                 slots: int = 0, max_len: int = 32, seed: int = 0):
        self.cfg = cfg
        self.max_len = max_len
        self.seed = seed
        if requests is None:
            # ragged prompts + staggered arrivals: the default stream
            # exercises mid-generation joins even in tiny trace tests
            rng = np.random.default_rng(seed)
            requests = [Request(rid=i,
                                prompt=rng.integers(
                                    0, cfg.vocab,
                                    max(1, prompt_len - (i % 2)),
                                    dtype=np.int32),
                                max_new_tokens=new_tokens,
                                arrival=float(i))
                        for i in range(batch)]
        self.requests = list(requests)
        self.slots = int(slots) or max(1, min(len(self.requests), 2))
        # worst-case serial-wave bound; informational (``done`` rules)
        waves = -(-len(self.requests) // self.slots)
        self.total_steps = (1 + int(max(r.arrival for r in self.requests))
                            + waves * max(r.max_new_tokens
                                          for r in self.requests))
        self.steps_done = 0
        self.state = None
        self.loop: Optional[ContinuousServeLoop] = None

    @property
    def done(self) -> bool:
        if self.loop is None or self.steps_done == 0:
            return False
        fin = set(self.loop.done_rids)
        return all(r.rid in fin for r in self.requests)

    def bind(self, handle: GangHandle) -> None:
        if self.loop is None:
            params = jax.jit(lambda k: tf.init_params(k, self.cfg))(
                jax.random.PRNGKey(self.seed))
            self.loop = ContinuousServeLoop(self.cfg, params,
                                            slots=self.slots,
                                            max_len=self.max_len)
        # adopt the new placement (and any restored snapshot) in one move
        self.loop.attach(handle, state=self.state)
        if self.state is not None:
            self._reconcile()
        self.state = self.loop.serve_state()

    def _reconcile(self) -> None:
        """Re-link caller-owned requests after a restore: occupied lanes
        roll their outputs back to the snapshot's decoded prefix,
        finished rids keep theirs, everything else re-queues from
        scratch (a post-snapshot admit must fully replay)."""
        keep = set(self.loop.occupied_rids()) | set(self.loop.done_rids)
        self.loop.adopt_requests(self.requests)
        for r in self.requests:
            if r.rid not in keep:
                r.out.clear()

    def init_state(self, handle: GangHandle) -> None:
        self.state = self.loop.serve_state()

    def run_step(self, handle: GangHandle) -> Dict[str, Any]:
        taken = set(self.loop.occupied_rids()) | set(self.loop.done_rids)
        for r in self.requests:         # due arrivals join mid-generation
            if r.rid in taken or r.arrival > self.steps_done:
                continue
            if self.loop.admit(r) is None:
                break                   # batch full — retry next step
        self.loop.decode_step()
        self.state = self.loop.serve_state()
        self.steps_done += 1
        return {"decoded": self.loop.stats.decoded_tokens,
                "active": self.loop.active,
                "admitted": self.loop.stats.admitted,
                "step": self.steps_done,
                "outputs": [list(r.out) for r in self.requests]}


def workload_factory(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                     data_cfg: dp.DataConfig, train_steps: int = 3,
                     serve_tokens: int = 3
                     ) -> Callable[[Job], GangWorkload]:
    """Default ``Job -> GangWorkload`` mapping for ``Fabric.run_trace``:
    ``Job.workload`` wins; otherwise omp jobs serve, mpi jobs train."""

    def make(job: Job) -> GangWorkload:
        kind = job.workload or ("serve" if job.kind == "omp" else "train")
        if kind == "serve":
            return ServeWorkload(cfg, new_tokens=serve_tokens,
                                 prompt_len=data_cfg.seq_len,
                                 batch=min(2, data_cfg.global_batch),
                                 max_len=data_cfg.seq_len + serve_tokens + 1,
                                 seed=job.priority + 1)
        return TrainWorkload(cfg, opt_cfg, data_cfg,
                             total_steps=train_steps)
    return make
