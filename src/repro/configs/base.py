"""Architecture + run configuration dataclasses.

Every assigned architecture gets one file in this package defining an
``ArchConfig``; ``registry.py`` resolves ``--arch <id>`` strings.  Shapes
(train/prefill/decode/long-decode) are defined here as well, so every
(arch x shape) cell used by the dry-run and benchmarks is well defined.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Block kinds making up the unified stack.
# ---------------------------------------------------------------------------
ATTN = "attn"            # GQA attention + MLP (dense transformer block)
MOE = "moe"              # GQA attention + MoE FFN
MAMBA = "mamba"          # Mamba2 SSM block
SHARED_ATTN = "shared_attn"  # zamba2: shared-weight attention block
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
CROSS_ATTN = "cross_attn"    # vlm: cross-attention to image embeddings + MLP
ENCDEC = "encdec"        # audio decoder block: self-attn + cross-attn + MLP


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Unified architecture description for the model zoo."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden size (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM / hybrid ---
    ssm_state: int = 0               # Mamba2 state dimension N
    ssm_expand: int = 2              # Mamba2 expansion factor
    ssm_headdim: int = 64            # Mamba2 head dim P
    ssm_chunk: int = 256             # chunked-scan chunk length
    shared_attn_every: int = 0       # zamba2: shared attn block period

    # --- xLSTM ---
    slstm_every: int = 0             # 1-in-k blocks are sLSTM (xLSTM[7:1] -> 8)
    xlstm_proj_factor: float = 2.0   # mLSTM up-projection factor

    # --- encoder-decoder (audio) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend: frames provided pre-embedded

    # --- VLM ---
    cross_attn_every: int = 0        # a cross-attn layer every k layers
    n_img_tokens: int = 0            # stub vision tower output length

    # --- common ---
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    window: int = 0                  # sliding-window attention (0 = full)
    dtype: str = "bfloat16"

    # --- runtime/dist knobs (overridable per run) ---
    remat: bool = True
    scan_layers: bool = True         # scan over layers (False = unroll, for analysis)
    fsdp: bool = False               # ZeRO-3 style param sharding over data axis
    use_pallas_kernels: bool = False # TPU deployment path; CPU uses jnp reference
    sequence_parallel: bool = False  # shard sequence over data axis (long prefill)
    deploy: bool = False             # True: lax.scan inner loops (deployable
                                     # artifact, realistic memory); False:
                                     # unrolled python loops (exact HLO FLOPs)
    bf16_tp_reduce: bool = False     # row-parallel matmul partial sums kept
                                     # bf16 so TP all-reduces move half the
                                     # bytes (Megatron-style; see §Perf)

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- block layout -----------------------------------------------------
    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "moe":
                kinds.append(MOE)
            elif self.family == "hybrid":
                if self.shared_attn_every and (i % self.shared_attn_every
                                               == self.shared_attn_every - 1):
                    kinds.append(SHARED_ATTN)
                else:
                    kinds.append(MAMBA)
            elif self.family == "ssm":
                if self.slstm_every and i % self.slstm_every == 0:
                    kinds.append(SLSTM)
                else:
                    kinds.append(MLSTM)
            elif self.family == "vlm":
                if self.cross_attn_every and (i % self.cross_attn_every
                                              == self.cross_attn_every - 1):
                    kinds.append(CROSS_ATTN)
                else:
                    kinds.append(ATTN)
            elif self.family == "audio":
                kinds.append(ENCDEC)
            else:  # dense
                kinds.append(ATTN)
        return tuple(kinds)

    def period(self) -> Tuple[str, ...]:
        """Block-kind pattern of one super-block period.

        The stack is ``n_periods`` repetitions of this pattern; params are
        stacked per period position, so ``lax.scan`` runs over periods even
        for heterogeneous (hybrid/ssm/vlm) stacks.
        """
        kinds = self.block_kinds()
        if self.family == "hybrid" and self.shared_attn_every:
            p = self.shared_attn_every
        elif self.family == "ssm" and self.slstm_every:
            p = self.slstm_every
        elif self.family == "vlm" and self.cross_attn_every:
            p = self.cross_attn_every
        else:
            p = 1
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        pat = kinds[:p]
        assert kinds == pat * (self.n_layers // p)
        return pat

    def n_periods(self) -> int:
        return self.n_layers // len(self.period())

    def is_subquadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?"""
        return self.family in ("ssm", "hybrid")

    def param_dtype(self):
        return jnp.dtype(self.dtype)

    # ---- analytic parameter counts (for roofline MODEL_FLOPS) -------------
    def n_params(self) -> int:
        """Total parameter count (analytic, matches init exactly)."""
        from repro.models.model import count_params  # lazy, avoids cycle
        return count_params(self)

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: only top_k experts count)."""
        from repro.models.model import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is paired with all four.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason when skipped."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, ("pure full-attention arch: 500k dense KV decode is "
                       "super-linear in state; skipped per DESIGN.md "
                       "SS4 shape-skips")
    return True, ""
