"""Fused expert-FFN kernel (Pallas): the MoE hot loop.

After capacity dispatch, expert inputs are a dense (E, M, d) tensor
(M = groups x capacity).  This kernel runs the whole SwiGLU expert FFN —
h = silu(x @ w1) * (x @ w3); y = h @ w2 — in VMEM per (expert, M-tile)
block, so the (M, ff) hidden activations never round-trip to HBM (the
reference path writes h twice and reads it once: 3 x M x ff x 2 bytes of
traffic that this kernel eliminates; see EXPERIMENTS.md §Perf).

Grid: (E, M/bm) — experts parallel, M-tiles parallel; the ff dimension is
processed in a VMEM loop with an f32 accumulator for y.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

BLOCK_M = 128
BLOCK_F = 512


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, y_ref, acc_scr, *,
                act: str, n_f_blocks: int):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)                 # (bm, d)
    w1 = w1_ref[0].astype(jnp.float32)               # (d, bf)
    w2 = w2_ref[0].astype(jnp.float32)               # (bf, d)
    h = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "silu":
        w3 = w3_ref[0].astype(jnp.float32)
        up = jax.lax.dot_general(x, w3, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        h = jax.nn.silu(h) * up
    else:
        h = jax.nn.gelu(h)
    acc_scr[...] += jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(fi == n_f_blocks - 1)
    def _finish():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_m", "block_f",
                                             "interpret"))
def expert_ffn(x, w1, w2, w3, *, act: str = "silu",
               block_m: int = BLOCK_M, block_f: int = BLOCK_F,
               interpret: bool = False):
    """x: (E, M, d); w1/w3: (E, d, ff); w2: (E, ff, d) -> (E, M, d)."""
    e, m, d = x.shape
    ff = w1.shape[-1]
    block_m = min(block_m, m)
    block_f = min(block_f, ff)
    assert m % block_m == 0 and ff % block_f == 0
    nf = ff // block_f
    grid = (e, m // block_m, nf)
    kernel = functools.partial(_ffn_kernel, act=act, n_f_blocks=nf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, d), lambda ee, mi, fi: (ee, mi, 0)),
            pl.BlockSpec((1, d, block_f), lambda ee, mi, fi: (ee, 0, fi)),
            pl.BlockSpec((1, d, block_f), lambda ee, mi, fi: (ee, 0, fi)),
            pl.BlockSpec((1, block_f, d), lambda ee, mi, fi: (ee, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, d),
                               lambda ee, mi, fi: (ee, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((e, m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w1, w3, w2)
