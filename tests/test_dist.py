"""Distributed-runtime tests on an 8-device host fabric.

Each test runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the main pytest process keeps its 1-device view (the
dry-run instructions require the flag NOT be set globally)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_collective_modes_agree():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import collectives as C
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 3)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (8, 7))}
        expect = jax.tree.map(lambda x: jnp.broadcast_to(x.mean(0), x.shape),
                              tree)
        for mode in ("flat", "hierarchical", "ring"):
            f = C.build_tree_allreduce(mesh, mode=mode)
            out, _ = jax.jit(f)(tree)
            for o, e in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
                np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                           atol=1e-5)
        print("modes-ok")
    """))


def test_compressed_allreduce_error_feedback_converges():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import collectives as C
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        tree = {"g": jax.random.normal(jax.random.PRNGKey(0), (8, 64))}
        f = jax.jit(C.build_tree_allreduce(mesh, mode="compressed",
                                           compress_frac=0.25))
        resid = C.init_residual_buffer(mesh, jax.tree.map(lambda x: x[0],
                                                          tree))
        total = jnp.zeros((8, 64))
        # repeated sync of the SAME grads: EF must deliver the full mean
        for _ in range(8):
            out, resid = f(tree, resid)
            total = total + out["g"]
        mean = jnp.broadcast_to(tree["g"].mean(0), (8, 64))
        err = float(jnp.abs(total / 8 - mean).max())
        assert err < 0.2, err
        print("ef-ok", err)
    """))


def test_runtime_failure_recovery_bit_exact():
    print(run_sub("""
        import shutil, numpy as np
        shutil.rmtree("/tmp/repro-t-rec", ignore_errors=True)
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import (FaabricTrainRuntime,
                                              RuntimeConfig)
        cfg = reduced_config("llama3.2-1b").with_(n_layers=2, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        base = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=10, checkpoint_every=4,
            ckpt_dir="/tmp/repro-t-rec/a")).run(seed=0)[1]
        failed = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=10, checkpoint_every=4,
            ckpt_dir="/tmp/repro-t-rec/b",
            inject_failures={6: "x"})).run(seed=0)[1]
        assert failed["recoveries"] == 1
        np.testing.assert_allclose(base["losses"], failed["losses"],
                                   atol=1e-6)
        print("recovery-ok")
    """))


def test_runtime_elastic_rescale_loss_invariant():
    print(run_sub("""
        import shutil, numpy as np
        shutil.rmtree("/tmp/repro-t-el", ignore_errors=True)
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import (FaabricTrainRuntime,
                                              RuntimeConfig)
        cfg = reduced_config("llama3.2-1b").with_(n_layers=2, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        base = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=8, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-el/a")).run(seed=0)[1]
        el = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=8, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-el/b",
            rescale_at={4: 4})).run(seed=0)[1]
        assert el["rescales"] == 1
        np.testing.assert_allclose(base["losses"], el["losses"], atol=1e-5)
        print("elastic-ok")
    """))


def test_migration_between_device_sets_bit_exact():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import migration, snapshot as snap_mod
        from repro.core.elastic import make_dp_mesh, replicated_shardings
        devs = jax.devices()
        state = {"w": jnp.arange(100000, dtype=jnp.float32),
                 "m": {"v": jnp.ones((13, 7))}}
        src = make_dp_mesh(devs[:4])
        state = jax.device_put(state, replicated_shardings(state, src))
        dst = make_dp_mesh(devs[4:])
        moved, stats = migration.migrate_via_snapshot(
            "j", 3, state, replicated_shardings(state, dst))
        assert migration.verify_migration(state, moved)
        # delta migration against a prior snapshot moves fewer bytes
        prior = snap_mod.take("j", 3, state)
        state2 = {"w": state["w"].at[5].add(1.0), "m": state["m"]}
        moved2, stats2 = migration.migrate_via_snapshot(
            "j", 4, state2, replicated_shardings(state, dst), prior=prior)
        assert stats2["moved_bytes"] < stats2["full_bytes"] / 2
        assert migration.verify_migration(state2, moved2)
        print("migration-ok", stats2["moved_bytes"], stats2["full_bytes"])
    """))


def test_two_pod_hierarchical_matches_flat_training():
    print(run_sub("""
        import shutil, numpy as np
        shutil.rmtree("/tmp/repro-t-pod", ignore_errors=True)
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import (FaabricTrainRuntime,
                                              RuntimeConfig)
        cfg = reduced_config("llama3.2-1b").with_(n_layers=2, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        ref = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=5, checkpoint_every=100, sync_mode="flat",
            ckpt_dir="/tmp/repro-t-pod/a")).run(seed=0)[1]
        hier = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=5, checkpoint_every=100, pods=2,
            sync_mode="hierarchical",
            ckpt_dir="/tmp/repro-t-pod/b")).run(seed=0)[1]
        np.testing.assert_allclose(ref["losses"], hier["losses"], atol=1e-5)
        print("pod-ok")
    """))


def test_straggler_triggers_live_migration():
    print(run_sub("""
        import shutil, numpy as np
        shutil.rmtree("/tmp/repro-t-strag", ignore_errors=True)
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.train_loop import (FaabricTrainRuntime,
                                              RuntimeConfig)
        cfg = reduced_config("llama3.2-1b").with_(n_layers=2, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        base = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=8, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-strag-b")).run(seed=0)[1]
        # straggler path: EWMA detector fires -> _migrate_gang reshards the
        # gang onto a rotated placement mid-run; losses must be unchanged
        rt = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=8, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-strag"))
        # deterministic detector firing: feed synthetic step times
        det = rt.control.straggler
        for t in (1.0, 1.0, 1.0):
            assert not det.observe(t)
        fired = [det.observe(5.0) for _ in range(det.patience)]
        assert fired[-1], "EWMA straggler detector must fire"
        # exercise the live-migration machinery at a control point
        state = rt.init_state(seed=0)
        rt._build()
        before = [d.id for d in rt.devices]
        state = rt._migrate_gang(state)
        after = [d.id for d in rt.devices]
        assert before != after and sorted(before) == sorted(after)
        out = rt.run(seed=0, state=state)[1]
        np.testing.assert_allclose(base["losses"], out["losses"],
                                   atol=1e-5)
        print("straggler-migration-ok", before, "->", after)
    """))
