"""jit'd wrapper matching the model's mLSTM call signature."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q, k, v, logi, logf, *, chunk: int = 128,
          interpret: bool | None = None):
    """Model layout: q/k/v (B,L,H,hd); logi/logf (B,L,H).

    Returns h (B,L,H,hd) and state tuple (c (B,H,hd,hd), n (B,H,hd),
    m (B,H)) — same as ``models.xlstm.mlstm_chunked``."""
    if interpret is None:
        interpret = _interpret_default()
    move = lambda x: jnp.moveaxis(x, 2, 1)
    h, c, n, m = _k.mlstm_scan(
        move(q), move(k), move(v),
        jnp.moveaxis(logi, 2, 1)[..., None],
        jnp.moveaxis(logf, 2, 1)[..., None],
        chunk=chunk, interpret=interpret)
    return jnp.moveaxis(h, 1, 2), (c, n[:, :, 0, :], m[:, :, 0, 0])
