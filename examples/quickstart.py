"""Quickstart: train a reduced llama3.2-1b for 30 steps with the Faabric
gang runtime (Granules, hierarchical grad sync, checkpoints), then serve it.

Run:
    PYTHONPATH=src python examples/quickstart.py
Multi-granule (8 Granules on the host fabric):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.serve_loop import Request, ServeLoop
from repro.runtime.train_loop import FaabricTrainRuntime, RuntimeConfig


def main():
    cfg = reduced_config("llama3.2-1b")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=30)
    rt = RuntimeConfig(total_steps=30, checkpoint_every=10,
                       ckpt_dir="/tmp/repro-quickstart",
                       sync_mode="hierarchical")

    runtime = FaabricTrainRuntime(cfg, ocfg, dcfg, rt)
    print(f"training on {len(runtime.devices)} Granule(s); "
          f"mesh={dict(runtime.mesh.shape)}")
    state, out = runtime.run(seed=0)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps")
    assert out["losses"][-1] < out["losses"][0]

    # serve the trained params
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16,
                                               dtype=np.int32),
                    max_new_tokens=8) for i in range(2)]
    loop = ServeLoop(cfg, state["params"], max_len=64)
    done = loop.run(reqs)
    print("generated:", done[0].out)


if __name__ == "__main__":
    main()
