"""Policy-driven gang placement on a shared cluster (paper §3.4, §6.2).

This is the single code path behind every placement decision in the repo:
the discrete-event simulator (paper Fig 10/11/14), the live runtime's
sub-mesh carving / rescale / migrate control-point actions, and the
scheduler facade in ``core.scheduler``.  The split is:

* ``CostModel`` — the one job-time model every layer consumes::

      T = (W / Σ_h n_h·s_h) · (1 + beta_kind · chi)

  with per-host speed factors ``s_h`` (mixed host generations) and a
  per-job-kind cross-host penalty ``beta`` calibrated from the paper's
  Fig 14 microbenchmarks (compute-bound 0.4, network-bound 13.0).
  Policies rank candidate placements by it, the simulator's job rates
  integrate it, and the engine's migration/preemption plans cost moves
  with it — so simulated and live decisions stay placement-for-placement
  identical.

* ``PlacementPolicy`` — a pure function from a free-chip snapshot
  (``ClusterView``) to a gang placement ``[(host, n_chips)]``.  Shipped
  policies:

  - ``binpack``      Faabric's default: greedy most-free-first so the gang
                     spans as few hosts as possible (the seed behaviour);
                     on heterogeneous fleets "most free" is measured in
                     effective throughput ``free_h·s_h``.
  - ``spread``       round-robin chips over hosts (load balancing),
                     throughput-weighted on heterogeneous fleets.
  - ``fixed-slice``  the §6.2 k-containers-per-VM baselines: whole slices
                     of ``slice_size`` chips, never shared between jobs.
  - ``locality``     scores candidate placements by the full predicted
                     ``T`` of the cost model and picks the minimiser,
                     tie-breaking on chips stranded on touched hosts
                     (best-fit) so large contiguous blocks survive for
                     later gangs.  On homogeneous fleets ``Σ n_h·s_h``
                     is constant across candidates, so the score
                     degenerates to the slowdown ``(1 + beta·chi)``
                     exactly as before the CostModel refactor.

* ``PlacementEngine`` — owns the mutable cluster state: free-chip
  accounting, gang allocation, preemption-safe reservations (hold chips
  before binding a job so multi-step decisions are atomic), migration
  planning at barrier points, and adoption of externally-created
  placements (``bind``, used by the live runtime).  Hosts default to
  ``chips_per_host`` chips each; ``capacities`` overrides per-host chip
  counts (a ragged last host on the CPU fabric) and ``speeds`` carries
  per-host speed factors (mixed host generations).

* ``PreemptPolicy`` — victim selection when a high-priority arrival
  cannot be placed: evict the cheapest set of strictly-lower-priority
  gangs (checkpoint + requeue is the *caller's* job — the engine only
  plans).  Used by the simulator's priority traces and by
  ``core.fabric.Fabric`` for live preemption.

* ``ShardedPlacementEngine`` — the decentralised scheduler (Fig 11 fix):
  the fleet is partitioned into host-group shards; a placement decision
  consults a cheap per-shard summary index (idle chips, idle
  throughput, max contiguous free block) and then runs the policy on
  the chosen shard's O(hosts_per_shard) slice only, forwarding to other
  shards (counted as ``decision_hops``) when the home shard cannot fit
  the gang.  With one shard covering the whole fleet every decision is
  bit-identical to the centralised ``PlacementEngine``.

The placement hot path (host ordering, greedy fills, candidate scoring)
is vectorized with numpy; the original pure-Python loops survive under
``reference_loops()`` so parity tests and the scheduler-scale benchmark
can A/B the exact pre-vectorization behaviour.

**Fleet churn** (``core.fleet`` drives it): the host set is no longer
immutable after construction.  ``add_hosts`` leases new hosts into the
fleet, ``drain_hosts`` begins a lease reclaim (the host takes no new
placements; its free chips are returned to the provider immediately and
held chips follow as gangs leave), ``fail_hosts`` is a hard failure
(every gang touching a failed host loses its allocation — the caller
requeues it from its last checkpoint), and ``evacuation_plan`` plans
moves off doomed hosts (the graceful-drain path, applied through the
same ``apply_migration`` machinery as barrier migration).  With no
churn (``draining`` never set, host count constant) every decision is
bit-identical to the pre-churn engine — pinned by tests.

**Risk-aware placement** (DESIGN.md §13): the engine carries per-host
lease-expiry times, online hazard estimates (fed from observed
``FleetEvent`` history via ``core.fleet.HazardEstimator``), and
blast-radius group ids.  With ``CostModel.risk_tau_s`` opted in, views
grow a ``RiskContext`` and every policy steers gangs away from
short-lease / historically-flaky hosts in proportion to the expected
lost work of landing there (blast-correlated hazard × half a
checkpoint interval); per-kind ``risk_weights`` let cheap restartable
work soak up risky capacity at weight 0.  Default-off keeps every
decision bit-identical to the risk-blind engine — pinned by tests.
``shrink_plan`` is the recovery half: the largest shrunken world of a
stranded gang that still fits on surviving capacity, tried before any
checkpoint rollback.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import (Dict, List, Mapping, Optional, Sequence, Tuple, Union)

import numpy as np

from repro.core import comms, telemetry

Placement = List[Tuple[int, int]]          # [(host, n_chips)] sorted

# Default host-group size for the sharded engine: the latency sweet spot
# in the Fig 11 regime (a 128-host fleet becomes 8 shards of 16).
DEFAULT_SHARD_HOSTS = 16


def auto_shard_hosts(hosts: int) -> int:
    """Adaptive shard size: ``hosts_per_shard ~ sqrt(2 * hosts)``.

    One decision pays ``O(hosts_per_shard)`` to scan its shard plus
    ``O(hosts / hosts_per_shard)`` summary-index entries when it has to
    forward — the sum is minimised at the square root, and the factor 2
    calibrates the optimum to the Fig 11 sweet spot measured in the
    scheduler-scale benchmark (128 hosts -> 16-host shards).  The
    sharded engine recomputes this as churn changes the live host count
    when built with ``hosts_per_shard="auto"``."""
    return max(2, min(hosts, int(round(math.sqrt(2.0 * hosts)))))

# When False, the placement hot path runs the original pre-vectorization
# implementation: pure-Python per-host/per-chip fill loops, per-call
# policy re-resolution, copied views, and per-call O(hosts) summary
# recomputation instead of the incremental counters.  Decisions are
# bit-identical either way (pinned by tests); the flag exists so
# bench_scheduler_scale can measure the speedup against the real pre-PR
# implementation and so a parity failure would be directly bisectable.
_VECTORIZED = True


@contextlib.contextmanager
def reference_loops():
    """Run the placement hot path on the pre-vectorization loop
    implementation (A/B baseline for benchmarks and parity tests)."""
    global _VECTORIZED
    prev = _VECTORIZED
    _VECTORIZED = False
    try:
        yield
    finally:
        _VECTORIZED = prev


def placement_cross_host_fraction(placement: Sequence[Tuple[int, int]]
                                  ) -> float:
    """chi = P[two random ranks sit on different hosts] — the collective
    slow-path fraction used by the simulator's time model."""
    n = sum(c for _, c in placement)
    if n <= 1:
        return 0.0
    return 1.0 - sum((c / n) ** 2 for _, c in placement)


def _chi_batch(placements: Sequence[Sequence[Tuple[int, int]]]
               ) -> np.ndarray:
    """Vectorized ``placement_cross_host_fraction`` over a batch: one
    flattened bincount pass.  Per-candidate accumulation order matches
    the Python generator sum (flat order), so values are bit-identical."""
    k = len(placements)
    sizes = np.array([len(p) for p in placements])
    chips = np.array([c for p in placements for _, c in p],
                     dtype=np.float64)
    seg = np.repeat(np.arange(k), sizes)
    n = np.bincount(seg, weights=chips, minlength=k)
    frac_sq = (chips / n[seg]) ** 2
    return np.where(n > 1, 1.0 - np.bincount(seg, weights=frac_sq,
                                             minlength=k), 0.0)


def derive_capacities(n_chips: int, chips_per_host: int) -> List[int]:
    """Per-host chip capacities for a pool of ``n_chips`` devices: hosts
    are consecutive runs of ``chips_per_host`` chips, and the last host
    carries the ragged remainder.  The one place the host map is derived
    — ``Fabric`` and ``PlacementEngine.for_chips`` both use it."""
    assert n_chips > 0 and chips_per_host > 0
    hosts = -(-n_chips // chips_per_host)
    return [min(chips_per_host, n_chips - h * chips_per_host)
            for h in range(hosts)]


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
class CostModel:
    """The §6 job-time model ``T = (W / Σ_h n_h·s_h)·(1 + beta_kind·chi)``.

    Calibration (paper Fig 14, §6.4):

    ==============  =====  ==========================================
    job kind        beta   source
    ==============  =====  ==========================================
    mpi-compute      0.4   LAMMPS co-located vs 4+4-fragmented = 1.2x
    mpi-network     13.0   all-to-all fragmented = 7.5x
    omp              1.0   shared-memory intermediate
    ==============  =====  ==========================================

    ``speeds`` (per-host factors ``s_h``, 1.0 = current generation) turn
    the perfect-scaling term ``W/n`` into ``W / Σ_h n_h·s_h``; with no
    speeds (homogeneous fleet) every method reduces bit-exactly to the
    pre-heterogeneity formulas.  ``migrate_progress_cap`` is Fig 14's
    migration-worthwhile heuristic: past this progress fraction the
    snapshot transfer no longer pays for itself; ``migration_cost_s``
    is that snapshot-transfer cost (the simulator's MIGRATION_COST_S),
    which a heterogeneous migration's predicted saving must exceed.
    """

    DEFAULT_BETAS: Dict[str, float] = {"mpi-compute": 0.4,
                                       "mpi-network": 13.0, "omp": 1.0}

    def __init__(self, betas: Optional[Mapping[str, float]] = None,
                 default_beta: float = 0.4,
                 migrate_progress_cap: float = 0.8,
                 migration_cost_s: float = 2.0,
                 preempt_cost_s: float = 2.0,
                 checkpoint_cost_s: float = 0.5,
                 ckpt_delta_fraction: Optional[float] = None,
                 ckpt_rebase_every: int = 8,
                 collective_bytes: Union[None, float,
                                         Mapping[str, float]] = None,
                 step_compute_s: float = 1.0,
                 link: Optional[comms.LinkProfile] = None,
                 compress_frac: float = 0.05,
                 serve_token_s: float = 0.05,
                 serve_slo_s: Optional[float] = None,
                 serve_kinds: Sequence[str] = ("omp", "serve"),
                 risk_tau_s: Optional[float] = None,
                 risk_weights: Optional[Mapping[str, float]] = None,
                 default_risk_weight: float = 1.0,
                 risk_lease_floor_s: float = 1.0):
        self.betas = dict(self.DEFAULT_BETAS if betas is None else betas)
        self.default_beta = default_beta
        # serve SLO term: ``serve_token_s`` is the base per-token decode
        # latency of a serve gang on reference chips; with
        # ``serve_slo_s`` set (opt-in, like collective_bytes), ``score``
        # / ``score_batch`` multiply a latency-violation penalty into
        # candidates for ``serve_kinds`` jobs so placement spreads serve
        # gangs onto topologies that can hold the SLO.  The penalty
        # deliberately does NOT enter ``slowdown`` — that feeds the
        # simulated execution rate, and an SLO preference must steer
        # *choices*, not rewrite physics.  None keeps every decision
        # bit-identical to the unpenalised model.
        self.serve_token_s = float(serve_token_s)
        self.serve_slo_s = serve_slo_s
        self.serve_kinds = tuple(serve_kinds)
        # collective-aware pricing (DESIGN.md §11): when
        # ``collective_bytes`` is set (bytes per sync step, scalar or
        # per-kind map), ``slowdown`` prices the *best achievable*
        # collective schedule on the candidate's topology
        # (``collective_time``) against ``step_compute_s`` of compute,
        # instead of the scalar ``beta·chi``.  None (the default) keeps
        # every decision bit-identical to the scalar model — the opt-in
        # gate that preserves the pinned placement tests.
        self.collective_bytes = collective_bytes
        self.step_compute_s = float(step_compute_s)
        self.link = link or comms.LinkProfile()
        self.compress_frac = float(compress_frac)
        self.migrate_progress_cap = migrate_progress_cap
        self.migration_cost_s = migration_cost_s
        self.preempt_cost_s = preempt_cost_s
        # periodic-checkpoint cost (a snapshot save, cheaper than the
        # cross-host transfer of migration_cost_s): what the simulator
        # charges per checkpoint under a checkpoint_interval policy and
        # the delta feeding the Young/Daly optimum in core.fleet
        self.checkpoint_cost_s = checkpoint_cost_s
        # delta checkpointing (core.diffsync chains): a delta save costs
        # ``ckpt_delta_fraction`` of a full one, with a full rebase every
        # ``ckpt_rebase_every`` checkpoints to bound the replay chain.
        # The fraction is *configured* (a deterministic parameter), so
        # predicted and live traces charge identically and Action logs
        # stay bit-equal; live-measured bytes land in ``ckpt_observed``
        # and live-measured step times in ``step_observed``
        # via ``observe_checkpoint`` as statistics only, to calibrate
        # the next run's fraction — never consumed mid-trace.
        # None keeps the pre-delta behaviour: every checkpoint is full.
        self.ckpt_delta_fraction = ckpt_delta_fraction
        self.ckpt_rebase_every = max(1, int(ckpt_rebase_every))
        self.ckpt_observed: List[Tuple[int, int]] = []
        self.step_observed: Dict[Tuple[str, Optional[str]],
                                 List[float]] = {}
        # risk term (DESIGN.md §13): with ``risk_tau_s`` set (the gang
        # checkpoint cadence, opt-in like collective_bytes /
        # serve_slo_s), ``score``-consuming policies multiply candidates
        # by the expected lost work of placing there — per-host hazard
        # (lease expiry + observed failure rate, correlated across a
        # blast-radius group) times half a checkpoint interval of
        # rollback.  ``risk_weights`` scales the term per job kind
        # (weight 0 = restartable work that happily soaks up risky
        # capacity).  Like the serve SLO term it deliberately does NOT
        # enter ``slowdown`` — risk steers *choices*, not physics.
        # None (the default) keeps every decision bit-identical.
        self.risk_tau_s = risk_tau_s
        self.risk_weights = (None if risk_weights is None
                             else dict(risk_weights))
        self.default_risk_weight = float(default_risk_weight)
        self.risk_lease_floor_s = float(risk_lease_floor_s)

    # ---- delta-checkpoint costs (core.diffsync chains) --------------------
    def checkpoint_cost(self, index: int = 0) -> float:
        """Cost of the ``index``-th periodic checkpoint of a run segment
        (index 0 = the baseline taken at start).  Rebase points —
        every ``ckpt_rebase_every``-th — pay the full snapshot cost;
        the checkpoints between them ship deltas."""
        if self.ckpt_delta_fraction is None:
            return self.checkpoint_cost_s
        if index % self.ckpt_rebase_every == 0:
            return self.checkpoint_cost_s
        return self.checkpoint_cost_s * self.ckpt_delta_fraction

    def effective_checkpoint_cost_s(
            self, fraction: Optional[float] = None) -> float:
        """Amortised per-checkpoint cost over one rebase period — the
        ``delta`` that ``fleet.optimal_checkpoint_interval`` (Young/Daly)
        consumes, so cheaper delta checkpoints buy a tighter cadence.
        ``fraction`` overrides the configured ``ckpt_delta_fraction``
        with a *measured* one (``observed_delta_fraction``) — the live
        runner's adaptive cadence re-derives its Young/Daly interval
        from it after each rebase window."""
        frac = self.ckpt_delta_fraction if fraction is None else fraction
        if frac is None:
            return self.checkpoint_cost_s
        r = self.ckpt_rebase_every
        return self.checkpoint_cost_s * (1.0 + (r - 1) * frac) / r

    def observe_checkpoint(self, delta_bytes: int, full_bytes: int) -> None:
        """Record one live checkpoint's measured (shipped, full) bytes.
        Statistics only: the trace keeps charging the configured
        fraction so live Action logs match ``predict_trace``."""
        self.ckpt_observed.append((int(delta_bytes), int(full_bytes)))

    def observed_delta_fraction(self) -> Optional[float]:
        """Measured Σdelta/Σfull over the observed checkpoints — the
        calibrated ``ckpt_delta_fraction`` for the *next* run."""
        if not self.ckpt_observed:
            return None
        full = sum(f for _, f in self.ckpt_observed)
        if full <= 0:
            return None
        return sum(d for d, _ in self.ckpt_observed) / full

    def observe_step(self, host_kind: str, job_kind: Optional[str],
                     step_s: float, count: int = 1) -> None:
        """Record measured wall step time for (host-kind, job-kind) —
        the telemetry plane's calibration feed (ROADMAP item 2).
        Statistics only, like ``observe_checkpoint``: predictions keep
        using the configured tables so pinned traces stay bit-equal;
        the *next* run may fit ``step_compute_s`` / speed factors from
        ``observed_step_times``."""
        key = (str(host_kind), job_kind if job_kind is None
               else str(job_kind))
        agg = self.step_observed.setdefault(key, [0, 0.0])
        agg[0] += int(count)
        agg[1] += float(step_s) * int(count)

    def observed_step_times(self) -> Dict[Tuple[str, Optional[str]],
                                          Tuple[int, float]]:
        """(host_kind, job_kind) -> (count, mean measured seconds)."""
        return {k: (int(v[0]), v[1] / v[0])
                for k, v in self.step_observed.items() if v[0]}

    def observed_step_time(self, host_kind: Optional[str] = None,
                           job_kind: Optional[str] = None
                           ) -> Optional[float]:
        """Mean measured step time over matching observations (either
        key may be None = any)."""
        n, tot = 0, 0.0
        for (hk, jk), (c, s) in self.step_observed.items():
            if host_kind is not None and hk != host_kind:
                continue
            if job_kind is not None and jk != job_kind:
                continue
            n += c
            tot += s
        return (tot / n) if n else None

    def beta(self, kind: Optional[str] = None) -> float:
        """Per-job-kind cross-host penalty; ``default_beta`` when the
        kind is unknown (e.g. a live gang with no trace kind)."""
        if kind is None:
            return self.default_beta
        return self.betas.get(kind, self.default_beta)

    @property
    def collective_pricing(self) -> bool:
        return self.collective_bytes is not None

    def sync_bytes(self, kind: Optional[str] = None) -> float:
        """Per-step collective message size for a job kind (scalar
        config applies to every kind)."""
        cb = self.collective_bytes
        if cb is None:
            return 0.0
        if isinstance(cb, Mapping):
            return float(cb.get(kind, cb.get(None, comms.DEFAULT_NBYTES)))
        return float(cb)

    def collective_time(self, placement: Sequence[Tuple[int, int]],
                        nbytes: Optional[float] = None,
                        kind: Optional[str] = None) -> float:
        """Seconds per sync step under the *best achievable* collective
        schedule (flat/ring/hierarchical/compressed) on this
        placement's topology — what the comms-layer ``CollectiveTuner``
        would actually dispatch (``core.comms`` pricing).  Unlike the
        scalar ``beta·chi`` this distinguishes balanced from ragged
        splits: the hierarchical slow hop ships ``bytes/min_fast``, so
        (4,4) prices cheaper than (6,2) at equal chi-ish spread."""
        if nbytes is None:
            nbytes = self.sync_bytes(kind) or comms.DEFAULT_NBYTES
        topo = comms.Topology.from_placement(placement)
        _, t = comms.best_schedule(topo, int(nbytes), self.link,
                                   self.compress_frac)
        return t

    def slowdown(self, placement: Sequence[Tuple[int, int]],
                 kind: Optional[str] = None) -> float:
        """``1 + beta_kind·chi`` for a placement — or, with collective
        pricing enabled, ``1 + collective_time/step_compute_s`` (the
        measured-schedule generalisation of the same ratio)."""
        if self.collective_bytes is not None:
            return 1.0 + (self.collective_time(placement, kind=kind)
                          / max(self.step_compute_s, 1e-12))
        return 1.0 + self.beta(kind) * placement_cross_host_fraction(
            placement)

    def effective_parallelism(self, placement: Sequence[Tuple[int, int]],
                              speeds: Optional[np.ndarray] = None,
                              active: Optional[int] = None) -> float:
        """``Σ_h n_h·s_h`` — chips weighted by host speed.  ``active``
        caps the working ranks below the allocated chips (an OpenMP job
        in an over-large container); the speed-weighted sum then scales
        by the active fraction."""
        n = sum(c for _, c in placement)
        if active is None:
            active = n
        if speeds is None:
            return float(active)
        eff = float(sum(c * float(speeds[h]) for h, c in placement))
        if active != n and n > 0:
            eff *= active / n
        return eff

    def predicted_time(self, work: float,
                       placement: Sequence[Tuple[int, int]],
                       kind: Optional[str] = None,
                       speeds: Optional[np.ndarray] = None,
                       active: Optional[int] = None) -> float:
        """``T = (W / Σ_h n_h·s_h)·(1 + beta_kind·chi)``."""
        eff = self.effective_parallelism(placement, speeds, active)
        if eff <= 0:
            return float("inf")
        return (work / eff) * self.slowdown(placement, kind)

    def token_latency(self, placement: Sequence[Tuple[int, int]],
                      kind: Optional[str] = None,
                      speeds: Optional[np.ndarray] = None) -> float:
        """Predicted per-token decode latency of a serve gang on this
        placement: the replicated decode step is paced by the slowest
        participating chip and pays the gang's cross-host / collective
        slowdown on every token."""
        if not placement:
            return float("inf")
        smin = 1.0 if speeds is None else min(float(speeds[h])
                                              for h, _ in placement)
        return (self.serve_token_s * self.slowdown(placement, kind)
                / max(smin, 1e-12))

    def serve_slo_penalty(self, placement: Sequence[Tuple[int, int]],
                          kind: Optional[str] = None,
                          speeds: Optional[np.ndarray] = None) -> float:
        """Multiplicative score penalty for serve-kind placements whose
        predicted ``token_latency`` breaks ``serve_slo_s`` (1.0 when the
        SLO holds, the violation ratio when it doesn't, 1.0 always when
        the term is not opted in)."""
        if self.serve_slo_s is None or kind not in self.serve_kinds:
            return 1.0
        lat = self.token_latency(placement, kind, speeds)
        return max(1.0, lat / self.serve_slo_s)

    def score(self, placement: Sequence[Tuple[int, int]],
              kind: Optional[str] = None,
              speeds: Optional[np.ndarray] = None) -> float:
        """Per-unit-work predicted ``T`` — what policies rank candidate
        placements by (``W`` is constant across candidates, so it drops
        out of the argmin).  With ``serve_slo_s`` opted in, serve-kind
        candidates that would break the token-latency SLO are scaled by
        the violation ratio."""
        return (self.predicted_time(1.0, placement, kind, speeds)
                * self.serve_slo_penalty(placement, kind, speeds))

    def score_batch(self, placements: Sequence[Sequence[Tuple[int, int]]],
                    kind: Optional[str] = None,
                    speeds: Optional[np.ndarray] = None) -> np.ndarray:
        """Vectorized ``score`` over a batch of candidate placements:
        one flattened numpy pass over all (host, chips) pairs instead of
        a Python reduction per candidate.  The per-candidate float
        operation order matches ``score`` (chi accumulates ``(c/n)**2``
        terms, then ``(1/eff) * slowdown``), so ranking candidates by
        either form agrees."""
        k = len(placements)
        if k == 0:
            return np.empty(0, dtype=np.float64)
        if self.collective_bytes is not None:
            # collective pricing walks each candidate's topology; the
            # candidate sets policies score are tiny (<= 5), so the
            # scalar path is fine here
            return np.array([self.score(p, kind, speeds)
                             for p in placements], dtype=np.float64)
        sizes = np.array([len(p) for p in placements])
        hosts = np.array([h for p in placements for h, _ in p],
                         dtype=np.int64)
        chips = np.array([c for p in placements for _, c in p],
                         dtype=np.float64)
        seg = np.repeat(np.arange(k), sizes)
        n = np.bincount(seg, weights=chips, minlength=k)
        frac_sq = (chips / n[seg]) ** 2
        chi = np.where(n > 1, 1.0 - np.bincount(seg, weights=frac_sq,
                                                minlength=k), 0.0)
        slowdown = 1.0 + self.beta(kind) * chi
        if speeds is None:
            eff = n
        else:
            eff = np.bincount(seg, weights=chips * speeds[hosts],
                              minlength=k)
        safe = np.where(eff > 0, eff, 1.0)
        out = np.where(eff > 0, (1.0 / safe) * slowdown, np.inf)
        if self.serve_slo_s is not None and kind in self.serve_kinds:
            # same formula as serve_slo_penalty, segmented: the decode
            # step is paced by the slowest chip in each candidate
            if speeds is None:
                smin = np.ones(k)
            else:
                smin = np.full(k, np.inf)
                np.minimum.at(smin, seg, speeds[hosts])
            lat = (self.serve_token_s * slowdown
                   / np.maximum(smin, 1e-12))
            out = out * np.maximum(1.0, lat / self.serve_slo_s)
        return out

    def active_workers(self, parallelism: int, alloc_n: int,
                       shared_memory: bool) -> int:
        """Working ranks on an allocation: OpenMP threads in one
        container cap at the container's chips (§6.2); MPI world sizes
        are fixed at submission."""
        return min(parallelism, alloc_n) if shared_memory else parallelism

    def migration_worthwhile(self, progress: float) -> bool:
        """Fig 14: consolidation pays off except near the finish line."""
        return progress <= self.migrate_progress_cap

    # ---- risk term (leases / failure history; DESIGN.md §13) --------------
    @property
    def risk_aware(self) -> bool:
        return self.risk_tau_s is not None

    def risk_weight(self, kind: Optional[str] = None) -> float:
        """Per-job-kind sensitivity to host risk.  High-priority or
        expensive-to-checkpoint kinds keep the default weight; cheap
        restartable kinds can be configured at 0 so they soak up risky
        capacity instead of competing for safe hosts."""
        if not self.risk_aware:
            return 0.0
        if self.risk_weights is None:
            return self.default_risk_weight
        return float(self.risk_weights.get(kind,
                                           self.default_risk_weight))

    def risk_loss_s(self) -> float:
        """Expected seconds lost per gang-wide disruption: on average
        half a checkpoint interval of progress rolls back, plus the
        requeue/restart overhead — the lost-work magnitude the hazard
        rate multiplies in the risk penalty."""
        return (self.risk_tau_s or 0.0) / 2.0 + self.preempt_cost_s


@dataclasses.dataclass
class Allocation:
    job_id: str
    placement: Placement
    slice_size: int = 0                     # 0 = granular

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)

    @property
    def hosts(self) -> List[int]:
        return [h for h, _ in self.placement]

    def fragmentation(self) -> int:
        return len(self.placement)

    def cross_host_fraction(self) -> float:
        return placement_cross_host_fraction(self.placement)


class RiskContext:
    """Per-host risk snapshot handed to policies inside a ``ClusterView``
    (attached by the engine only when its cost model opted into the risk
    term, so risk-blind decisions never see one — bit-identity).

    The combined per-host hazard rate is

        rate_h = hazard_h + 1 / max(lease_until_h - now, lease_floor)

    — the online failure-rate estimate from observed ``FleetEvent``
    history plus the certain disruption of an approaching lease expiry
    (an infinite lease contributes 0).  A gang placement's disruption
    rate correlates hazards across blast-radius groups: any host of a
    group failing kills the whole gang, and failures *within* a group
    are one event (shared rack/switch/power), so

        Lambda(P) = sum over groups g touched by P of max rate_h, h in g∩P

    — spanning extra groups adds independent failure sources; packing
    deeper into one already-touched group costs nothing extra.  The
    score penalty is ``1 + w_kind · Lambda(P) · risk_loss_s`` (expected
    lost-work fraction), and greedy policies order hosts by the
    risk-discounted effective throughput ``free·s / (1 + w·rate·loss)``.
    """

    __slots__ = ("model", "lease_until_s", "hazards", "blast_group",
                 "now", "_rates")

    def __init__(self, model: CostModel, lease_until_s: np.ndarray,
                 hazards: np.ndarray, blast_group: np.ndarray,
                 now: float, rates: Optional[np.ndarray] = None):
        self.model = model
        self.lease_until_s = lease_until_s
        self.hazards = hazards
        self.blast_group = blast_group
        self.now = now
        self._rates = rates

    def rates(self) -> np.ndarray:
        """Combined per-host disruption rate (cached per context)."""
        if self._rates is None:
            left = self.lease_until_s - self.now
            lease_rate = np.where(
                np.isfinite(self.lease_until_s),
                1.0 / np.maximum(left, self.model.risk_lease_floor_s),
                0.0)
            self._rates = self.hazards + lease_rate
        return self._rates

    def sliced(self, lo: int, hi: int) -> "RiskContext":
        """Shard-slice view of the same snapshot (local host indices)."""
        return RiskContext(self.model, self.lease_until_s[lo:hi],
                           self.hazards[lo:hi], self.blast_group[lo:hi],
                           self.now, rates=self.rates()[lo:hi])

    def discounts(self, kind: Optional[str] = None) -> Optional[np.ndarray]:
        """Per-host multiplicative discount ``1/(1 + w·rate·loss)`` for
        greedy host ordering; None when the kind is risk-indifferent
        (weight 0) so its decisions keep the exact risk-blind path."""
        w = self.model.risk_weight(kind)
        if w <= 0.0:
            return None
        return 1.0 / (1.0 + w * self.rates() * self.model.risk_loss_s())

    def order_speeds(self, kind: Optional[str],
                     speeds: Optional[np.ndarray]) -> Optional[np.ndarray]:
        """Risk-discounted speed factors for ``_host_order`` — ordering
        only, never fed to any charged quantity (choices, not physics)."""
        disc = self.discounts(kind)
        if disc is None:
            return None
        return disc if speeds is None else speeds * disc

    def gang_rate(self, placement: Sequence[Tuple[int, int]]) -> float:
        """Blast-correlated disruption rate Lambda(P) of a placement."""
        rates = self.rates()
        worst: Dict[int, float] = {}
        for h, _ in placement:
            g = int(self.blast_group[h])
            r = float(rates[h])
            if r > worst.get(g, -1.0):
                worst[g] = r
        return sum(worst.values())

    def penalty(self, placement: Sequence[Tuple[int, int]],
                kind: Optional[str] = None) -> float:
        """Multiplicative score penalty ``1 + w·Lambda(P)·loss_s``."""
        w = self.model.risk_weight(kind)
        if w <= 0.0:
            return 1.0
        return 1.0 + w * self.gang_rate(placement) \
            * self.model.risk_loss_s()

    def penalty_batch(self, placements: Sequence[Sequence[Tuple[int,
                                                                int]]],
                      kind: Optional[str] = None) -> np.ndarray:
        """``penalty`` over a candidate batch (candidate sets are tiny,
        so the per-candidate group reduction stays a Python loop)."""
        w = self.model.risk_weight(kind)
        if w <= 0.0:
            return np.ones(len(placements))
        loss = self.model.risk_loss_s()
        return np.array([1.0 + w * self.gang_rate(p) * loss
                         for p in placements])


class ClusterView:
    """Read-only free-chip snapshot handed to policies (keeps them pure).

    ``capacities`` carries per-host chip counts (ragged last host) and
    ``speeds`` per-host speed factors; ``speeds is None`` means a
    homogeneous fleet and keeps every policy on its exact pre-CostModel
    integer code path.

    ``hetero`` / ``idle`` / ``idle_eff`` are optional precomputed
    summaries: the engine maintains them incrementally (commit/release
    deltas) and passes them in, so the per-decision loop no longer
    recomputes an O(hosts) reduction per property access.  When absent
    they are computed lazily, once, on first access."""

    __slots__ = ("free", "chips_per_host", "capacities", "speeds",
                 "_hetero", "_idle", "_idle_eff", "risk")

    def __init__(self, free: np.ndarray, chips_per_host: int,
                 capacities: Optional[np.ndarray] = None,
                 speeds: Optional[np.ndarray] = None,
                 hetero: Optional[bool] = None,
                 idle: Optional[int] = None,
                 idle_eff: Optional[float] = None,
                 risk: Optional[RiskContext] = None):
        self.free = free
        self.chips_per_host = chips_per_host
        # per-host risk metadata (None unless the engine's cost model
        # opted into the risk term — the risk-blind path never sees it)
        self.risk = risk
        self.capacities = (np.full(len(free), chips_per_host,
                                   dtype=np.int64)
                           if capacities is None
                           else np.asarray(capacities, dtype=np.int64))
        self.speeds = (None if speeds is None
                       else np.asarray(speeds, dtype=np.float64))
        self._hetero = hetero
        self._idle = idle
        self._idle_eff = idle_eff

    @property
    def hosts(self) -> int:
        return len(self.free)

    @property
    def heterogeneous(self) -> bool:
        """True when per-host speeds actually differ — a uniform-speed
        fleet (even at s != 1) ranks placements exactly like the
        homogeneous case, so policies keep the degenerate path.
        Cached (the answer cannot change for a given view)."""
        if self._hetero is None:
            self._hetero = self.speeds is not None and bool(
                (self.speeds != self.speeds[0]).any())
        return self._hetero

    def idle_chips(self) -> int:
        if self._idle is None:
            self._idle = int(self.free.sum())
        return self._idle

    def idle_throughput(self) -> float:
        """Idle capacity in effective (speed-weighted) chips; cached."""
        if self._idle_eff is None:
            self._idle_eff = (float(self.idle_chips())
                              if self.speeds is None
                              else float((self.free * self.speeds).sum()))
        return self._idle_eff


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------
class PlacementPolicy:
    """A pure placement function; the engine commits the result.

    ``kind`` is the job kind from the trace (``Job.kind``) so policies
    that consult the cost model use the same per-job beta as the
    simulator's rate integration; None falls back to the model default.
    """

    name = "abstract"
    slice_size = 0                          # granular unless overridden

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        raise NotImplementedError

    def with_model(self, model: CostModel) -> "PlacementPolicy":
        """Bind an engine's cost model.  Policies that score with one
        return a bound copy (never mutating the shared ``POLICIES``
        singletons); stateless policies return self.  The engine calls
        this on every resolved policy so placement and execution always
        score with the *same* model — the one-model invariant."""
        return self


def _host_order(free: np.ndarray,
                speeds: Optional[np.ndarray] = None) -> np.ndarray:
    """Hosts by descending free capacity; on heterogeneous fleets by
    descending effective free throughput ``free_h·s_h``, tie-broken
    toward faster hosts (so equal-throughput fast hosts are preferred
    over one big slow host)."""
    if speeds is None:
        return np.argsort(free)[::-1]
    return np.lexsort((speeds, free * speeds))[::-1]


def _greedy_most_free_loop(free: np.ndarray, n: int,
                           speeds: Optional[np.ndarray] = None
                           ) -> Optional[Placement]:
    """Pre-vectorization reference: per-host Python loop over the greedy
    order (kept for ``reference_loops()`` A/B parity + benchmarking)."""
    order = _host_order(free, speeds)
    placement: Placement = []
    remaining = n
    for h in order:
        if free[h] == 0:
            continue
        take = min(int(free[h]), remaining)
        placement.append((int(h), take))
        remaining -= take
        if remaining == 0:
            break
    return sorted(placement) if remaining == 0 else None


def _greedy_most_free(free: np.ndarray, n: int,
                      speeds: Optional[np.ndarray] = None
                      ) -> Optional[Placement]:
    """Most-free-first greedy: the gang spans as few hosts as possible
    (as few *effective-throughput-ordered* hosts on mixed fleets).

    Vectorized cumulative-sum fill: hosts in greedy order contribute
    their full free count until the running total covers ``n``; the
    cutoff host contributes the remainder.  Zero-free hosts sort last in
    every greedy order (free and free·s are both 0), so the prefix never
    contains one — bit-identical to the reference loop."""
    if not _VECTORIZED:
        return _greedy_most_free_loop(free, n, speeds)
    order = _host_order(free, speeds)
    if free[order[0]] >= n:                  # whole gang on the top host
        return [(int(order[0]), n)]
    f = free[order]
    cum = np.cumsum(f)
    if cum.size == 0 or cum[-1] < n:
        return None
    k = int(np.searchsorted(cum, n))
    take = f[:k + 1]
    last = n - (int(cum[k - 1]) if k else 0)
    placement = [(int(h), int(c))
                 for h, c in zip(order[:k], take[:k])]
    placement.append((int(order[k]), last))
    return sorted(placement)


class BinpackPolicy(PlacementPolicy):
    """Faabric's default: fewest hosts via greedy most-free-first.  On a
    heterogeneous fleet the greedy order is the cost model's effective
    throughput ``free_h·s_h`` — the homogeneous case degenerates to the
    original free-chip order bit-exactly."""

    name = "binpack"

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        if view.risk is not None:
            # risk-discounted greedy order: short-lease / flaky hosts
            # sort as if slower, so the gang packs onto safe capacity
            # first (risk-indifferent kinds get None back and keep the
            # exact risk-blind order)
            rw = view.risk.order_speeds(kind, view.speeds)
            if rw is not None:
                return _greedy_most_free(view.free, n, rw)
        speeds = view.speeds if view.heterogeneous else None
        return _greedy_most_free(view.free, n, speeds)


def _spread_fill_loop(free: np.ndarray, n: int,
                      speeds: Optional[np.ndarray] = None
                      ) -> Optional[Placement]:
    """Pre-vectorization reference: one argmax per chip (kept for
    ``reference_loops()`` A/B parity + benchmarking, and still the
    implementation for heterogeneous fleets, where each chip shifts the
    effective-throughput weights by that host's speed)."""
    counts: Dict[int, int] = {}
    avail = free.copy()
    remaining = n
    while remaining > 0:
        candidates = np.nonzero(avail > 0)[0]
        if candidates.size == 0:
            return None
        weight = (avail[candidates] * speeds[candidates]
                  if speeds is not None else avail[candidates])
        h = int(candidates[np.argmax(weight)])
        counts[h] = counts.get(h, 0) + 1
        avail[h] -= 1
        remaining -= 1
    return sorted(counts.items())


def _spread_fill(free: np.ndarray, n: int,
                 speeds: Optional[np.ndarray] = None
                 ) -> Optional[Placement]:
    """Round-robin water-filling: each chip goes to the host with the
    most free chips (lowest index on ties).

    Homogeneous vectorized form: instead of one argmax per chip, whole
    *levels* are drained at once — with ``k`` hosts at the max level
    ``m1`` and the next level at ``m2``, the per-chip reference
    distributes the next ``k*(m1-m2)`` chips as full cycles over those
    hosts in ascending index order, so ``divmod`` gives each host ``q``
    chips and the first ``r`` (by index) one extra.  Bit-identical to
    the reference loop; heterogeneous fleets keep the per-chip loop
    (each chip moves that host's weight by its own speed factor)."""
    if not _VECTORIZED or speeds is not None:
        return _spread_fill_loop(free, n, speeds)
    if int(free.sum()) < n:
        return None
    # closed-form water level: the per-chip process drains every host
    # above level L, where L is the lowest level whose surplus
    # S(L) = sum(max(free - L, 0)) still fits in n; the n - S(L)
    # leftover chips come off the hosts sitting at L (free >= L), one
    # each in ascending index order — exactly the reference's final
    # partial cycle.  Levels are bounded by chips_per_host, so the
    # S scan is a tiny (levels x hosts) broadcast.
    levels = np.arange(int(free.max()) + 1)
    surplus = np.clip(free[None, :] - levels[:, None], 0, None).sum(axis=1)
    lvl = int(np.argmax(surplus <= n))
    counts = np.clip(free - lvl, 0, None)
    extra = n - int(surplus[lvl])
    if extra:
        at = np.nonzero(free >= max(lvl, 1))[0]
        counts[at[:extra]] += 1
    return [(int(h), int(counts[h])) for h in np.nonzero(counts)[0]]


class SpreadPolicy(PlacementPolicy):
    """Round-robin chips over hosts (load balancing); on mixed fleets
    each chip lands on the host with the most effective free throughput."""

    name = "spread"

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        if view.risk is not None:
            rw = view.risk.order_speeds(kind, view.speeds)
            if rw is not None:
                return _spread_fill(view.free, n, rw)
        speeds = view.speeds if view.heterogeneous else None
        return _spread_fill(view.free, n, speeds)


class FixedSlicePolicy(PlacementPolicy):
    """Whole-slice allocation: ceil(n/slice) slices, each on one host.

    Emulates the paper's k-containers-per-VM baselines: a host holds
    ``chips_per_host // slice_size`` slices; slices are never shared
    between jobs, so a request is rounded up to whole slices (the
    fragmentation waste of Fig 10).
    """

    name = "fixed-slice"

    def __init__(self, slice_size: int):
        assert slice_size > 0
        self.slice_size = slice_size

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        slice_size = self.slice_size
        n_slices = -(-n // slice_size)
        free = view.free
        speeds = view.speeds if view.heterogeneous else None
        if view.risk is not None:
            rw = view.risk.order_speeds(kind, view.speeds)
            if rw is not None:
                speeds = rw          # host *ordering* only
        if not _VECTORIZED:
            return self._place_loop(free, n_slices, speeds)
        # vectorized: whole slices per host in greedy order, cumulative
        # cut at n_slices (hosts too small for one slice contribute 0
        # and are dropped — exactly what the reference loop skips)
        order = _host_order(free, speeds)
        slices = free[order] // slice_size
        cum = np.cumsum(slices)
        if cum.size == 0 or cum[-1] < n_slices:
            return None
        k = int(np.searchsorted(cum, n_slices))
        take = slices[:k + 1].copy()
        take[k] = n_slices - (int(cum[k - 1]) if k else 0)
        return sorted((int(h), int(s) * slice_size)
                      for h, s in zip(order[:k + 1], take) if s > 0)

    def _place_loop(self, free: np.ndarray, n_slices: int,
                    speeds: Optional[np.ndarray]) -> Optional[Placement]:
        """Pre-vectorization reference (``reference_loops()``)."""
        slice_size = self.slice_size
        placement: Dict[int, int] = {}
        need = n_slices
        for h in _host_order(free, speeds):
            while free[h] - placement.get(int(h), 0) >= slice_size \
                    and need > 0:
                placement[int(h)] = placement.get(int(h), 0) + slice_size
                need -= 1
            if need == 0:
                break
        if need:
            return None
        return sorted(placement.items())


class LocalityScoredPolicy(PlacementPolicy):
    """Minimise the predicted job time ``T`` of the §6 cost model.

    Candidate placements are scored by the model's per-unit-work ``T``
    (``CostModel.score``): on a homogeneous fleet ``Σ n_h·s_h`` is the
    same for every candidate, so the score degenerates to the slowdown
    factor ``(1 + beta_kind·chi)`` — bit-identical to the pre-CostModel
    behaviour; on a mixed-generation fleet the score trades cross-host
    fragmentation against host speed *per job kind* (a network-bound
    job with beta 13 co-locates on a slow host, a compute-bound job
    with beta 0.4 splits across the fast generation).  Ties (e.g. every
    single-host placement of a given speed has chi = 0) break on chips
    *stranded* on touched hosts: best-fit keeps large free blocks
    intact, so later gangs fragment less — that second-order effect is
    what lowers the trace-wide mean chi versus binpack's worst-fit
    choice of the most-free host.
    """

    name = "locality"

    def __init__(self, beta: Optional[float] = None,
                 cost_model: Optional[CostModel] = None):
        # an explicitly-configured policy keeps its model through
        # with_model; only the default construction (the POLICIES
        # singleton, by-name resolution) is rebindable to an engine's
        self._custom = cost_model is not None or beta is not None
        # an explicit beta overrides every kind (the pre-CostModel
        # semantics: one scalar scored all placements), so the
        # calibration table is dropped, not merely re-defaulted
        self.cost_model = cost_model or (
            CostModel() if beta is None
            else CostModel(betas={}, default_beta=beta))

    @property
    def beta(self) -> float:
        return self.cost_model.default_beta

    def with_model(self, model: CostModel) -> "LocalityScoredPolicy":
        if self._custom or model is self.cost_model:
            return self
        bound = LocalityScoredPolicy(cost_model=model)
        bound._custom = False           # engine-bound, still rebindable
        return bound

    def _stranded(self, view: ClusterView, placement: Placement) -> int:
        return sum(int(view.free[h]) - c for h, c in placement)

    def _candidates(self, view: ClusterView, n: int,
                    kind: Optional[str] = None,
                    risk: Optional[RiskContext] = None) -> List[Placement]:
        free = view.free
        candidates: List[Placement] = []
        fits = np.nonzero(free >= n)[0]
        if fits.size:                        # best-fit single host
            h = int(fits[np.argmin(free[fits])])
            candidates.append([(h, n)])
        greedy = _greedy_most_free(free, n)
        if greedy is not None:
            candidates.append(greedy)
        if not fits.size:
            # when a single host fits, exact-fill's first probe returns
            # the same best-fit single-host placement — skip the dup
            exact = self._greedy_exact_fill(free, n)
            if exact is not None:
                candidates.append(exact)
        if view.heterogeneous:
            # speed-aware candidates: the fastest single host that fits,
            # and the effective-throughput greedy over the fast hosts
            if fits.size:
                hf = int(fits[np.argmax(view.speeds[fits])])
                candidates.append([(hf, n)])
            fast = _greedy_most_free(free, n, view.speeds)
            if fast is not None:
                candidates.append(fast)
        if self.cost_model.collective_pricing and not fits.size:
            # balanced (maximin) split over the fewest hosts: the
            # two-level schedule ships bytes/min_fast over the slow
            # link, so a {5,5,5} split is ~5x cheaper than greedy's
            # ragged {7,7,1} — only the collective-priced score can
            # rank it, so the candidate is gated to that mode and the
            # default candidate set stays decision-identical
            bal = self._balanced_split(free, n)
            if bal is not None and bal not in candidates:
                candidates.append(bal)
        if risk is not None:
            # risk-avoiding candidates: the safest single host that
            # fits, and the risk-discounted greedy fill — only the
            # penalised score can rank them, so they are gated to the
            # risk-aware mode and the default set stays
            # decision-identical
            if fits.size:
                rates = risk.rates()
                hs = int(fits[np.argmin(rates[fits])])
                cand = [(hs, n)]
                if cand not in candidates:
                    candidates.append(cand)
            rw = risk.order_speeds(kind, view.speeds)
            if rw is not None:
                safe = _greedy_most_free(free, n, rw)
                if safe is not None and safe not in candidates:
                    candidates.append(safe)
        return candidates

    @staticmethod
    def _balanced_split(free: np.ndarray, n: int) -> Optional[Placement]:
        """Even (maximin) split of ``n`` over the fewest freest hosts."""
        order = np.argsort(-free, kind="stable")
        csum = np.cumsum(free[order])
        if not csum.size or csum[-1] < n:
            return None
        k = int(np.searchsorted(csum, n)) + 1
        hosts = order[:k][::-1]          # ascending free: caps bind first
        placement: Placement = []
        rem = n
        for i, h in enumerate(hosts):
            share = min(int(free[h]), -(-rem // (k - i)))
            if share <= 0:
                return None
            placement.append((int(h), share))
            rem -= share
        return placement if rem == 0 else None

    def place(self, view: ClusterView, n: int,
              kind: Optional[str] = None) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        hetero = view.heterogeneous
        # risk term active for this kind?  (weight 0 keeps the exact
        # risk-blind decision path, including the short-circuit below)
        risk = view.risk
        if risk is not None and self.cost_model.risk_weight(kind) <= 0.0:
            risk = None
        if _VECTORIZED and not hetero and risk is None:
            # best-fit short-circuit: when some host fits the whole
            # gang, every candidate is single-host (chi = 0 for all, so
            # the score ties) and best-fit strands the fewest chips —
            # greedy's most-free host can never win the (score,
            # stranded) key, and exact-fill's first probe *is* the
            # best-fit host.  Decision-identical to scoring the full
            # candidate set, without the fills.  With the risk term
            # active single-host candidates no longer tie (hazards
            # differ), so risk-aware decisions must score the full set.
            fits = np.nonzero(view.free >= n)[0]
            if fits.size:
                return [(int(fits[np.argmin(view.free[fits])]), n)]
        candidates = self._candidates(view, n, kind=kind, risk=risk)
        if not candidates:
            return None
        if _VECTORIZED:
            # batched scoring: one numpy pass over all candidates'
            # (host, chips) pairs; per-candidate float operation order
            # matches the Python reduction (bincount accumulates in
            # flat order), and the stable lexsort keeps min()'s
            # first-of-equals tie-break on (score, stranded)
            if hetero:
                scores = self.cost_model.score_batch(candidates, kind,
                                                     view.speeds)
            elif self.cost_model.collective_pricing:
                # achievable-schedule pricing (DESIGN.md §11): rank by
                # the best collective time on each candidate topology
                scores = self.cost_model.score_batch(candidates, kind)
            else:
                # the exact pre-CostModel homogeneous key 1 + beta*chi
                scores = 1.0 + self.cost_model.beta(kind) \
                    * _chi_batch(candidates)
            if risk is not None:
                # expected-lost-work penalty (DESIGN.md §13): steers
                # the argmin, never the charged rate
                scores = scores * risk.penalty_batch(candidates, kind)
            k = len(candidates)
            sizes = np.array([len(p) for p in candidates])
            seg = np.repeat(np.arange(k), sizes)
            hosts = np.array([h for p in candidates for h, _ in p],
                             dtype=np.int64)
            chips = np.array([c for p in candidates for _, c in p],
                             dtype=np.int64)
            stranded = np.bincount(
                seg, weights=(view.free[hosts] - chips).astype(
                    np.float64), minlength=k)
            return candidates[int(np.lexsort((stranded, scores))[0])]
        if hetero or self.cost_model.collective_pricing:
            # reference Python reduction
            model = self.cost_model
            speeds = view.speeds if hetero else None
            return min(candidates, key=lambda p: (
                model.score(p, kind, speeds)
                * (risk.penalty(p, kind) if risk is not None else 1.0),
                self._stranded(view, p)))
        # homogeneous: Σ n_h·s_h is constant, so T reduces to the
        # slowdown — the exact pre-CostModel scoring key
        beta = self.cost_model.beta(kind)
        return min(candidates, key=lambda p: (
            (1.0 + beta * placement_cross_host_fraction(p))
            * (risk.penalty(p, kind) if risk is not None else 1.0),
            self._stranded(view, p)))

    @staticmethod
    def _greedy_exact_fill_loop(free: np.ndarray,
                                n: int) -> Optional[Placement]:
        """Pre-vectorization reference (``reference_loops()``): one
        full-array scan per host drained."""
        avail = free.copy()
        placement: Placement = []
        remaining = n
        while remaining > 0:
            fits = np.nonzero(avail >= remaining)[0]
            if fits.size:
                h = int(fits[np.argmin(avail[fits])])
                placement.append((h, remaining))
                remaining = 0
                break
            h = int(np.argmax(avail))
            if avail[h] == 0:
                return None
            take = int(avail[h])
            placement.append((h, take))
            avail[h] = 0
            remaining -= take
        return sorted(placement)

    @staticmethod
    def _greedy_exact_fill(free: np.ndarray, n: int) -> Optional[Placement]:
        """Greedy most-free-first, but finish the remainder on the
        best-fit host (smallest free count that still covers it) — same
        chi as plain greedy when the chunk multiset matches, strictly
        fewer stranded chips otherwise.

        Vectorized: the reference drains hosts in stable most-free
        order (repeated argmax = descending free, ascending index on
        ties) until some host covers the remainder, so the cut point is
        the first prefix position whose host already fits what is left
        — one cumulative-sum comparison instead of a scan per host."""
        if not _VECTORIZED:
            return LocalityScoredPolicy._greedy_exact_fill_loop(free, n)
        order = np.argsort(-free, kind="stable")
        f = free[order]
        cum = np.cumsum(f)
        rem = n - (cum - f)                  # remainder before each step
        cond = f >= rem
        if not cond.any():
            return None                      # total free < n
        k = int(np.argmax(cond))
        rem_k = int(rem[k])
        # best-fit finisher: f[k:] is descending, so the untaken hosts
        # that still fit rem_k form a prefix; the smallest fitting value
        # m sits at the prefix end, and (stable sort = ascending index
        # within a value run) the lowest-index host with value m is the
        # run's first position at or past k
        cut = int(np.searchsorted(-f[k:], -rem_k, side="right"))
        m = int(f[k + cut - 1])
        start = int(np.searchsorted(-f, -m, side="left"))
        finisher = int(order[max(k, start)])
        placement = [(int(order[i]), int(f[i])) for i in range(k)]
        placement.append((finisher, rem_k))
        return sorted(placement)


POLICIES: Dict[str, PlacementPolicy] = {
    "binpack": BinpackPolicy(),
    "spread": SpreadPolicy(),
    "locality": LocalityScoredPolicy(),
}


def resolve_policy(policy: Union[str, PlacementPolicy, None],
                   default: Optional[PlacementPolicy] = None
                   ) -> PlacementPolicy:
    if policy is None:
        assert default is not None
        return default
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(f"unknown placement policy: {policy!r}") from None


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PreemptPolicy:
    """Victim selection for a high-priority arrival that cannot be placed.

    Victims are strictly-lower-priority gangs, evicted cheapest-first:
    lowest priority class first, and within a class the largest gang first
    (frees the most chips per eviction).  Greedy selection stops as soon
    as the arrival fits under the engine's placement policy; a prune pass
    then drops any victim the fit does not actually need — preferring to
    spare the *higher*-priority ones — so no gang is evicted needlessly.
    The plan is a pure decision — the caller performs the actual
    checkpoint + release + requeue.

    The fit probe runs the placement policy against the engine's real
    view (capacities, per-host speeds, the arrival's job kind), so a
    preemption planned in simulation lands identically on the live
    fabric.

    ``max_victims`` bounds the blast radius of one arrival (0 = unbounded).
    """

    max_victims: int = 0

    def plan(self, engine: "PlacementEngine", n: int, priority: int,
             priorities: Dict[str, int],
             policy: Union[str, PlacementPolicy, None] = None,
             kind: Optional[str] = None) -> Optional[List[str]]:
        """job_ids to evict so an ``n``-chip gang at ``priority`` places;
        ``None`` if no lower-priority victim set suffices, ``[]`` if it
        already fits without eviction."""
        pol = resolve_policy(policy, engine.default_policy).with_model(
            engine.cost_model)
        scratch = engine.free.copy()
        # victims' chips on a draining host are being reclaimed by the
        # provider — they never count toward the fit probe (churn-free
        # fleets skip the mask entirely: bit-identical pre-churn path)
        drain = getattr(engine, "draining", None)
        if drain is not None and not drain.any():
            drain = None

        def fits() -> bool:
            probe = scratch if drain is None else np.where(drain, 0,
                                                           scratch)
            return pol.place(engine.view_with(probe), n,
                             kind=kind) is not None

        if fits():
            return []
        # cheapest-first victim order: priority asc, gang size desc, id
        victims = sorted(
            (a for a in engine.allocations.values()
             if priorities.get(a.job_id, 0) < priority),
            key=lambda a: (priorities.get(a.job_id, 0), -a.n, a.job_id))
        chosen: List[Allocation] = []
        for a in victims:
            for h, c in a.placement:
                scratch[h] += c
            chosen.append(a)
            if fits():
                break
        else:
            return None
        # prune needless victims, sparing higher-priority gangs first
        for a in sorted(chosen,
                        key=lambda a: (-priorities.get(a.job_id, 0), a.n,
                                       a.job_id)):
            for h, c in a.placement:
                scratch[h] -= c
            if fits():
                chosen.remove(a)        # not needed after all
            else:
                for h, c in a.placement:
                    scratch[h] += c
        if self.max_victims and len(chosen) > self.max_victims:
            return None
        return [a.job_id for a in chosen]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Reservation:
    """Chips held but not yet bound to a job.

    The preemption-safe handshake: ``reserve`` carves the chips out of the
    free pool atomically, so a multi-step decision (e.g. elastic grow:
    decide, snapshot, reshard) cannot lose the chips to a concurrent
    allocation; ``commit`` binds them to a job, ``cancel`` returns them.
    """

    placement: Placement
    slice_size: int = 0
    settled: bool = False                   # committed or cancelled

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)


class PlacementEngine:
    """Free-chip accounting + policy-driven gang allocation for a cluster
    of ``hosts`` hosts with ``chips_per_host`` chips each.  ``capacities``
    overrides per-host chip counts (e.g. a ragged last host); ``speeds``
    carries per-host speed factors for mixed host generations;
    ``cost_model`` is the shared job-time model policies and plans score
    against."""

    def __init__(self, hosts: int, chips_per_host: int,
                 policy: Union[str, PlacementPolicy] = "binpack",
                 capacities: Optional[Sequence[int]] = None,
                 speeds: Optional[Sequence[float]] = None,
                 cost_model: Optional[CostModel] = None):
        self.hosts = hosts
        self.chips_per_host = chips_per_host
        if capacities is None:
            self.capacities = np.full(hosts, chips_per_host, dtype=np.int64)
        else:
            assert len(capacities) == hosts
            self.capacities = np.asarray(capacities, dtype=np.int64)
            assert (self.capacities >= 0).all() \
                and (self.capacities <= chips_per_host).all()
        if speeds is None:
            self.speeds: Optional[np.ndarray] = None
        else:
            assert len(speeds) == hosts
            self.speeds = np.asarray(speeds, dtype=np.float64)
            assert (self.speeds > 0).all()
        self.cost_model = cost_model if cost_model is not None \
            else CostModel()
        self.free = self.capacities.copy()
        self.jobs_on_host: List[set] = [set() for _ in range(hosts)]
        self.default_policy = resolve_policy(policy).with_model(
            self.cost_model)
        self.allocations: Dict[str, Allocation] = {}
        # resolved-and-model-bound policies, cached per engine: the old
        # path re-ran resolve_policy(...).with_model(...) on every
        # decision, constructing a fresh bound LocalityScoredPolicy each
        # time the by-name singleton met a non-default model
        self._policy_cache: Dict[Union[str, int],
                                 Tuple[object, PlacementPolicy]] = {}
        # incrementally-maintained free-chip summaries (commit/release
        # deltas through _take/_give) — the per-decision loop never
        # recomputes an O(hosts) reduction for these
        self._hetero = self.speeds is not None and bool(
            (self.speeds != self.speeds[0]).any())
        self._idle_chips = int(self.free.sum())
        self._idle_eff = (float(self._idle_chips) if self.speeds is None
                          else float((self.free * self.speeds).sum()))
        # forwarding hops of the last placement decision (always 0 for
        # the centralised engine; ShardedPlacementEngine counts the
        # shards a decision consulted beyond its home shard)
        self.decision_hops = 0
        # fleet churn (core.fleet): hosts being lease-reclaimed take no
        # new placements and retire chips as gangs leave; _any_draining
        # keeps every churn-free hot path on its exact pre-churn code
        self.draining = np.zeros(hosts, dtype=bool)
        self._any_draining = False
        # per-host risk metadata (DESIGN.md §13): absolute lease-expiry
        # times (inf = reserved / no known end), online hazard estimates
        # (events/s, fed from observed FleetEvent history), and
        # blast-radius group ids (default: every host its own group).
        # Benign defaults; inert until the cost model opts into the risk
        # term, so risk-blind decisions are bit-identical.
        self.lease_until_s = np.full(hosts, np.inf)
        self.hazards = np.zeros(hosts)
        self.blast_group = np.arange(hosts, dtype=np.int64)
        self.risk_now = 0.0
        self._risk_cache: Optional[RiskContext] = None

    @classmethod
    def for_chips(cls, n_chips: int, chips_per_host: int,
                  **kwargs) -> "PlacementEngine":
        """Engine for a flat pool of ``n_chips`` devices — host count and
        the ragged last host come from ``derive_capacities`` (the single
        shared derivation; ``core.fabric.Fabric`` builds through here)."""
        caps = derive_capacities(n_chips, chips_per_host)
        return cls(len(caps), chips_per_host, capacities=caps, **kwargs)

    # ---- capacity ----------------------------------------------------------
    @property
    def total_chips(self) -> int:
        return int(self.capacities.sum())

    @property
    def heterogeneous(self) -> bool:
        return self._hetero

    @property
    def sched_hosts(self) -> int:
        """Hosts one scheduling decision scans — the centralised
        engine's Fig 11 latency term (the sharded engine overrides this
        with its per-shard host count)."""
        return self.hosts

    def idle_chips(self) -> int:
        return self._idle_chips

    def idle_fraction(self) -> float:
        total = self.total_chips        # shrinks under fleet churn
        return self._idle_chips / total if total else 0.0

    def idle_throughput(self) -> float:
        """Idle capacity in effective (speed-weighted) chips —
        incrementally maintained, not recomputed per call."""
        return self._idle_eff

    def view(self) -> ClusterView:
        """Policy view over the live free map.  No copy: views are
        read-only by the policy contract (policies copy before they
        mutate), and the engine only moves chips after ``place``
        returns — so the hot path skips an O(hosts) copy per decision."""
        return ClusterView(self.free, self.chips_per_host,
                           self.capacities, self.speeds,
                           hetero=self._hetero, idle=self._idle_chips,
                           idle_eff=self._idle_eff,
                           risk=self._risk_context())

    def view_with(self, free: np.ndarray) -> ClusterView:
        """A policy view over an alternative free map (scratch planning)
        that still carries this engine's capacities and speeds."""
        return ClusterView(free, self.chips_per_host,
                           self.capacities, self.speeds,
                           hetero=self._hetero,
                           risk=self._risk_context())

    # ---- risk metadata (leases / failure history; DESIGN.md §13) ----------
    def _risk_context(self) -> Optional[RiskContext]:
        """The RiskContext views carry — None unless the cost model
        opted into the risk term, cached until the metadata or the
        clock moves (rates are free-map-independent)."""
        if not self.cost_model.risk_aware:
            return None
        ctx = self._risk_cache
        if ctx is None or ctx.now != self.risk_now:
            ctx = RiskContext(self.cost_model, self.lease_until_s,
                              self.hazards, self.blast_group,
                              self.risk_now)
            self._risk_cache = ctx
        return ctx

    def set_host_risk(self,
                      lease_until_s: Optional[Sequence[float]] = None,
                      hazards: Optional[Sequence[float]] = None,
                      blast_groups: Optional[Sequence[int]] = None
                      ) -> None:
        """Bulk-install risk metadata (lease table from the provider,
        hazard estimates from ``fleet.HazardEstimator``, blast groups
        from rack topology).  Lengths must match the current fleet."""
        if lease_until_s is not None:
            arr = np.asarray(lease_until_s, dtype=np.float64)
            assert len(arr) == self.hosts
            self.lease_until_s = arr
        if hazards is not None:
            arr = np.asarray(hazards, dtype=np.float64)
            assert len(arr) == self.hosts
            self.hazards = arr
        if blast_groups is not None:
            arr = np.asarray(blast_groups, dtype=np.int64)
            assert len(arr) == self.hosts
            self.blast_group = arr
        self._risk_cache = None

    def risk_tick(self, now: float) -> None:
        """Advance the clock lease-remaining is measured against (the
        scheduling loop calls this before placing under risk)."""
        self.risk_now = float(now)

    def _copy_risk_to(self, eng: "PlacementEngine") -> None:
        eng.lease_until_s = self.lease_until_s.copy()
        eng.hazards = self.hazards.copy()
        eng.blast_group = self.blast_group.copy()
        eng.risk_now = self.risk_now

    def clone_empty(self) -> "PlacementEngine":
        """A fresh, idle engine of the same shape (hosts, capacities,
        speeds, policy, cost model) — what ``Fabric.predict_trace``
        simulates against so prediction and live execution share one
        accounting configuration."""
        eng = type(self)(self.hosts, self.chips_per_host,
                         policy=self.default_policy,
                         capacities=list(self.capacities),
                         speeds=None if self.speeds is None
                         else list(self.speeds),
                         cost_model=self.cost_model)
        self._copy_risk_to(eng)        # prediction sees the same leases
        return eng

    # ---- free-map mutation (the one place chips move) ----------------------
    def _take(self, placement: Sequence[Tuple[int, int]]) -> None:
        """Move chips out of the free pool, maintaining the incremental
        summaries.  Every mutation path (reserve/bind/apply_migration)
        funnels through here so subclasses can track shard summaries.
        Conservation is asserted per touched host — O(gang), replacing
        the old O(hosts) full-map scans on the per-decision path.  Wide
        placements (a spread gang touches ~n hosts) take the fancy-index
        path; short ones stay on the cheaper scalar loop.  Fancy
        indexing applies ONE update per index, so a placement that
        repeats a host (never policy-emitted, but ``bind`` adopts
        external placements) must take the scalar loop instead."""
        if len(placement) > 4 \
                and len({h for h, _ in placement}) == len(placement):
            hs = np.array([h for h, _ in placement], dtype=np.int64)
            cs = np.array([c for _, c in placement], dtype=np.int64)
            self.free[hs] -= cs
            assert (self.free[hs] >= 0).all(), "host oversubscribed"
            self._idle_chips -= int(cs.sum())
            if self.speeds is not None:
                self._idle_eff -= float((cs * self.speeds[hs]).sum())
            else:
                self._idle_eff = float(self._idle_chips)
            return
        taken = 0
        for h, c in placement:
            self.free[h] -= c
            assert self.free[h] >= 0, f"host {h} oversubscribed"
            taken += c
            if self.speeds is not None:
                self._idle_eff -= c * float(self.speeds[h])
        self._idle_chips -= taken
        if self.speeds is None:
            self._idle_eff = float(self._idle_chips)

    def _retire_draining(self, placement: Sequence[Tuple[int, int]]
                         ) -> Sequence[Tuple[int, int]]:
        """Chips returned on a draining host go back to the *provider*,
        not the free pool: the host's capacity shrinks to its remaining
        usage (when it hits 0 the lease is fully surrendered).  Returns
        the entries that still free normally.  Only called when some
        host is draining, so churn-free paths never pay for it."""
        live: List[Tuple[int, int]] = []
        for h, c in placement:
            if self.draining[h]:
                self.capacities[h] -= c
                assert self.capacities[h] >= 0, f"host {h} over-retired"
            else:
                live.append((h, c))
        return live

    def _give(self, placement: Sequence[Tuple[int, int]]) -> None:
        """Return chips to the free pool (inverse of ``_take``; same
        unique-host requirement for the fancy-index path).  Chips landing
        on a draining host are retired instead (``_retire_draining``)."""
        if self._any_draining:
            placement = self._retire_draining(placement)
            if not placement:
                return
        if len(placement) > 4 \
                and len({h for h, _ in placement}) == len(placement):
            hs = np.array([h for h, _ in placement], dtype=np.int64)
            cs = np.array([c for _, c in placement], dtype=np.int64)
            self.free[hs] += cs
            assert (self.free[hs] <= self.capacities[hs]).all(), \
                "host over-freed"
            self._idle_chips += int(cs.sum())
            if self.speeds is not None:
                self._idle_eff += float((cs * self.speeds[hs]).sum())
            else:
                self._idle_eff = float(self._idle_chips)
            return
        given = 0
        for h, c in placement:
            self.free[h] += c
            assert self.free[h] <= self.capacities[h], \
                f"host {h} over-freed"
            given += c
            if self.speeds is not None:
                self._idle_eff += c * float(self.speeds[h])
        self._idle_chips += given
        if self.speeds is None:
            self._idle_eff = float(self._idle_chips)

    def _resolve(self, policy: Union[str, PlacementPolicy, None]
                 ) -> PlacementPolicy:
        """Resolved policy bound to this engine's cost model, cached
        (one ``with_model`` bind per distinct policy per engine instead
        of one per decision)."""
        if policy is None:
            return self.default_policy
        key = policy if isinstance(policy, str) else id(policy)
        hit = self._policy_cache.get(key)
        if hit is not None and (hit[0] is policy or hit[0] == policy):
            return hit[1]
        pol = resolve_policy(policy, self.default_policy).with_model(
            self.cost_model)
        self._policy_cache[key] = (policy, pol)
        return pol

    # ---- telemetry ----------------------------------------------------------
    def _record_decision(self, name: str, t0: float, *,
                         n: Optional[int] = None,
                         placed: Optional[bool] = None,
                         policy: Union[str, PlacementPolicy, None] = None,
                         kind: Optional[str] = None,
                         plans: Optional[int] = None) -> None:
        """Record one scheduling decision's span + latency histogram.
        Only called from the public decision wrappers, and only when a
        live telemetry recorder is installed."""
        tel = telemetry.get()
        t1 = time.perf_counter()
        dt = t1 - t0
        tel.count(f"placement.{name}")
        tel.observe("placement.decision_latency_s", dt)
        tel.observe(f"placement.{name}_latency_s", dt)
        pol = policy if isinstance(policy, str) else (
            "default" if policy is None else type(policy).__name__)
        steal = getattr(self, "_steal_left", None)
        budget = getattr(self, "steal_budget", 0)
        attrs = {"policy": pol, "kind": kind,
                 "engine": type(self).__name__,
                 "hops": int(self.decision_hops),
                 "candidates": int((self.free > 0).sum())}
        if n is not None:
            attrs["n"] = int(n)
        if placed is not None:
            attrs["placed"] = bool(placed)
        if plans is not None:
            attrs["plans"] = int(plans)
        if budget and steal is not None and steal != float("inf"):
            attrs["steal_spent"] = int(budget - steal)
        tel.span_at(f"placement.{name}", t0, t1, track="sched",
                    clock="wall", **attrs)

    # ---- reservation lifecycle ---------------------------------------------
    def reserve(self, n: int,
                policy: Union[str, PlacementPolicy, None] = None,
                kind: Optional[str] = None) -> Optional[Reservation]:
        if not telemetry.get().enabled:
            return self._reserve_impl(n, policy, kind=kind)
        t0 = time.perf_counter()
        res = self._reserve_impl(n, policy, kind=kind)
        self._record_decision("reserve", t0, n=n, placed=res is not None,
                              policy=policy, kind=kind)
        return res

    def _reserve_impl(self, n: int,
                      policy: Union[str, PlacementPolicy, None] = None,
                      kind: Optional[str] = None) -> Optional[Reservation]:
        if _VECTORIZED:
            if n > self._idle_chips:
                # no policy can place n chips with fewer idle (every
                # placement draws at least n from the free pool), so a
                # blocked-queue probe fails before building a view
                return None
            pol = self._resolve(policy)
            view = self.view()
        else:
            # pre-PR decision path (reference_loops): re-resolve + bind
            # the policy, copy the view, recompute summaries per access
            pol = resolve_policy(policy, self.default_policy).with_model(
                self.cost_model)
            view = ClusterView(self.free.copy(), self.chips_per_host,
                               self.capacities, self.speeds)
        placement = pol.place(view, n, kind=kind)
        if placement is None:
            return None
        self._take(placement)
        if not _VECTORIZED:
            assert (self.free >= 0).all()
        return Reservation(placement, slice_size=pol.slice_size)

    def commit(self, res: Reservation, job_id: str) -> Allocation:
        assert not res.settled, "reservation already settled"
        res.settled = True
        for h, _ in res.placement:
            self.jobs_on_host[h].add(job_id)
        alloc = Allocation(job_id, sorted(res.placement),
                           slice_size=res.slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def cancel(self, res: Reservation) -> None:
        assert not res.settled, "reservation already settled"
        res.settled = True
        self._give(res.placement)       # per-host conservation asserts

    # ---- allocation ----------------------------------------------------------
    def allocate(self, job_id: str, n: int,
                 policy: Union[str, PlacementPolicy, None] = None,
                 kind: Optional[str] = None) -> Optional[Allocation]:
        res = self.reserve(n, policy, kind=kind)
        return None if res is None else self.commit(res, job_id)

    def bind(self, job_id: str, placement: Sequence[Tuple[int, int]],
             slice_size: int = 0) -> Allocation:
        """Adopt an externally-determined placement (the live runtime
        attaching the gang it was launched with)."""
        for h, c in placement:
            assert 0 < c <= self.free[h], \
                f"bind over-subscribes host {h}: {c} > {self.free[h]}"
            self.jobs_on_host[h].add(job_id)
        self._take(placement)
        alloc = Allocation(job_id, sorted(placement), slice_size=slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def release(self, alloc: Allocation) -> None:
        for h, _ in alloc.placement:
            self.jobs_on_host[h].discard(alloc.job_id)
        self._give(alloc.placement)     # per-host conservation asserts
        self.allocations.pop(alloc.job_id, None)

    # ---- preemption -----------------------------------------------------------
    def preemption_plan(self, n: int, priority: int,
                        priorities: Dict[str, int],
                        policy: Union[str, PlacementPolicy, None] = None,
                        preempt: Optional[PreemptPolicy] = None,
                        kind: Optional[str] = None) -> Optional[List[str]]:
        if not telemetry.get().enabled:
            return self._preemption_impl(n, priority, priorities,
                                         policy=policy, preempt=preempt,
                                         kind=kind)
        t0 = time.perf_counter()
        plan = self._preemption_impl(n, priority, priorities,
                                     policy=policy, preempt=preempt,
                                     kind=kind)
        self._record_decision("preemption_plan", t0, n=n,
                              placed=plan is not None, policy=policy,
                              kind=kind,
                              plans=len(plan) if plan else 0)
        return plan

    def _preemption_impl(self, n: int, priority: int,
                         priorities: Dict[str, int],
                         policy: Union[str, PlacementPolicy, None] = None,
                         preempt: Optional[PreemptPolicy] = None,
                         kind: Optional[str] = None
                         ) -> Optional[List[str]]:
        """Plan victims (see ``PreemptPolicy.plan``) against the live
        allocation table; the caller checkpoints + releases + requeues."""
        return (preempt or PreemptPolicy()).plan(self, n, priority,
                                                 priorities, policy,
                                                 kind=kind)

    # ---- migration (defragmentation at barrier points) ------------------------
    def migration_plan(self, allocs: Sequence[Allocation],
                       kinds: Optional[Mapping[str, str]] = None,
                       remaining: Optional[Mapping[str, float]] = None
                       ) -> List[Tuple[str, Placement]]:
        if not telemetry.get().enabled:
            return self._migration_impl(allocs, kinds=kinds,
                                        remaining=remaining)
        t0 = time.perf_counter()
        plans = self._migration_impl(allocs, kinds=kinds,
                                     remaining=remaining)
        self._record_decision("migration_plan", t0, n=len(allocs),
                              plans=len(plans))
        return plans

    def _migration_impl(self, allocs: Sequence[Allocation],
                       kinds: Optional[Mapping[str, str]] = None,
                       remaining: Optional[Mapping[str, float]] = None
                       ) -> List[Tuple[str, Placement]]:
        """For each granular gang, try to find a better placement using
        currently-free chips (+ the chips the gang already holds).
        Returns [(job_id, new_placement)].

        Homogeneous fleet: consolidate fragmented gangs onto fewer hosts
        (the pre-CostModel behaviour, bit-identical).  Heterogeneous
        fleet: candidate moves are costed with the engine's ``CostModel``
        under the gang's job kind (``kinds``), so a gang also migrates
        onto faster hosts when that lowers its predicted ``T`` — the
        same criterion the simulator's rate integration uses.
        ``remaining`` (job_id -> seconds of work left under the current
        placement) makes that check cost-aware: the predicted saving on
        the remaining work must exceed ``CostModel.migration_cost_s``
        (the snapshot transfer the move will pay).  Without it (a
        caller-initiated live barrier migration) any strict improvement
        is emitted.

        Invariants: slice allocations are never migrated; a plan that
        does not strictly improve (fewer hosts / lower predicted T) is
        not emitted; plans are committed against a scratch free map so
        they never double-book chips among themselves.
        """
        plans = []
        free = self.free.copy()
        drain = self.draining if self._any_draining else None
        for alloc in allocs:
            new_placement = self._plan_move(
                free, alloc, alloc.placement, self.heterogeneous,
                self.speeds, (kinds or {}).get(alloc.job_id),
                (remaining or {}).get(alloc.job_id), draining=drain)
            if new_placement is not None:
                plans.append((alloc.job_id, new_placement))
        return plans

    def _plan_move(self, free: np.ndarray, alloc: Allocation,
                   placement: Placement, hetero: bool,
                   speeds: Optional[np.ndarray], kind: Optional[str],
                   rem: Optional[float],
                   draining: Optional[np.ndarray] = None
                   ) -> Optional[Placement]:
        """Plan one gang's move against the scratch ``free`` map (shared
        across the whole planning pass so plans never double-book) and
        commit the winning plan into it.  ``free``/``placement``/
        ``speeds`` share a coordinate space — global for the centralised
        engine, a shard slice (with local host indices) for shard-local
        planning — so a shard decision only touches its own O(shard)
        state.  Returns the new placement, or None to stay put.

        Scratch mutation in place of the old per-gang ``free.copy()``:
        the gang's held chips are added before planning and removed
        again when no plan is emitted — O(gang) instead of O(hosts) per
        candidate gang (``reference_loops()`` restores the pre-PR
        per-gang copy for A/B benchmarking)."""
        if alloc.slice_size:
            return None
        if not hetero and len(placement) <= 1:
            return None
        model = self.cost_model
        avail = free if _VECTORIZED else free.copy()
        # gang's own chips count — except chips on a draining host,
        # which are being reclaimed and must not be re-planned onto
        # (a draining host's free is already 0, so nothing else can
        # land there either); churn-free fleets pass draining=None
        cred = placement if draining is None else [
            (h, c) for h, c in placement if not draining[h]]
        for h, c in cred:
            avail[h] += c
        new_placement: Optional[Placement] = None
        if hetero:
            current = model.score(placement, kind, speeds)
            candidates = [p for p in (
                _greedy_most_free(avail, alloc.n, speeds),
                _greedy_most_free(avail, alloc.n))
                if p is not None and p != placement]
            if candidates:
                best = min(candidates,
                           key=lambda p: model.score(p, kind, speeds))
                best_score = model.score(best, kind, speeds)
                if best_score < current - 1e-12:
                    # rate scales as 1/score, so the move shrinks the
                    # remaining time by rem*(1 - best/current); it must
                    # buy back the snapshot transfer it costs
                    if rem is None or rem * (1.0 - best_score / current) \
                            > model.migration_cost_s:
                        new_placement = best
        else:
            # can the gang fit on fewer hosts?
            cand = _greedy_most_free(avail, alloc.n)
            if cand is not None and len(cand) < len(placement):
                new_placement = cand
        if new_placement is None:             # stay put: undo the credit
            if avail is free:
                for h, c in cred:
                    free[h] -= c
            return None
        if avail is free:                     # commit into the scratch
            for h, c in new_placement:
                free[h] -= c
        else:
            for h, c in cred:
                free[h] += c
            for h, c in new_placement:
                free[h] -= c
        return new_placement

    def apply_migration(self, alloc: Allocation,
                        new_placement: Sequence[Tuple[int, int]]
                        ) -> Allocation:
        self.release(alloc)
        for h, _ in new_placement:
            self.jobs_on_host[h].add(alloc.job_id)
        self._take(new_placement)       # per-host conservation asserts
        new = Allocation(alloc.job_id, sorted(new_placement))
        self.allocations[alloc.job_id] = new
        return new

    # ---- fleet churn (leased hosts come and go; see core.fleet) -------------
    def alive_hosts(self) -> int:
        """Hosts still holding capacity (leased and not fully retired) —
        what adaptive shard sizing scales against."""
        return int((self.capacities > 0).sum())

    # True when a scheduling loop (the simulator's queue pump) owns the
    # steal-budget lifecycle; False = direct use, where each decision
    # resets its own budget (a per-decision cap) so a one-shot caller
    # can never be starved by budget a past decision spent
    external_budget_reset = False

    def reset_steal_budget(self) -> None:
        """Per-scheduling-pass budget reset (a no-op here; the sharded
        engine caps cross-shard split/escalation attempts per pump)."""

    def add_hosts(self, capacities: Sequence[int],
                  speeds: Optional[Sequence[float]] = None) -> List[int]:
        """Lease new hosts into the fleet (a FleetEvent ``join``).

        New hosts append at the end of the index space (retired host
        slots are never reused, so existing placements keep their
        coordinates).  ``speeds`` carries the new hosts' generation
        factors; when either side of the fleet has speeds the other is
        padded at 1.0.  Returns the new host indices."""
        caps = np.asarray(list(capacities), dtype=np.int64)
        assert len(caps) > 0 and (caps > 0).all() \
            and (caps <= self.chips_per_host).all()
        k = len(caps)
        new_idx = list(range(self.hosts, self.hosts + k))
        if speeds is not None or self.speeds is not None:
            old = (self.speeds if self.speeds is not None
                   else np.ones(self.hosts, dtype=np.float64))
            new = (np.asarray(list(speeds), dtype=np.float64)
                   if speeds is not None
                   else np.ones(k, dtype=np.float64))
            assert len(new) == k and (new > 0).all()
            self.speeds = np.concatenate([old, new])
        self.capacities = np.concatenate([self.capacities, caps])
        self.free = np.concatenate([self.free, caps])
        self.draining = np.concatenate(
            [self.draining, np.zeros(k, dtype=bool)])
        # risk metadata grows with benign defaults: fresh leases with
        # no known end, no failure history, each joiner its own blast
        # group (callers refine via set_host_risk)
        self.lease_until_s = np.concatenate(
            [self.lease_until_s, np.full(k, np.inf)])
        self.hazards = np.concatenate([self.hazards, np.zeros(k)])
        nb = (int(self.blast_group.max()) + 1 if len(self.blast_group)
              else 0)
        self.blast_group = np.concatenate(
            [self.blast_group, np.arange(nb, nb + k, dtype=np.int64)])
        self._risk_cache = None
        self.jobs_on_host.extend(set() for _ in range(k))
        self.hosts += k
        self._idle_chips += int(caps.sum())
        if self.speeds is None:
            self._idle_eff = float(self._idle_chips)
        else:
            self._idle_eff += float(
                (caps * self.speeds[new_idx]).sum())
            self._hetero = bool((self.speeds != self.speeds[0]).any())
        return new_idx

    def drain_hosts(self, hosts: Sequence[int]) -> None:
        """Begin a lease reclaim (a FleetEvent ``reclaim``): the hosts
        take no new placements (their free chips are surrendered to the
        provider immediately; capacity shrinks to current usage) and
        chips later freed on them retire instead of re-entering the
        pool.  Gangs still running there are the caller's problem:
        ``evacuation_plan`` for the graceful path, ``fail_hosts`` when
        the drain deadline expires."""
        for h in hosts:
            h = int(h)
            if self.draining[h]:
                continue
            f = int(self.free[h])
            if f:
                self._take([(h, f)])     # leaves the idle summaries
            self.capacities[h] -= f
            self.draining[h] = True
        self._any_draining = bool(self.draining.any())

    def fail_hosts(self, hosts: Sequence[int]) -> List[str]:
        """Hard host failure (a FleetEvent ``fail``, or a drain deadline
        expiring): every gang touching a failed host loses its whole
        allocation — chips on surviving hosts return to the pool, chips
        on the failed hosts vanish, and the host's capacity drops to 0
        (the slot stays, dead, so indices never shift).  Returns the
        job_ids that lost chips; the caller requeues each from its last
        checkpoint (the Faasm-style snapshot recovery path)."""
        dead = {int(h) for h in hosts}
        victims = [a for a in self.allocations.values()
                   if any(h in dead for h, _ in a.placement)]
        for a in victims:
            for h, _ in a.placement:
                self.jobs_on_host[h].discard(a.job_id)
            survivors = [(h, c) for h, c in a.placement
                         if h not in dead]
            if survivors:
                self._give(survivors)    # draining hosts retire instead
            self.allocations.pop(a.job_id)
        for h in dead:
            f = int(self.free[h])
            if f:
                self._take([(h, f)])
            self.capacities[h] = 0
            self.draining[h] = False
        self._any_draining = bool(self.draining.any())
        return [a.job_id for a in victims]

    def evacuation_plan(self, hosts: Optional[Sequence[int]] = None,
                        kinds: Optional[Mapping[str, str]] = None
                        ) -> Tuple[List[Tuple[str, Placement]], List[str]]:
        if not telemetry.get().enabled:
            return self._evacuation_impl(hosts, kinds=kinds)
        t0 = time.perf_counter()
        plans, stranded = self._evacuation_impl(hosts, kinds=kinds)
        self._record_decision("evacuation_plan", t0, plans=len(plans),
                              n=len(stranded))
        return plans, stranded

    def _evacuation_impl(self, hosts: Optional[Sequence[int]] = None,
                         kinds: Optional[Mapping[str, str]] = None
                         ) -> Tuple[List[Tuple[str, Placement]],
                                    List[str]]:
        """Plan moves off doomed hosts (``hosts``; default: everything
        draining) — the graceful-drain half of a lease reclaim.

        Each affected granular gang is re-placed with the greedy fill
        over the surviving free chips plus its own chips on safe hosts
        (on heterogeneous fleets the cost model picks between the
        throughput-ordered and plain greedy candidates under the gang's
        job kind, exactly like ``migration_plan``'s hetero move).  Plans
        share one scratch map so they never double-book, and the caller
        applies them through ``apply_migration`` — the same machinery as
        barrier migration, which retires the vacated draining chips via
        ``_give``.  Returns ``(plans, stranded)``: stranded gangs (no
        fit, or slice allocations, which never migrate) run until the
        drain deadline and then hard-fail.  Evacuation is a global
        (cross-shard) decision by construction — a whole shard may be
        draining — so the sharded engine inherits this unchanged."""
        # every draining host is doomed regardless of which reclaim this
        # pass is for: fold the full draining set into the mask so a
        # gang's keep-credit on an *earlier* reclaim's host is never
        # counted as a landing spot (overlapping reclaims)
        mask = self.draining.copy()
        if hosts is not None:
            mask[[int(h) for h in hosts]] = True
        free = self.free.copy()
        free[mask] = 0                   # never evacuate *onto* doom
        hetero = self.heterogeneous
        model = self.cost_model
        plans: List[Tuple[str, Placement]] = []
        stranded: List[str] = []
        for alloc in list(self.allocations.values()):
            if not any(mask[h] for h, _ in alloc.placement):
                continue
            if alloc.slice_size:
                stranded.append(alloc.job_id)
                continue
            keep = [(h, c) for h, c in alloc.placement if not mask[h]]
            for h, c in keep:
                free[h] += c             # own safe chips are reusable
            if hetero:
                kind = (kinds or {}).get(alloc.job_id)
                cands = [p for p in (
                    _greedy_most_free(free, alloc.n, self.speeds),
                    _greedy_most_free(free, alloc.n)) if p is not None]
                cand = min(cands, key=lambda p: model.score(
                    p, kind, self.speeds)) if cands else None
            else:
                cand = _greedy_most_free(free, alloc.n)
            if cand is None:
                for h, c in keep:
                    free[h] -= c
                stranded.append(alloc.job_id)
                continue
            for h, c in cand:
                free[h] -= c
            plans.append((alloc.job_id, cand))
        return plans, stranded

    def shrink_plan(self, worlds: Sequence[int],
                    credit: Sequence[Tuple[int, int]] = (),
                    avoid: Sequence[int] = (),
                    policy: Union[str, PlacementPolicy, None] = None,
                    kind: Optional[str] = None
                    ) -> Optional[Placement]:
        if not telemetry.get().enabled:
            return self._shrink_impl(worlds, credit=credit, avoid=avoid,
                                     policy=policy, kind=kind)
        t0 = time.perf_counter()
        p = self._shrink_impl(worlds, credit=credit, avoid=avoid,
                              policy=policy, kind=kind)
        self._record_decision("shrink_plan", t0,
                              n=max(worlds) if len(worlds) else 0,
                              placed=p is not None, policy=policy,
                              kind=kind)
        return p

    def _shrink_impl(self, worlds: Sequence[int],
                     credit: Sequence[Tuple[int, int]] = (),
                     avoid: Sequence[int] = (),
                     policy: Union[str, PlacementPolicy, None] = None,
                     kind: Optional[str] = None
                     ) -> Optional[Placement]:
        """Shrink-before-rollback (DESIGN.md §13): the largest world in
        ``worlds`` (descending; see ``elastic.shrink_worlds``) placeable
        on surviving capacity — draining hosts and ``avoid`` are
        excluded, and the stranded gang's own chips on safe hosts
        (``credit``) count as landing room.  Returns the placement for
        the first world that fits, or None when checkpoint rollback is
        the only option left.  Like ``evacuation_plan`` this is a
        global (cross-shard) recovery decision, so the sharded engine
        inherits it unchanged."""
        pol = self._resolve(policy)
        free = self.free.copy()
        if self._any_draining:
            free[self.draining] = 0
        for h in avoid:
            free[int(h)] = 0
        for h, c in credit:
            free[h] += c
        for w in worlds:
            p = pol.place(self.view_with(free), w, kind=kind)
            if p is not None:
                return p
        return None


# ---------------------------------------------------------------------------
# Sharded engine (decentralised scheduling, the Fig 11 fix)
# ---------------------------------------------------------------------------
class _ShardScope:
    """Engine-like facade over one shard for ``PreemptPolicy.plan``:
    shard-slice free map, shard-local allocation table (local host
    indices), shard-slice policy views.  Victim ids come back unchanged,
    so a shard-local plan drops straight into the caller's checkpoint +
    requeue path."""

    def __init__(self, engine: "ShardedPlacementEngine", shard: int):
        lo, hi = engine.shard_bounds[shard]
        self._engine = engine
        self._shard = shard
        self._lo, self._hi = lo, hi
        self.free = engine.free[lo:hi]
        self.draining = engine.draining[lo:hi]
        self.default_policy = engine.default_policy
        self.cost_model = engine.cost_model
        self.allocations = {
            a.job_id: Allocation(a.job_id,
                                 [(h - lo, c) for h, c in a.placement],
                                 slice_size=a.slice_size)
            for a in engine.allocations.values()
            if engine.shard_of_gang(a) == shard}

    def view_with(self, free: np.ndarray) -> ClusterView:
        e, lo, hi = self._engine, self._lo, self._hi
        ctx = e._risk_context()
        return ClusterView(free, e.chips_per_host, e.capacities[lo:hi],
                           None if e.speeds is None else e.speeds[lo:hi],
                           hetero=e.shard_hetero[self._shard],
                           risk=None if ctx is None
                           else ctx.sliced(lo, hi))


class ShardedPlacementEngine(PlacementEngine):
    """Decentralised placement: the fleet is partitioned into host-group
    shards of ``hosts_per_shard`` consecutive hosts (ragged last shard),
    and a placement decision touches O(chips_needed + shards) state
    instead of O(hosts):

    1. the *summary index* — per-shard idle chips, idle (speed-weighted)
       throughput, and max contiguous free block, all maintained
       incrementally on commit/release — picks candidate shards:
       shards that could co-locate the gang on one host first, then by
       idle throughput (binpack's most-free-first, at shard granularity);
    2. the policy runs on the chosen shard's O(hosts_per_shard) slice
       only; a miss *forwards* to the next candidate shard
       (``decision_hops`` counts the extra shards consulted — the
       simulator charges them as forwarding latency);
    3. a gang no single shard can hold is *split*: shards contribute
       greedily in summary order, each placing its part locally.

    Accounting stays global (one free map, one allocation table), so
    release / bind / reservations / ``apply_migration`` are inherited
    unchanged and consumers see the exact ``PlacementEngine`` interface.
    ``migration_plan`` and ``preemption_plan`` run shard-locally for
    gangs inside one shard, with an explicit cross-shard escalation
    path (global planning) for gangs or arrivals that span shards.

    With a single shard covering the whole fleet every decision —
    placement, migration, preemption — is bit-identical to the
    centralised engine, and ``decision_hops`` stays 0.

    ``hosts_per_shard="auto"`` sizes shards from the fleet
    (``auto_shard_hosts``) and re-balances as fleet churn moves the
    live host count; a numeric spec keeps its fleet-size clamp across
    joins.  ``steal_budget`` caps cross-shard forwards / splits /
    preemption escalations per scheduling pass (reset once per queue
    pump by the simulator; 0 = unbounded, bit-identical) so a
    churn-thrashed backlog cannot hammer the summary index.
    """

    def __init__(self, hosts: int, chips_per_host: int,
                 hosts_per_shard: Union[int, str] = DEFAULT_SHARD_HOSTS,
                 steal_budget: int = 0, **kwargs):
        super().__init__(hosts, chips_per_host, **kwargs)
        # "auto" sizes shards from the fleet (auto_shard_hosts) and
        # re-sizes them as churn changes the live host count; a numeric
        # spec is fixed for the engine's lifetime
        self._shard_spec: Union[int, str] = hosts_per_shard
        if hosts_per_shard == "auto":
            hosts_per_shard = auto_shard_hosts(hosts)
        assert int(hosts_per_shard) > 0
        self.hosts_per_shard = min(int(hosts_per_shard), hosts)
        # steal budget: cross-shard split / escalation attempts allowed
        # per scheduling pass (0 = unbounded — the pre-budget
        # behaviour, bit-identical); the simulator resets it once per
        # queue pump so a churn-thrashed backlog cannot hammer the
        # summary index with hopeless cross-shard work
        self.steal_budget = steal_budget
        self._steal_left: float = float("inf")
        self.reset_steal_budget()
        self._rebuild_shards()

    def _rebuild_shards(self) -> None:
        """(Re)compute shard bounds and the summary index from the live
        free map — run at construction and after fleet churn changes
        the host count (``add_hosts``) or the adaptive shard size.
        Dead/retired host slots stay inside their shard at capacity 0;
        summaries are exact by construction."""
        hosts = self.hosts
        self.shard_bounds: List[Tuple[int, int]] = [
            (lo, min(lo + self.hosts_per_shard, hosts))
            for lo in range(0, hosts, self.hosts_per_shard)]
        self.n_shards = len(self.shard_bounds)
        self._shard_of = np.repeat(np.arange(self.n_shards),
                                   [hi - lo for lo, hi
                                    in self.shard_bounds])
        # summary index: incrementally maintained on every _take/_give
        self._shard_idle = np.array(
            [int(self.free[lo:hi].sum()) for lo, hi in self.shard_bounds],
            dtype=np.int64)
        self._shard_eff = np.array(
            [float(self._shard_idle[s]) if self.speeds is None
             else float((self.free[lo:hi] * self.speeds[lo:hi]).sum())
             for s, (lo, hi) in enumerate(self.shard_bounds)])
        self._shard_max = np.array(
            [int(self.free[lo:hi].max()) for lo, hi in self.shard_bounds],
            dtype=np.int64)
        self._shard_dirty = np.zeros(self.n_shards, dtype=bool)
        self.shard_hetero = [
            self.speeds is not None and bool(
                (self.speeds[lo:hi] != self.speeds[lo]).any())
            for lo, hi in self.shard_bounds]

    # ---- fleet churn --------------------------------------------------------
    def reset_steal_budget(self) -> None:
        self._steal_left = (float("inf") if not self.steal_budget
                            else float(self.steal_budget))

    def _spend_steal(self) -> bool:
        """Consume one cross-shard attempt; False when exhausted."""
        if self._steal_left <= 0:
            return False
        self._steal_left -= 1
        return True

    def _maybe_resize_shards(self) -> bool:
        """Resharding hook: churn that moves the host count re-derives
        the shard size from the original spec — ``"auto"`` re-balances
        against the live host count, a numeric spec re-applies its
        fleet-size clamp (so a spec covering the whole fleet keeps
        covering it after joins: single-shard parity with the
        centralised engine survives growth).  True when it changed."""
        if self._shard_spec == "auto":
            want = min(auto_shard_hosts(max(1, self.alive_hosts())),
                       self.hosts)
        else:
            want = min(int(self._shard_spec), self.hosts)
        if want == self.hosts_per_shard:
            return False
        self.hosts_per_shard = want
        return True

    def add_hosts(self, capacities: Sequence[int],
                  speeds: Optional[Sequence[float]] = None) -> List[int]:
        new_idx = super().add_hosts(capacities, speeds)
        self._maybe_resize_shards()
        self._rebuild_shards()          # new hosts need shard membership
        return new_idx

    def fail_hosts(self, hosts: Sequence[int]) -> List[str]:
        out = super().fail_hosts(hosts)
        # host slots persist (indices never shift), so only an adaptive
        # size change forces a rebuild — summaries already track the
        # retired chips through the _take/_give funnels
        if self._maybe_resize_shards():
            self._rebuild_shards()
        return out

    @property
    def sched_hosts(self) -> int:
        """One decision scans one shard, not the fleet — the latency
        term the simulator's ``sched="sharded"`` mode charges."""
        return self.hosts_per_shard

    def clone_empty(self) -> "ShardedPlacementEngine":
        eng = ShardedPlacementEngine(
            self.hosts, self.chips_per_host,
            hosts_per_shard=self._shard_spec,
            steal_budget=self.steal_budget,
            policy=self.default_policy, capacities=list(self.capacities),
            speeds=None if self.speeds is None else list(self.speeds),
            cost_model=self.cost_model)
        self._copy_risk_to(eng)
        return eng

    # ---- summary index ------------------------------------------------------
    def _take(self, placement: Sequence[Tuple[int, int]]) -> None:
        super()._take(placement)
        self._shard_delta(placement, -1)

    def _give(self, placement: Sequence[Tuple[int, int]]) -> None:
        # split off draining-host retirements BEFORE the shard delta:
        # retired chips never re-enter a shard's idle summary (the base
        # second pass then finds nothing draining left to filter)
        if self._any_draining:
            placement = self._retire_draining(placement)
            if not placement:
                return
        super()._give(placement)
        self._shard_delta(placement, +1)

    def _shard_delta(self, placement: Sequence[Tuple[int, int]],
                     sign: int) -> None:
        for h, c in placement:
            s = int(self._shard_of[h])
            self._shard_idle[s] += sign * c
            if self.speeds is not None:
                self._shard_eff[s] += sign * c * float(self.speeds[h])
            else:
                self._shard_eff[s] = float(self._shard_idle[s])
            self._shard_dirty[s] = True

    def _shard_risk_eff(self, kind: Optional[str]
                        ) -> Optional[np.ndarray]:
        """Summary index under risk: per-shard idle throughput with
        each host's contribution scaled by its risk discount — the
        lease/hazard metadata's entry into shard ranking, so decisions
        forward toward shards of safe capacity first.  One vectorized
        O(hosts) bincount, paid only in risk-aware mode (None keeps
        the exact incremental ``_shard_eff`` ordering)."""
        ctx = self._risk_context()
        if ctx is None:
            return None
        disc = ctx.discounts(kind)
        if disc is None:
            return None
        w = self.free * disc
        if self.speeds is not None:
            w = w * self.speeds
        return np.bincount(self._shard_of, weights=w,
                           minlength=self.n_shards)

    def _shard_max_free(self) -> np.ndarray:
        """Max contiguous free block per shard (lazily refreshed for
        shards whose free map moved since the last read)."""
        for s in np.nonzero(self._shard_dirty)[0]:
            lo, hi = self.shard_bounds[int(s)]
            self._shard_max[s] = int(self.free[lo:hi].max())
        self._shard_dirty[:] = False
        return self._shard_max

    def shard_of_gang(self, alloc: Allocation) -> Optional[int]:
        """The shard an allocation lives in, or None when it spans."""
        shards = {int(self._shard_of[h]) for h, _ in alloc.placement}
        return shards.pop() if len(shards) == 1 else None

    def _shard_view(self, shard: int) -> ClusterView:
        lo, hi = self.shard_bounds[shard]
        ctx = self._risk_context()
        return ClusterView(self.free[lo:hi], self.chips_per_host,
                           self.capacities[lo:hi],
                           None if self.speeds is None
                           else self.speeds[lo:hi],
                           hetero=self.shard_hetero[shard],
                           idle=int(self._shard_idle[shard]),
                           idle_eff=float(self._shard_eff[shard]),
                           risk=None if ctx is None
                           else ctx.sliced(lo, hi))

    # ---- placement ----------------------------------------------------------
    def _reserve_impl(self, n: int,
                      policy: Union[str, PlacementPolicy, None] = None,
                      kind: Optional[str] = None) -> Optional[Reservation]:
        pol = self._resolve(policy)
        self.decision_hops = 0
        if not self.external_budget_reset:
            self.reset_steal_budget()    # direct use: per-decision cap
        if n > self._idle_chips:
            return None
        consults = 0
        placement: Optional[Placement] = None
        # home shard first, then forward: shards that can co-locate the
        # gang on one host, then by idle throughput (summary index only
        # — no shard state is touched until the policy runs)
        fits_host = self._shard_max_free() >= n
        candidates = np.nonzero(self._shard_idle >= n)[0]
        if candidates.size:
            eff = self._shard_risk_eff(kind)
            if eff is None:
                eff = self._shard_eff
            order = candidates[np.lexsort(
                (-eff[candidates],
                 ~fits_host[candidates]))]
            for s in order:
                # forwarding beyond the home shard spends steal budget
                # (with budget 0 = unbounded this never breaks)
                if consults >= 1 and not self._spend_steal():
                    break
                lo, _ = self.shard_bounds[int(s)]
                local = pol.place(self._shard_view(int(s)), n, kind=kind)
                consults += 1
                if local is not None:
                    placement = sorted((h + lo, c) for h, c in local)
                    break
        if placement is None:
            if not self._spend_steal():  # a split is a cross-shard steal
                return None
            placement, split_consults = self._split_place(pol, n, kind)
            consults += split_consults
            if placement is None:
                return None
        self.decision_hops = consults - 1
        self._take(placement)           # per-host conservation asserts
        return Reservation(placement, slice_size=pol.slice_size)

    def _split_place(self, pol: PlacementPolicy, n: int,
                     kind: Optional[str]
                     ) -> Tuple[Optional[Placement], int]:
        """Cross-shard split for a gang no single shard can hold:
        shards contribute greedily in idle-throughput order, each
        placing its part through the policy on its own slice."""
        order = np.nonzero(self._shard_idle > 0)[0]
        eff = self._shard_risk_eff(kind)
        if eff is None:
            eff = self._shard_eff
        order = order[np.lexsort((-eff[order],))]
        parts: Placement = []
        remaining = n
        consults = 0
        for s in order:
            lo, _ = self.shard_bounds[int(s)]
            take = min(int(self._shard_idle[s]), remaining)
            view = self._shard_view(int(s))
            local = None
            while take > 0:
                local = pol.place(view, take, kind=kind)
                if local is not None:
                    break
                take -= 1           # slice policies may need fewer chips
            consults += 1
            if local is None:
                continue
            parts.extend((h + lo, c) for h, c in local)
            remaining -= sum(c for _, c in local)
            if remaining <= 0:
                break
        if remaining > 0:
            return None, consults
        return sorted(parts), consults

    # ---- preemption ---------------------------------------------------------
    def _preemption_impl(self, n: int, priority: int,
                         priorities: Dict[str, int],
                         policy: Union[str, PlacementPolicy, None] = None,
                         preempt: Optional[PreemptPolicy] = None,
                         kind: Optional[str] = None
                         ) -> Optional[List[str]]:
        """Shard-local victim planning: each shard (by idle throughput)
        plans against its own gangs and fit-probes its own slice, so the
        arrival lands entirely inside the shard that evicts for it.
        When no single shard can host the arrival even with evictions,
        the plan *escalates* cross-shard: the centralised planner runs
        over the global table (victims and placement may then span
        shards)."""
        pp = preempt or PreemptPolicy()
        if not self.external_budget_reset:
            self.reset_steal_budget()    # direct use: per-decision cap
        caps = np.array([int(self.capacities[lo:hi].sum())
                         for lo, hi in self.shard_bounds])
        order = np.nonzero(caps >= n)[0]
        order = order[np.lexsort((-self._shard_eff[order],))]
        for s in order:
            scope = _ShardScope(self, int(s))
            local_pri = {jid: priorities.get(jid, 0)
                         for jid in scope.allocations}
            plan = pp.plan(scope, n, priority, local_pri, policy,
                           kind=kind)
            if plan is not None:
                return plan
        if not self._spend_steal():     # escalation is a cross-shard steal
            return None
        return super()._preemption_impl(n, priority, priorities,
                                        policy=policy, preempt=pp,
                                        kind=kind)

    # ---- migration ----------------------------------------------------------
    def _migration_impl(self, allocs: Sequence[Allocation],
                        kinds: Optional[Mapping[str, str]] = None,
                        remaining: Optional[Mapping[str, float]] = None
                        ) -> List[Tuple[str, Placement]]:
        """Shard-local defragmentation: a gang inside one shard is
        re-planned against that shard's slice only (moves never leave
        the shard); a gang already spanning shards escalates to global
        planning.  One global scratch map keeps shard-local and
        escalated plans from double-booking each other."""
        plans = []
        free = self.free.copy()
        drain = self.draining if self._any_draining else None
        for alloc in allocs:
            shard = self.shard_of_gang(alloc)
            kind = (kinds or {}).get(alloc.job_id)
            rem = (remaining or {}).get(alloc.job_id)
            if shard is None:                 # spans shards: escalate
                new = self._plan_move(free, alloc, alloc.placement,
                                      self.heterogeneous, self.speeds,
                                      kind, rem, draining=drain)
            else:
                lo, hi = self.shard_bounds[shard]
                local = [(h - lo, c) for h, c in alloc.placement]
                new = self._plan_move(
                    free[lo:hi], alloc, local, self.shard_hetero[shard],
                    None if self.speeds is None else self.speeds[lo:hi],
                    kind, rem,
                    draining=None if drain is None else drain[lo:hi])
                if new is not None:
                    new = [(h + lo, c) for h, c in new]
            if new is not None:
                plans.append((alloc.job_id, new))
        return plans
