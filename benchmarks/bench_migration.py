"""Paper Fig 14 (Granule migration at runtime).

Two halves:
  * REAL migration mechanics on the host fabric (subprocess, 8 devices):
    snapshot -> restore wall time, full vs delta bytes moved, bit-exact
    verification — the actual cost side of Fig 14.
  * The speedup side (migrating a fragmented gang at 20/40/60/80% of the
    run) reproduced in the discrete-event simulator with the paper's
    calibration: network-bound jobs gain up to ~3.5x when migrated early;
    compute-bound jobs see single-digit gains and a slight loss at 80%.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.core import simulator as S
from repro.core.scheduler import Allocation

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PROG = """
import json, time
import jax, jax.numpy as jnp
from repro.core import migration, snapshot as snap_mod
from repro.core.elastic import make_dp_mesh, replicated_shardings
from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.optim.adamw import AdamWConfig

cfg = reduced_config("llama3.2-1b")
ocfg = AdamWConfig()
state = jax.jit(lambda k: M.init_train_state(k, cfg, ocfg))(
    jax.random.PRNGKey(0))
devs = jax.devices()
src = make_dp_mesh(devs[:4]); dst = make_dp_mesh(devs[4:])
state = jax.device_put(state, replicated_shardings(state, src))

out = {}
t0 = time.perf_counter()
moved, stats = migration.migrate_via_snapshot(
    "j", 0, state, replicated_shardings(state, dst))
out["full_migration_s"] = round(time.perf_counter() - t0, 3)
out["full_bytes_mb"] = round(stats["full_bytes"] / 2**20, 1)
assert migration.verify_migration(state, moved)

prior = snap_mod.take("j", 0, state)
state2 = {"params": jax.tree.map(lambda x: x, state["params"]),
          "opt": state["opt"]}
state2["params"]["final_norm"] = state2["params"]["final_norm"] * 1.001
t0 = time.perf_counter()
moved2, stats2 = migration.migrate_via_snapshot(
    "j", 1, state2, replicated_shardings(state, dst), prior=prior)
out["delta_migration_s"] = round(time.perf_counter() - t0, 3)
out["delta_bytes_mb"] = round(stats2["moved_bytes"] / 2**20, 3)
assert migration.verify_migration(state2, moved2)

# delta-chain checkpointing of the same live model state: one full
# base then per-step diffs (CheckpointManager delta_chain), restored
# bit-exactly through the chain
import tempfile
from repro.checkpoint.manager import CheckpointManager
with tempfile.TemporaryDirectory() as td:
    mgr = CheckpointManager(td, "mig", delta_chain=True,
                            rebase_every=4)
    st = state2
    t0 = time.perf_counter()
    for s in range(3):
        st = {"params": dict(st["params"]), "opt": st["opt"]}
        st["params"]["final_norm"] = st["params"]["final_norm"] * 1.001
        mgr.save(s, st)
    out["delta_chain_save_s"] = round(time.perf_counter() - t0, 3)
    deltas = [x["bytes"] for x in mgr.stats if x["kind"] == "delta"]
    out["delta_chain_link_mb"] = round(sum(deltas) / len(deltas)
                                       / 2**20, 3)
    out["delta_chain_full_mb"] = round(mgr.stats[0]["full_bytes"]
                                       / 2**20, 1)
    restored, step = mgr.restore(2)
    assert step == 2
print(json.dumps(out))
"""


def _single_job_speedup(kind: str, migrate_at: float) -> float:
    """One 8-rank job forced to fragment 4+4 over two hosts, optionally
    consolidated at ``migrate_at`` fraction of its work (paper Fig 14)."""
    job = S.Job("j", kind, 8, 400.0)
    frag = Allocation("j", [(0, 4), (1, 4)])
    whole = Allocation("j", [(0, 8)])

    def runtime(alloc_before, alloc_after, frac):
        rj = S.RunningJob(job, alloc_before, 0.0,
                          eff_parallelism=job.parallelism)
        t1 = frac / rj.rate()
        rj2 = S.RunningJob(job, alloc_after, 0.0,
                           eff_parallelism=job.parallelism)
        t2 = (1 - frac) / rj2.rate() + (S.MIGRATION_COST_S
                                        if frac < 1.0 else 0.0)
        return t1 + t2

    t_frag = runtime(frag, frag, 1.0)
    t_mig = runtime(frag, whole, migrate_at)
    return t_frag / t_mig


def run(report, tiny=False):
    if not tiny:
        # real snapshot/restore mechanics need the 8-device subprocess;
        # the smoke run keeps the (fast, pure) simulator half only
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = SRC
        res = subprocess.run([sys.executable, "-c",
                              textwrap.dedent(_PROG)],
                             capture_output=True, text=True, env=env,
                             timeout=1200)
        assert res.returncode == 0, res.stderr[-3000:]
        data = json.loads(res.stdout.strip().splitlines()[-1])
        for k, v in data.items():
            report(k, v, "", "Fig14 migration mechanics (real)")

    for kind, label in (("mpi-network", "all-to-all"),
                        ("mpi-compute", "LAMMPS")):
        coloc = _single_job_speedup(kind, 0.0)
        report(f"speedup/{label}/colocated", round(coloc, 2), "x",
               "Fig14 (1 VM reference)")
        for frac in (0.2, 0.4, 0.6, 0.8):
            sp = _single_job_speedup(kind, frac)
            report(f"speedup/{label}/migrate_at_{int(frac*100)}pct",
                   round(sp, 2), "x", "Fig14")
