"""Scheduler scalability at 32-256 hosts (Fig 11 + the decentralised fix).

Two measurements:

* **decisions/sec** — raw placement-decision throughput of the engine
  (reserve/cancel cycles) on a heavily fragmented ~2/3-utilized fleet
  whose gang sizes span the idle capacity, so placements cross many
  hosts — the regime where the pre-PR pure-Python fill loops are
  O(gang x hosts).  The ``reference_loops()`` baseline runs the exact
  pre-PR implementation (loop fills, per-call policy re-resolution,
  copied views, per-call summary recomputation); the vectorized engine
  and the sharded engine run the new hot path.  Also reported: trace
  *replay* throughput (decisions/sec of a full Simulator run, including
  migration planning and rate integration) for the same A/B.

* **simulated makespan, centralised vs sharded** — the same mixed trace
  scheduled by the centralised engine (per-decision latency
  ``SCHED_LATENCY_PER_HOST * hosts``) and by ``sched="sharded"``
  (``SCHED_LATENCY_PER_HOST * hosts_per_shard`` + forwarding hops).
  In the Fig 11 regime (128+ hosts) the centralised scan cost dominates
  queue-era scheduling and sharding wins the makespan.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import placement as P
from repro.core import simulator as S

SHARD_HOSTS = 16
POLICIES = ("binpack", "spread", "locality")
KINDS = ("mpi-compute", "omp", "mpi-network")


def _saturate(engine, seed=0):
    """Drive the fleet to a fragmented steady state: fill with small
    gangs, then release a third at random — free chips end up scattered
    a few per host across the whole fleet."""
    rng = np.random.default_rng(seed)
    live, i = [], 0
    while True:
        a = engine.allocate(f"warm{i}", int(rng.integers(6, 9)))
        if a is None:
            break
        live.append(a)
        i += 1
    rng.shuffle(live)
    for a in live[: len(live) // 3]:
        engine.release(a)


def _decision_rate(engine, decisions, seed=1):
    """Placement decisions/sec: reserve+cancel cycles against the
    fragmented fleet, gang sizes spanning half to most of the idle
    capacity (placements cross many hosts), policies round-robin."""
    rng = np.random.default_rng(seed)
    idle = engine.idle_chips()
    sizes = rng.integers(max(2, idle // 2), max(3, (9 * idle) // 10),
                         2048)
    t0 = time.perf_counter()
    for j in range(decisions):
        res = engine.reserve(int(sizes[j % 2048]),
                             policy=POLICIES[j % 3], kind=KINDS[j % 3])
        if res is not None:
            engine.cancel(res)
    return decisions / (time.perf_counter() - t0)


def _replay(hosts, njobs, sched="central"):
    """Full trace replay: wall-clock scheduling throughput and the
    simulated makespan under the engine's latency model."""
    jobs = S.mixed_trace(njobs, seed=hosts, arrival_rate=njobs / 120.0)
    sim = S.Simulator(hosts, 8, "granular", migrate=True,
                      policy="locality", backfill=True, sched=sched,
                      shard_hosts=SHARD_HOSTS)
    t0 = time.perf_counter()
    r = sim.run(jobs)
    wall = time.perf_counter() - t0
    decisions = sum(1 for a in r.actions
                    if a.kind in ("start", "resume", "migrate"))
    return decisions / wall, r.makespan


def run(report, tiny=False):
    scales = (32, 64) if tiny else (32, 64, 128, 256)
    k_dec = 200 if tiny else 2500

    # ---- decision throughput: pre-PR loops vs vectorized vs sharded ----
    for hosts in scales:
        eng = P.PlacementEngine(hosts, 8)
        _saturate(eng)
        with P.reference_loops():
            loop = _decision_rate(eng, k_dec)
        eng = P.PlacementEngine(hosts, 8)
        _saturate(eng)
        vec = _decision_rate(eng, k_dec)
        eng = P.ShardedPlacementEngine(hosts, 8,
                                       hosts_per_shard=SHARD_HOSTS)
        _saturate(eng)
        shard = _decision_rate(eng, k_dec)
        report(f"decisions_per_sec/{hosts}h/loop", round(loop, 0),
               "dec/s", "pre-PR loop implementation")
        report(f"decisions_per_sec/{hosts}h/vectorized", round(vec, 0),
               "dec/s", "numpy hot path")
        report(f"decisions_per_sec/{hosts}h/sharded", round(shard, 0),
               "dec/s", f"{SHARD_HOSTS}-host shards")
        report(f"decisions_per_sec/{hosts}h/vectorized_vs_loop",
               round(vec / loop, 2), "x",
               "acceptance: >=5x at 128 hosts")

    # ---- end-to-end: replay throughput + centralised vs sharded ----
    for hosts in scales:
        njobs = hosts if tiny else hosts * 3
        with P.reference_loops():
            loop_dps, _ = _replay(hosts, njobs)
        vec_dps, mk_central = _replay(hosts, njobs)
        shard_dps, mk_sharded = _replay(hosts, njobs, sched="sharded")
        report(f"replay/{hosts}h/decisions_per_sec_loop",
               round(loop_dps, 0), "dec/s", "pre-PR replay throughput")
        report(f"replay/{hosts}h/decisions_per_sec_vectorized",
               round(vec_dps, 0), "dec/s", "vectorized replay")
        report(f"replay/{hosts}h/speedup",
               round(vec_dps / loop_dps, 2), "x", "replay wall-clock")
        report(f"makespan/{hosts}h/central", round(mk_central, 1), "s",
               "SCHED_LATENCY_PER_HOST * hosts per decision")
        report(f"makespan/{hosts}h/sharded", round(mk_sharded, 1), "s",
               f"{SHARD_HOSTS}-host shards + forwarding hops")
        report(f"makespan/{hosts}h/sharded_win_pct",
               round((mk_central - mk_sharded) / mk_central * 100, 2),
               "% lower makespan",
               "acceptance: sharded beats central at 128/256 (Fig 11)")
        report(f"replay/{hosts}h/decisions_per_sec_sharded",
               round(shard_dps, 0), "dec/s", "sharded replay")
