"""Fleet dynamics: leased hosts that come and go under running gangs.

Faabric's economics (§2.1) only bite if the provider can actually move
capacity between applications — rFaaS (PAPERS.md) models that as
*leased, reclaimable executors*, and Faasm's snapshot-based state is
the recovery mechanism when a lease ends badly.  This module is the
churn side of that story; ``core.snapshot`` + the engine's
checkpoint/requeue machinery are the recovery side.

* ``FleetEvent`` — one timestamped change to the host set:

  - ``join``     new hosts lease in (``capacities`` chips each, optional
                 generation ``speeds``); indices append at the end so
                 running placements never shift.
  - ``reclaim``  a lease ends *with warning*: the hosts drain for
                 ``drain_s`` seconds — no new placements, gangs evacuate
                 gracefully (``PlacementEngine.evacuation_plan`` →
                 ``apply_migration``) — then whatever still holds chips
                 hard-fails.
  - ``fail``     hosts vanish with no warning: every gang touching them
                 is requeued from its last checkpoint, charging the work
                 since that checkpoint as lost.

* ``FleetController`` — applies events to a ``PlacementEngine`` (or
  ``ShardedPlacementEngine``) and returns a ``FleetOutcome`` of pure
  decisions: joined host indices, evacuation plans, stranded gangs,
  failed job_ids.  The *caller* — ``core.simulator``'s event loop, or
  ``core.fabric`` live — owns job/gang state and performs the actual
  moves, requeues and snapshot restores, so simulated and live churn
  share one semantics.

* ``churn_schedule`` — the trace-side regimes the CLI and benchmarks
  compose with arrival traces:

  - ``spot-heavy``                Poisson lease reclaims (short drains)
                                  with like-for-like rejoins — the spot
                                  market.
  - ``steady-join``               capacity arrives steadily over the
                                  trace (a growing reservation), with a
                                  rare hard failure.
  - ``correlated-rack-failure``   a contiguous rack of hosts hard-fails
                                  at once, replaced later by a join.

* checkpoint-interval policy — ``optimal_checkpoint_interval`` is the
  Young/Daly first-order optimum ``tau* = sqrt(2 · delta · MTBF)`` with
  ``delta`` the checkpoint cost (``CostModel.checkpoint_cost_s``) and
  the MTBF estimated from the churn schedule (``churn_mtbf``).  The
  simulator's ``checkpoint_interval`` sweeps cadence against lost work
  (``benchmarks/bench_churn.py``) and the optimum is non-trivial: too
  frequent and the checkpoint overhead dominates, too rare and every
  failure throws away a long tail of work.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry
from repro.core.placement import Placement, PlacementEngine

# Default drain window for spot reclaims (the cloud's two-minute warning,
# scaled to the simulator's seconds-long jobs).
DEFAULT_DRAIN_S = 5.0

# Drain-deadline evacuation retries: capped exponential backoff.  The
# base/cap are fractions of typical drain windows (a 5 s spot drain gets
# ~3 retries, a 30 s reserved drain ~8) and the deterministic jitter
# de-synchronises concurrent drains in large fleets.
RETRY_BASE_S = 0.5
RETRY_CAP_S = 4.0


@dataclasses.dataclass
class FleetEvent:
    """One timestamped change to the host set (see module docstring)."""

    t: float
    kind: str                                   # join | reclaim | fail
    hosts: List[int] = dataclasses.field(default_factory=list)
    capacities: List[int] = dataclasses.field(default_factory=list)
    speeds: Optional[List[float]] = None        # join only
    drain_s: float = DEFAULT_DRAIN_S            # reclaim only

    def __post_init__(self):
        assert self.kind in ("join", "reclaim", "fail"), self.kind
        if self.kind == "join":
            assert self.capacities, "join needs per-host capacities"
        else:
            assert self.hosts, f"{self.kind} needs target hosts"


@dataclasses.dataclass
class FleetOutcome:
    """Pure decisions from applying one event — the caller moves the
    actual jobs/gangs (requeue, snapshot restore, device churn)."""

    event: FleetEvent
    joined: List[int] = dataclasses.field(default_factory=list)
    evacuations: List[Tuple[str, Placement]] = dataclasses.field(
        default_factory=list)
    stranded: List[str] = dataclasses.field(default_factory=list)
    failed: List[str] = dataclasses.field(default_factory=list)
    deadline: Optional[float] = None            # reclaim only


class FleetController:
    """Applies ``FleetEvent``s to the placement layer.

    One controller per engine; both the simulator's event loop and the
    live ``Fabric`` drive churn through it so lease/drain/fail semantics
    live in exactly one place.  The controller never touches job state:
    it returns plans (``FleetOutcome``) the caller executes."""

    def __init__(self, engine: PlacementEngine):
        self.engine = engine

    def apply(self, ev: FleetEvent, now: float,
              kinds: Optional[Mapping[str, str]] = None) -> FleetOutcome:
        """Apply one event at virtual time ``now``.

        join     -> hosts added; ``joined`` carries the new indices.
        fail     -> allocations dropped; ``failed`` lists the victims to
                    requeue from their last checkpoint.
        reclaim  -> hosts start draining; ``evacuations`` are the
                    graceful moves to apply now (``apply_migration``),
                    ``stranded`` the gangs with nowhere to go, and
                    ``deadline`` when ``expire`` must run.
        """
        out = FleetOutcome(event=ev)
        if ev.kind == "join":
            out.joined = self.engine.add_hosts(ev.capacities, ev.speeds)
        elif ev.kind == "fail":
            out.failed = self.engine.fail_hosts(ev.hosts)
        else:                                   # reclaim
            self.engine.drain_hosts(ev.hosts)
            out.evacuations, out.stranded = self.engine.evacuation_plan(
                ev.hosts, kinds=kinds)
            out.deadline = now + ev.drain_s
        tel = telemetry.get()
        if tel.enabled:
            tel.count(f"fleet.{ev.kind}")
            tel.instant(f"fleet.{ev.kind}", t=now, track="fleet",
                        clock="virtual",
                        hosts=[int(h) for h in (ev.hosts or [])],
                        joined=[int(h) for h in out.joined],
                        failed=list(out.failed),
                        evacuations=len(out.evacuations),
                        stranded=list(out.stranded))
        return out

    def expire(self, ev: FleetEvent,
               kinds: Optional[Mapping[str, str]] = None) -> FleetOutcome:
        """Drain deadline hit: one last-chance evacuation pass (capacity
        may have freed since the reclaim), after which the caller
        applies the moves and then ``fail``s the hosts — whatever still
        holds chips there is requeued from its checkpoint."""
        out = FleetOutcome(event=ev)
        out.evacuations, out.stranded = self.engine.evacuation_plan(
            ev.hosts, kinds=kinds)
        return out

    def fail(self, hosts: Sequence[int]) -> List[str]:
        """Retire ``hosts`` for good (hard failure / drain expiry)."""
        return self.engine.fail_hosts(hosts)

    # retry backoff knobs (module defaults; per-controller overridable)
    retry_base_s = RETRY_BASE_S
    retry_cap_s = RETRY_CAP_S

    def retry_times(self, ev: FleetEvent, now: float) -> List[float]:
        """Evacuation-retry schedule through a reclaim's drain window:
        capped exponential backoff (base doubling up to ``retry_cap_s``)
        with deterministic jitter, strictly inside ``(now, deadline)``.
        Capacity freed mid-drain (a finish, a join) is caught at the
        next retry instead of only at the deadline.  The jitter derives
        from the event's own timestamp and the attempt index — never
        per-process state — so simulator and live runtime (and
        ``predict_trace`` vs ``run_trace``) compute identical schedules,
        while concurrent drains across a large fleet land at different
        offsets instead of thundering-herding the engine."""
        deadline = now + ev.drain_s
        times: List[float] = []
        delay = self.retry_base_s
        t = now
        for k in range(32):             # far beyond any real window
            rng = np.random.default_rng(
                [int(round(ev.t * 1e6)) % (2 ** 31), k, 73])
            t += delay * (1.0 + 0.25 * float(rng.random()))
            if t >= deadline - 1e-9:
                break
            times.append(t)
            delay = min(delay * 2.0, self.retry_cap_s)
        return times


# ---------------------------------------------------------------------------
# Checkpoint-interval policy (Young/Daly)
# ---------------------------------------------------------------------------
def optimal_checkpoint_interval(mtbf_s: float,
                                checkpoint_cost_s: float = 0.5,
                                cost_model=None) -> float:
    """Young/Daly first-order optimum ``tau* = sqrt(2 · delta · MTBF)``.

    ``delta`` is the per-checkpoint cost (``CostModel.checkpoint_cost_s``)
    and ``mtbf_s`` the mean time between failures *as seen by one gang*
    — estimate it from a churn schedule with ``churn_mtbf``.  Checkpoint
    overhead grows as ``delta/tau`` while expected lost work per failure
    grows as ``tau/2``; the product of rates is minimised at ``tau*``.
    Returns ``inf`` for a failure-free fleet (never checkpoint).

    ``cost_model``: a ``CostModel`` to take ``delta`` from instead of
    ``checkpoint_cost_s`` — with delta checkpointing configured
    (``ckpt_delta_fraction``) its amortised
    ``effective_checkpoint_cost_s()`` is cheaper than a full snapshot,
    so the optimum cadence tightens (``sqrt`` of the cost ratio)."""
    if cost_model is not None:
        checkpoint_cost_s = cost_model.effective_checkpoint_cost_s()
    assert checkpoint_cost_s >= 0
    if not math.isfinite(mtbf_s):
        return float("inf")
    return math.sqrt(2.0 * checkpoint_cost_s * mtbf_s)


# ---------------------------------------------------------------------------
# Hazard estimation (per-host / per-group failure rates)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HazardEstimate:
    """Per-host disruption-rate estimates over a schedule horizon — the
    one estimator both Young/Daly (via ``fleet_mtbf``) and the
    ``CostModel`` risk term's failure-history component consume, so a
    host the cadence policy considers flaky is exactly the host
    placement steers away from."""

    rates: np.ndarray                   # disruptions/s per host
    horizon_s: float
    events_seen: int                    # disruptive events counted

    def host_rate(self, host: int) -> float:
        return float(self.rates[host]) if host < len(self.rates) else 0.0

    def group_rates(self, blast_groups: Sequence[int]
                    ) -> Dict[int, float]:
        """Max per-host rate within each blast-radius group — the
        correlated (one event kills the whole group) view of the same
        estimates."""
        out: Dict[int, float] = {}
        for h, g in enumerate(blast_groups):
            g = int(g)
            r = float(self.rates[h]) if h < len(self.rates) else 0.0
            if r > out.get(g, -1.0):
                out[g] = r
        return out

    def fleet_mtbf(self) -> float:
        """Blast-weighted fleet MTBF: the reciprocal of the mean
        per-host rate — an event killing 2 of 32 hosts disrupts a given
        gang ~1/16th as often as a full-fleet outage (the historical
        ``churn_mtbf`` scalar, re-derived from the per-host rates)."""
        if self.events_seen == 0 or not len(self.rates):
            return float("inf")
        mean = float(self.rates.mean())
        return 1.0 / mean if mean > 0 else float("inf")


def estimate_hazards(events: Sequence[FleetEvent], horizon_s: float,
                     hosts: int) -> HazardEstimate:
    """Per-host disruption rates from a churn schedule: each
    reclaim/fail event counts one disruption against every host it
    targets, over ``horizon_s`` seconds.  Hosts the schedule never
    touches (including join indices past the initial fleet) estimate at
    rate 0."""
    counts = np.zeros(hosts)
    seen = 0
    for e in events:
        if e.kind in ("reclaim", "fail"):
            seen += 1
            for h in e.hosts:
                if 0 <= h < hosts:
                    counts[h] += 1.0
    horizon = max(float(horizon_s), 1e-9)
    return HazardEstimate(rates=counts / horizon, horizon_s=horizon,
                          events_seen=seen)


def churn_mtbf(events: Sequence[FleetEvent], horizon_s: float,
               hosts: int = 0) -> float:
    """MTBF estimate feeding ``optimal_checkpoint_interval`` — a thin
    wrapper over ``estimate_hazards``: mean time between *disruptive*
    events (reclaim/fail) over the horizon, blast-weighted by the
    fraction of the fleet each one takes when ``hosts`` is given.
    ``hosts=0`` keeps the unweighted event spacing.  ``inf`` with no
    disruptions."""
    if hosts:
        return estimate_hazards(events, horizon_s, hosts).fleet_mtbf()
    count = sum(1 for e in events if e.kind in ("reclaim", "fail"))
    if count == 0:
        return float("inf")
    return horizon_s / count


class HazardEstimator:
    """Online per-host failure-rate estimation from *observed*
    ``FleetEvent`` history — the live twin of ``estimate_hazards`` (one
    counts a schedule ahead of time, this one accumulates events as the
    controller applies them; both expose per-host rates).

    ``rate_h(now) = (prior_events + count_h) / max(now, min_horizon_s)``
    — a Laplace-smoothed event rate.  ``prior_events > 0`` gives every
    host a small uniform hazard before any history exists, which
    activates blast-radius correlation from t=0: with all rates equal,
    the risk penalty reduces to the number of blast groups a gang
    touches, so gangs pack within failure domains even before the first
    observed event."""

    def __init__(self, hosts: int, prior_events: float = 0.25,
                 min_horizon_s: float = 1.0):
        self.counts = np.zeros(hosts)
        self.prior_events = float(prior_events)
        self.min_horizon_s = float(min_horizon_s)

    def _ensure(self, hosts: int) -> None:
        if hosts > len(self.counts):
            self.counts = np.concatenate(
                [self.counts, np.zeros(hosts - len(self.counts))])

    def observe(self, ev: FleetEvent) -> None:
        """Record one applied event (joins are not disruptions)."""
        if ev.kind not in ("reclaim", "fail"):
            return
        if ev.hosts:
            self._ensure(max(ev.hosts) + 1)
            for h in ev.hosts:
                self.counts[h] += 1.0

    def rates(self, hosts: int, now: float) -> np.ndarray:
        """Per-host rate estimates sized to the current fleet."""
        self._ensure(hosts)
        horizon = max(float(now), self.min_horizon_s)
        return (self.counts[:hosts] + self.prior_events) / horizon


def lease_expiries(events: Sequence[FleetEvent],
                   hosts: int) -> np.ndarray:
    """Per-host absolute lease-expiry times from a schedule's *reclaim*
    events — the contractual part of churn: a reclaim at ``t`` is the
    lease term the provider sold (rFaaS leases carry their duration),
    so placement may legitimately know it ahead.  Hard ``fail`` events
    are surprises and deliberately NOT included — they reach the risk
    term only through observed hazard history.  ``inf`` = no scheduled
    reclaim (reserved, or a joiner)."""
    out = np.full(hosts, np.inf)
    for e in events:
        if e.kind == "reclaim":
            for h in e.hosts:
                if 0 <= h < hosts:
                    out[h] = min(out[h], e.t)
    return out


def blast_groups(events: Sequence[FleetEvent], hosts: int) -> np.ndarray:
    """Blast-radius group ids from the fleet topology a schedule
    encodes: hosts listed together in one multi-host disruptive event
    share a failure domain (the rack/switch/power the
    correlated-rack generator models — topology an operator knows
    statically), so they union into one group; everything else keeps a
    singleton group.  Group ids are the union-find roots, stable under
    host-index growth."""
    parent = list(range(hosts))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in events:
        if e.kind in ("reclaim", "fail") and len(e.hosts) > 1:
            anchor = None
            for h in e.hosts:
                if not 0 <= h < hosts:
                    continue
                if anchor is None:
                    anchor = find(h)
                else:
                    parent[find(h)] = anchor
    return np.array([find(h) for h in range(hosts)], dtype=np.int64)


# ---------------------------------------------------------------------------
# Churn regimes (trace generators)
# ---------------------------------------------------------------------------
CHURN_REGIMES = ("spot-heavy", "steady-join", "correlated-rack-failure")


def churn_schedule(regime: str, hosts: int, chips_per_host: int,
                   horizon: float, seed: int = 0, rate: float = 0.02,
                   drain_s: float = DEFAULT_DRAIN_S,
                   rack: int = 0) -> List[FleetEvent]:
    """Generate a churn schedule composing with an arrival trace.

    ``hosts`` is the fleet size at trace start; joined hosts take fresh
    indices (``hosts``, ``hosts+1``, ...) exactly as
    ``PlacementEngine.add_hosts`` assigns them, so the schedule can be
    replayed on the simulator and the live fabric alike.  ``rate`` is
    the disruptive-event rate (events/second) for the Poisson regimes;
    ``rack`` the correlated-failure blast radius (default: an eighth of
    the fleet, at least 2 hosts).  Deterministic given the seed; events
    never target a host twice, and at least half the initial fleet is
    always left untouched so traces stay schedulable."""
    assert regime in CHURN_REGIMES, regime
    rng = np.random.default_rng([seed, 97])
    events: List[FleetEvent] = []
    removable = list(range(hosts))         # never reclaim a host twice
    rng.shuffle(removable)
    floor = (hosts + 1) // 2               # keep half the fleet stable
    removable = removable[:hosts - floor]

    def take_hosts(k: int) -> List[int]:
        picked, removable[:] = removable[:k], removable[k:]
        return sorted(picked)

    if regime == "spot-heavy":
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / max(rate, 1e-9)))
            if t >= horizon or not removable:
                break
            victims = take_hosts(int(rng.integers(1, 3)))
            if not victims:
                break
            events.append(FleetEvent(t, "reclaim", hosts=victims,
                                     drain_s=drain_s))
            # the spot market gives back: a like-for-like join lands
            # a short lease-turnaround later (capacity roughly conserved)
            delay = float(rng.uniform(2.0, 6.0)) + drain_s
            caps = [chips_per_host] * len(victims)
            events.append(FleetEvent(t + delay, "join",
                                     capacities=caps))
    elif regime == "steady-join":
        # capacity grows steadily over the first 2/3 of the horizon;
        # one rare hard failure keeps recovery honest
        n_joins = max(2, int(horizon * rate))
        for i in range(n_joins):
            t = (i + 1) * (2.0 * horizon / 3.0) / n_joins
            events.append(FleetEvent(t, "join",
                                     capacities=[chips_per_host]))
        if removable:
            t_fail = float(rng.uniform(0.4, 0.6)) * horizon
            events.append(FleetEvent(t_fail, "fail",
                                     hosts=take_hosts(1)))
    else:                                  # correlated-rack-failure
        blast = rack or max(2, hosts // 8)
        blast = min(blast, len(removable))
        # a contiguous run (a rack shares power/switch): pick the start
        # so the rack sits inside the removable half
        start = int(rng.integers(floor, max(floor + 1,
                                            hosts - blast + 1)))
        rack_hosts = list(range(start, min(start + blast, hosts)))
        t_fail = float(rng.uniform(0.25, 0.45)) * horizon
        events.append(FleetEvent(t_fail, "fail", hosts=rack_hosts))
        # the replacement rack leases in after repair
        t_join = t_fail + float(rng.uniform(0.15, 0.3)) * horizon
        events.append(FleetEvent(
            t_join, "join",
            capacities=[chips_per_host] * len(rack_hosts)))
    events.sort(key=lambda e: e.t)
    return events
