"""Mamba2 (SSD) blocks: chunked-parallel training path + recurrent decode.

The chunked selective-state-space algorithm (SSD) splits the sequence into
chunks of ``cfg.ssm_chunk`` tokens.  Within a chunk the computation is an
attention-like batched matmul (MXU-friendly); across chunks a tiny
associative recurrence carries the (P, N) state.  The pure-jnp path below is
the reference/dry-run implementation; ``kernels.mamba_scan`` is the fused
Pallas version selected by ``cfg.use_pallas_kernels``.

Tensor parallelism: projections are *split* (z / x / B / C / dt) rather than
fused so that head-structured tensors (x, dt, per-head A/D) shard cleanly
over the ``model`` axis while the small shared B/C streams stay replicated —
the TPU-native layout of Mamba2 TP.

State layout per layer (decode):
  conv_x/b/c: (B, d_conv-1, ·)   rolling windows of conv inputs
  ssm:        (B, H, P, N)       selective state (f32)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, matmul, matmul_rp, rms_norm

D_CONV = 4  # depthwise conv kernel width


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads


def init_mamba(key, cfg):
    d = cfg.d_model
    d_inner, h = dims(cfg)
    n = cfg.ssm_state
    kz, kx, kb, kc, kdt, kcx, kcb, kcc, kout = jax.random.split(key, 9)
    dtype = cfg.param_dtype()
    return {
        "in_z": dense_init(kz, (d, d_inner), dtype),
        "in_x": dense_init(kx, (d, d_inner), dtype),
        "in_b": dense_init(kb, (d, n), dtype),
        "in_c": dense_init(kc, (d, n), dtype),
        "in_dt": dense_init(kdt, (d, h), dtype),
        "conv_x": dense_init(kcx, (D_CONV, d_inner), dtype, scale=0.5),
        "conv_b": dense_init(kcb, (D_CONV, n), dtype, scale=0.5),
        "conv_c": dense_init(kcc, (D_CONV, n), dtype, scale=0.5),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(kout, (d_inner, d), dtype),
    }


def _conv1d(x, w):
    """Causal depthwise conv, kernel width D_CONV.  x: (B,L,C), w: (K,C)."""
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(D_CONV):
        shift = D_CONV - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xs.astype(jnp.float32) * w[k].astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_chunked(x, dt, a, b, c, chunk: int):
    """Chunked SSD scan (reference).

    x: (B,L,H,P)  dt: (B,L,H)  a: (H,) negative  b,c: (B,L,N)
    Returns y: (B,L,H,P), final_state: (B,H,P,N).
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    nc = l // q
    xc = x.reshape(bs, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bs, nc, q, h).astype(jnp.float32)
    bc = b.reshape(bs, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, q, n).astype(jnp.float32)

    da = dtc * a  # (B,nc,q,H), negative
    cum = jnp.cumsum(da, axis=2)                       # inclusive cumsum
    total = cum[:, :, -1]                              # (B,nc,H)

    # --- within-chunk (attention-like) ---
    # decay(i,j) = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: masked entries are +large, and grad-of-where would
    # propagate inf*0=NaN through the unselected exp branch otherwise
    seg = jnp.where(causal[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)          # (B,nc,i,j)
    m = scores[..., None] * decay * dtc[:, :, None, :, :]   # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # --- chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j (x) x_j ---
    w = jnp.exp(total[:, :, None, :] - cum) * dtc           # (B,nc,q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, bc, xc)  # (B,nc,H,P,N)

    # --- inter-chunk recurrence over the nc axis (tiny sequential scan) ---
    gamma = jnp.exp(total)                                  # (B,nc,H)

    def step(s, inp):
        g, st = inp                                         # g:(B,H) st:(B,H,P,N)
        s_new = s * g[:, :, None, None] + st
        return s_new, s
    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    s_fin, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(gamma, 1, 0), jnp.moveaxis(states, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                         # state entering chunk

    # --- inter-chunk output: y_i += exp(cum_i) * C_i . S_in ---
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         cc, s_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bs, l, h, p)
    return y.astype(x.dtype), s_fin


def mamba_forward(params, x, cfg) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence Mamba2 block. x: (B,L,d) -> (y, final_state)."""
    bs, l, d = x.shape
    d_inner, h = dims(cfg)
    n = cfg.ssm_state
    p = cfg.ssm_headdim

    z = matmul(x, params["in_z"])
    xr = matmul(x, params["in_x"])                     # pre-conv x stream
    br = matmul(x, params["in_b"])
    cr = matmul(x, params["in_c"])
    xs = jax.nn.silu(_conv1d(xr, params["conv_x"]))
    b = jax.nn.silu(_conv1d(br, params["conv_b"]))
    c = jax.nn.silu(_conv1d(cr, params["conv_c"]))
    dt = jax.nn.softplus(
        matmul(x, params["in_dt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    xh = xs.reshape(bs, l, h, p)
    if cfg.use_pallas_kernels:
        from repro.kernels.mamba_scan import ops as scan_ops
        y, s_fin = scan_ops.ssd(xh, dt, a, b, c, chunk=cfg.ssm_chunk)
    else:
        y, s_fin = ssd_chunked(xh, dt, a, b, c, cfg.ssm_chunk)
    y = y + xh.astype(y.dtype) * params["d_skip"].astype(
        y.dtype)[None, None, :, None]
    y = y.reshape(bs, l, d_inner) * jax.nn.silu(z)
    y = rms_norm(params["norm_w"], y, cfg.norm_eps)
    tail = lambda r: jnp.pad(
        r, ((0, 0), (D_CONV - 1, 0), (0, 0)))[:, -(D_CONV - 1):]
    state = {"ssm": s_fin, "conv_x": tail(xr), "conv_b": tail(br),
             "conv_c": tail(cr)}
    return matmul_rp(y, params["out_proj"], cfg), state


def init_mamba_state(cfg, batch, dtype):
    d_inner, h = dims(cfg)
    n = cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, D_CONV - 1, d_inner), dtype),
        "conv_b": jnp.zeros((batch, D_CONV - 1, n), dtype),
        "conv_c": jnp.zeros((batch, D_CONV - 1, n), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_headdim, n), jnp.float32),
    }


def _conv_step(window, w):
    """window: (B,K,C) including current input; w: (K,C)."""
    return jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      w.astype(jnp.float32))


def mamba_decode(params, x, state, cfg):
    """Single-token decode. x: (B,1,d) -> (y, new_state)."""
    bs = x.shape[0]
    d_inner, h = dims(cfg)
    n = cfg.ssm_state
    p = cfg.ssm_headdim

    xt = x[:, 0]
    z = matmul(xt, params["in_z"])
    xr = matmul(xt, params["in_x"])
    br = matmul(xt, params["in_b"])
    cr = matmul(xt, params["in_c"])
    wx = jnp.concatenate([state["conv_x"], xr[:, None]], axis=1)
    wb = jnp.concatenate([state["conv_b"], br[:, None]], axis=1)
    wc = jnp.concatenate([state["conv_c"], cr[:, None]], axis=1)
    xs = jax.nn.silu(_conv_step(wx, params["conv_x"])).astype(x.dtype)
    b = jax.nn.silu(_conv_step(wb, params["conv_b"]))
    c = jax.nn.silu(_conv_step(wc, params["conv_c"]))
    dt = jax.nn.softplus(
        matmul(xt, params["in_dt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])

    xh = xs.reshape(bs, h, p).astype(jnp.float32)
    da = jnp.exp(dt * a)                                    # (B,H)
    s = state["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, b, xh)
    y = jnp.einsum("bn,bhpn->bhp", c, s)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bs, d_inner).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(params["norm_w"], y, cfg.norm_eps)
    out = matmul_rp(y, params["out_proj"], cfg)[:, None]
    return out, {"ssm": s, "conv_x": wx[:, 1:], "conv_b": wb[:, 1:],
                 "conv_c": wc[:, 1:]}
