"""Gradient/delta compression with error feedback (beyond-paper extension of
Faabric's merge-operation diffs, DESIGN.md §5).

The paper synchronises shared state by shipping *byte-wise diffs* with merge
operations.  For cross-pod gradient sync we generalise the diff to a sparse
*delta*: each gradient leaf is chunked and each chunk ships only its
largest-magnitude element (merge op = ``sum``) — the vectorized
threshold-select codec of ``kernels/collective_codec``, one O(n) streaming
pass where the old global ``top_k`` paid an O(n log n) sort.  The message
is the same fixed ``frac`` of the leaf; the residual is kept locally and
added to the next step's gradient (error feedback), which preserves
convergence.

``compress`` returns (values, indices) per leaf — the analogue of the
paper's (offset, bytes) diff list — plus the new error-feedback residual.
``decompress`` scatters back to a dense tensor for the merge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.collective_codec import ops as codec_ops


def _select_leaf(g, frac: float):
    sel, idx, resid = codec_ops.select_codec(g.reshape(-1), frac=frac)
    return (sel, idx), resid.reshape(g.shape)


def compress(grads, residual, frac: float = 0.05):
    """grads (+carried residual) -> (sparse diff pytree, new residual)."""
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    flat, treedef = jax.tree.flatten(grads)
    out = [_select_leaf(g, frac) for g in flat]
    sparse = jax.tree.unflatten(treedef, [o[0] for o in out])
    resid = jax.tree.unflatten(treedef, [o[1] for o in out])
    return sparse, resid


def decompress(sparse, shapes_like):
    """Scatter sparse (vals, idx) diffs back to dense leaves of the given
    shapes (the paper's merge-apply with op=sum onto a zero base)."""
    def one(sp, like):
        vals, idx = sp
        flat = jnp.zeros((like.size,), jnp.float32).at[idx].add(vals)
        return flat.reshape(like.shape)
    return jax.tree.map(one, sparse, shapes_like,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(sparse, dense_like) -> float:
    sent = sum(v.size + i.size for v, i in jax.tree.leaves(
        sparse, is_leaf=lambda x: isinstance(x, tuple)))
    total = sum(l.size for l in jax.tree.leaves(dense_like))
    return sent / max(total, 1)
