"""Serving launcher: open-loop continuous batching vs the fixed-batch
baseline on a reduced config.

Requests arrive on their own (virtual) clock — Poisson, diurnal or
bursty — and enter a ``ContinuousServeLoop`` slot as soon as one frees;
``--engine fixed`` replays the same stream through the old drain-to-
slowest batch loop, and ``--engine both`` reports the head-to-head.
Latency percentiles are measured in virtual seconds (one decode step =
``--step-ms``); throughput additionally reports real wall time.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
        --engine both --arrival-regime burst --offered-load 0.6 \
        --requests 24 --target-p99-ms 400
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.core import telemetry
from repro.models import transformer as tf
from repro.runtime.admission import (ARRIVAL_REGIMES, request_stream,
                                     run_fixed_batch, run_open_loop)
from repro.runtime.serve_loop import ContinuousServeLoop, ServeLoop


def _extras_fns(cfg, seed: int):
    """Per-request / per-batch model extras (audio frames, image
    tokens) for the multimodal families; None elsewhere."""
    if cfg.family not in ("audio", "vlm"):
        return None, None
    key, shape = (("frames", cfg.enc_seq) if cfg.family == "audio"
                  else ("img", cfg.n_img_tokens))

    def draw(b: int, rid: int):
        rng = np.random.default_rng([seed, 5, rid])
        return jnp.asarray(rng.normal(size=(b, shape, cfg.d_model)),
                           cfg.param_dtype())

    def one(req):
        return {key: draw(1, req.rid)}

    def batch(reqs):
        return {key: jnp.concatenate([draw(1, r.rid) for r in reqs])}
    return one, batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "fixed", "both"])
    ap.add_argument("--arrival-regime", default="poisson",
                    choices=list(ARRIVAL_REGIMES),
                    help="open-loop arrival process for the request "
                         "stream (virtual time)")
    ap.add_argument("--offered-load", type=float, default=0.5,
                    help="mean arrival rate in requests per virtual "
                         "second")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous engine slot capacity")
    ap.add_argument("--batch", type=int, default=0,
                    help="fixed-batch size (default: --slots)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--step-ms", type=float, default=50.0,
                    help="virtual cost of one decode step")
    ap.add_argument("--target-p99-ms", type=float, default=500.0,
                    help="SLO: p99 per-token latency ceiling")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--emit-trace", metavar="PATH", default=None,
                    help="record telemetry and write a Chrome trace-"
                         "event JSON (Perfetto-loadable) to PATH; the "
                         "metrics summary lands at PATH + "
                         "'.summary.json'")
    args = ap.parse_args()

    tel = (telemetry.enable() if args.emit_trace else telemetry.get())

    cfg = reduced_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(key)
    batch = args.batch or args.slots
    step_s = args.step_ms / 1e3
    one_extra, batch_extra = _extras_fns(cfg, args.seed)

    # the fixed baseline needs equal-length prompts; the continuous
    # engine takes the stream ragged
    prompt_lens = ((max(1, args.prompt_len // 2), args.prompt_len)
                   if args.engine == "continuous"
                   else (args.prompt_len, args.prompt_len))

    def stream():
        return request_stream(
            args.requests, args.offered_load, args.seed,
            regime=args.arrival_regime, vocab=cfg.vocab,
            prompt_lens=prompt_lens,
            max_new=(max(1, args.new_tokens // 2), args.new_tokens))

    out = {"arch": args.arch, "engine": args.engine,
           "arrival_regime": args.arrival_regime,
           "offered_load": args.offered_load,
           "requests": args.requests, "slots": args.slots,
           "batch": batch, "step_ms": args.step_ms,
           "target_p99_ms": args.target_p99_ms}

    def emit(name, report, wall):
        p99_ms = report.token_lat_p99 * 1e3
        out[name] = {
            "finished": report.finished,
            "decoded_tokens": report.decoded_tokens,
            "prefill_tokens": report.prefill_tokens,
            "virtual_s": round(report.elapsed_s, 3),
            "tokens_per_virtual_s": round(report.tokens_per_s, 2),
            "token_lat_p50_ms": round(report.token_lat_p50 * 1e3, 2),
            "token_lat_p99_ms": round(p99_ms, 2),
            "ttft_p99_ms": round(report.ttft_p99 * 1e3, 2),
            "queue_wait_p99_ms": round(report.queue_wait_p99 * 1e3, 2),
            "slo_met": bool(p99_ms <= args.target_p99_ms),
            "wall_s": round(wall, 2)}

    if args.engine in ("continuous", "both"):
        loop = ContinuousServeLoop(cfg, params, slots=args.slots,
                                   max_len=args.max_len)
        t0 = time.time()
        rep = run_open_loop(loop, stream(), step_s=step_s,
                            extras_fn=one_extra)
        emit("continuous", rep, time.time() - t0)
    if args.engine in ("fixed", "both"):
        loop = ServeLoop(cfg, params, max_len=args.max_len)
        t0 = time.time()
        rep = run_fixed_batch(loop, stream(), batch, step_s=step_s,
                              extras_fn=batch_extra)
        emit("fixed", rep, time.time() - t0)
    if args.engine == "both":
        c, f = out["continuous"], out["fixed"]
        out["continuous_speedup"] = round(
            c["tokens_per_virtual_s"]
            / max(f["tokens_per_virtual_s"], 1e-9), 3)
    if args.emit_trace:
        tel.write_chrome_trace(args.emit_trace)
        tel.write_summary(args.emit_trace + ".summary.json")
        out["emit_trace"] = args.emit_trace
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
