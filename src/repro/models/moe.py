"""Mixture-of-Experts FFN: token-choice top-k router with grouped capacity
dispatch (GShard-style einsum dispatch).

Sharding: experts live on the leading axis of the expert weights and are
sharded over the ``model`` mesh axis (expert parallelism); token groups are
sharded over ``data``.  The dispatch/combine einsums lower to all-to-all-like
collectives under pjit.

The expert matmul has two execution paths:
  * reference (default / dry-run): dense einsum over the dispatched
    ``(groups, experts, capacity, d)`` tensor — XLA counts its FLOPs.
  * ``cfg.use_pallas_kernels``: sort-based ragged grouped matmul via the
    ``kernels.moe_gmm`` Pallas kernel (TPU deployment path).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

# Tokens are routed within groups of this size, so the dispatch tensor is
# (G, GROUP, E, C) with C ~ GROUP*top_k*cf/E — keeping it VMEM-friendly.
GROUP = 512


def init_moe(key, cfg):
    d, e = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    kr, k1, k2, k3 = jax.random.split(key, 4)
    dtype = cfg.param_dtype()
    return {
        "router": dense_init(kr, (d, e), jnp.float32),  # router kept in f32
        "w1": dense_init(k1, (e, d, ff), dtype),
        "w2": dense_init(k2, (e, ff, d), dtype),
        "w3": dense_init(k3, (e, d, ff), dtype),
    }


def expert_capacity(cfg, group: int) -> int:
    cap = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.top_k)  # never below top_k slots


def _route(router_w, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing. x: (G,S,d) -> gates (G,S,k), idx (G,S,k), aux loss."""
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    e = cfg.n_experts
    me = jnp.mean(probs, axis=(0, 1))                       # mean router prob
    pe = jnp.mean(jax.nn.one_hot(idx[..., 0], e), axis=(0, 1))  # top-1 fraction
    aux = e * jnp.sum(me * pe)
    return gates, idx, aux


def _dispatch_tensors(gates, idx, cfg, capacity):
    """Build dispatch (G,S,E,C) one-hot and combine (G,S,E,C) weighted.

    Position-in-expert is assigned in (s, k) priority order via a cumulative
    sum over the flattened (S*k) one-hot routing mask, exactly GShard's
    capacity algorithm; tokens past capacity are dropped.  The (S*k, E, C)
    one-hot product is never materialised: the k slots are accumulated one
    at a time (peak memory k-fold smaller).
    """
    g, s, k = idx.shape
    e = cfg.n_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (G,S,k,E)
    flat = onehot.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                    # slots before me
    keep = ((pos < capacity) * flat).reshape(g, s, k, e)
    pos = pos.reshape(g, s, k, e)
    dispatch = jnp.zeros((g, s, e, capacity), jnp.bfloat16)
    combine = jnp.zeros((g, s, e, capacity), jnp.bfloat16)
    for kk in range(k):                                      # per-slot
        d_k = (jax.nn.one_hot(pos[:, :, kk], capacity, dtype=jnp.float32)
               * keep[:, :, kk, :, None])                    # (G,S,E,C)
        dispatch = dispatch + d_k.astype(jnp.bfloat16)
        combine = combine + (gates[:, :, kk, None, None]
                             * d_k).astype(jnp.bfloat16)
    return dispatch, combine


def moe_ffn(params, x, cfg):
    """MoE feed-forward. x: (B,S,d) -> (y, aux_loss)."""
    b, s, d = x.shape
    tokens = b * s
    group = min(GROUP, tokens)
    g = tokens // group
    xg = x.reshape(g, group, d)
    cap = expert_capacity(cfg, group)

    gates, idx, aux = _route(params["router"], xg, cfg)
    dispatch, combine = _dispatch_tensors(gates, idx, cfg, cap)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(jnp.float32)
    # pin the E dim of dispatch/combine to the expert-parallel axis —
    # propagation otherwise replicates them and all-gathers per layer
    # (§Perf #10; ~310 GB/device/step observed on granite before the pin)
    if cfg.n_experts % 16 == 0:
        try:
            from jax.sharding import PartitionSpec as P
            spec = P(None, None, "model", None)
            dispatch = jax.lax.with_sharding_constraint(dispatch, spec)
            combine = jax.lax.with_sharding_constraint(combine, spec)
        except (ValueError, NameError, KeyError, TypeError):
            pass  # no "model" axis in scope (CPU tests, gang runtime)

    # Gather expert inputs: (G,E,C,d)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.use_pallas_kernels:
        from repro.kernels.moe_gmm import ops as gmm_ops
        ye = gmm_ops.expert_ffn(xe, params["w1"], params["w2"], params["w3"],
                                act=cfg.act)
    else:
        h = jnp.einsum("gecd,edf->gecf", xe, params["w1"],
                       preferred_element_type=jnp.float32)
        if cfg.act == "silu":
            up = jnp.einsum("gecd,edf->gecf", xe, params["w3"],
                            preferred_element_type=jnp.float32)
            h = jax.nn.silu(h) * up
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("gecf,efd->gecd", h.astype(x.dtype), params["w2"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    # Scatter back with gate weights: (G,S,d)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y.reshape(b, s, d), cfg.router_aux_weight * aux
