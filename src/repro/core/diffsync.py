"""Byte-wise-diff synchronisation of shared state (paper §4, Table 3).

Faabric tracks writes to shared pages with ``mprotect`` and ships byte-wise
diffs with *merge operations* back to the main snapshot.  On TPU there is no
page-fault hook inside an XLA program, so the TPU-native adaptation is
explicit **chunk-wise diffing**: every state leaf is viewed as a sequence of
fixed-size chunks (the page analogue); dirty chunks are found by comparing
against the parent snapshot, and only dirty chunks travel.

Three representations are provided:

* **sparse** (host-side; checkpointing, migration, cross-pod delta sync):
  per-leaf ``(chunk_idx, payload)`` arrays with dynamic length — exactly the
  paper's (offset, bytes) diff list.  The hot path is fully vectorized:
  dirty detection is one batched compare per leaf, merge maths touch only
  the gathered dirty chunks, and ``apply_leaf(..., inplace=True)`` /
  ``apply_many`` never materialise clean chunks — merge cost scales with
  dirty bytes, not state bytes.
* **tracked** (``TrackedFork``): the ``mprotect`` analogue for host
  buffers — a chunk-granular copy-on-write fork that records dirty chunks
  as writes land, so neither the fork nor the diff ever scans clean state.
* **dense-mask** (jit-side; in-graph reductions): (mask, delta) with static
  shapes, consumed by the ``kernels.diff_merge`` Pallas kernel.  Large
  leaves route there from the host-side API via ``fused_diff_apply``.

Merge operations follow Table 3 exactly:
    sum        A1 = A0 + (B1 - B0)
    subtract   A1 = A0 - (B0 - B1)
    multiply   A1 = A0 * (B1 / B0)
    divide     A1 = A0 / (B0 / B1)
    overwrite  A1 = B1
where A0 = main-snapshot value, B0 = child's snapshot-at-fork value,
B1 = child's value after execution, A1 = merged main value.

Dtypes are preserved end to end: float leaves run the merge maths in
float64 and round once back to the leaf dtype (bit-identical to the
pinned ``reference_*`` implementations), integer leaves use exact integer
sum/subtract/overwrite (no float round-trip — the reference path silently
corrupted int64 values above 2**53).

The pre-vectorization implementations are kept verbatim as
``reference_merge_scalarwise`` / ``reference_diff_leaf`` /
``reference_apply_leaf`` / ``reference_apply_tree`` and pinned against the
hot path by the parity suite in ``tests/test_diffsync.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK = 1024  # elements per chunk (the "page" size of the diff protocol)

MERGE_OPS = ("sum", "subtract", "multiply", "divide", "overwrite")

# leaves with at least this many elements route through the
# kernels/diff_merge Pallas kernel when the backend is a TPU
# (``fused_diff_apply``); smaller leaves and CPU hosts stay on the
# vectorized numpy path, where kernel dispatch overhead would dominate
KERNEL_MIN_ELEMS = 1 << 20


def _as_f64(a):
    return np.asarray(a, dtype=np.float64)


def _is_int(dtype) -> bool:
    return np.issubdtype(np.dtype(dtype), np.integer)


def merge_scalarwise(a0, b0, b1, op: str):
    """Apply one Table-3 merge op elementwise (host/numpy),
    dtype-preserving: float leaves compute in float64 and round once
    (bit-identical to ``reference_merge_scalarwise``); integer leaves
    use exact integer arithmetic for sum/subtract/overwrite."""
    a0 = np.asarray(a0)
    if op == "overwrite":
        return np.asarray(b1, dtype=a0.dtype)
    if _is_int(a0.dtype) and op in ("sum", "subtract"):
        b0i = np.asarray(b0, dtype=a0.dtype)
        b1i = np.asarray(b1, dtype=a0.dtype)
        if op == "sum":
            return a0 + (b1i - b0i)
        return a0 - (b0i - b1i)
    a0d, b0d, b1d = _as_f64(a0), _as_f64(b0), _as_f64(b1)
    if op == "sum":
        out = a0d + (b1d - b0d)
    elif op == "subtract":
        out = a0d - (b0d - b1d)
    elif op == "multiply":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b0d == 0, a0d, a0d * (b1d / b0d))
    elif op == "divide":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b1d == 0, a0d, a0d / (b0d / b1d))
    else:
        raise ValueError(op)
    return out.astype(a0.dtype)


# ---------------------------------------------------------------------------
# Sparse (host-side) diff lists — the migration/checkpoint wire format
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LeafDiff:
    """Diff of one state leaf: dirty chunk indices + their new contents.

    ``new``/``old`` rows align with ``idx``; the tail chunk of a ragged
    leaf (size not a CHUNK multiple) is zero-padded to full width.
    ``new``/``old`` may be *views* into live buffers (contiguous dirty
    runs, ``TrackedFork.diff``) — treat a LeafDiff as immutable."""
    idx: np.ndarray        # (k,) int32 dirty chunk indices
    new: np.ndarray        # (k, CHUNK) values after execution (B1)
    old: np.ndarray        # (k, CHUNK) values at fork (B0); merge ops need it
    shape: Tuple[int, ...]
    dtype: Any
    op: str = "overwrite"

    @property
    def nbytes(self) -> int:
        return int(self.idx.nbytes + self.new.nbytes
                   + (0 if self.op == "overwrite" else self.old.nbytes))


def _flat_view(a: np.ndarray) -> np.ndarray:
    """Zero-copy flat view (host snapshots are contiguous; fall back to
    a copy only for exotic layouts)."""
    a = np.asarray(a)
    flat = a.reshape(-1) if a.flags.c_contiguous else np.ravel(a)
    return flat


def _body_tail(flat: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Split a flat buffer into a zero-copy (n_full, CHUNK) body view and
    the ragged tail (possibly empty)."""
    n_full = flat.size // CHUNK
    body = flat[:n_full * CHUNK].reshape(n_full, CHUNK)
    return body, flat[n_full * CHUNK:]


def _pad_chunk(vals: np.ndarray) -> np.ndarray:
    """One ragged tail as a zero-padded (1, CHUNK) row."""
    row = np.zeros((1, CHUNK), dtype=vals.dtype)
    row[0, :vals.size] = vals
    return row


def _gather(body: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Gather chunk rows; a contiguous run comes back as a zero-copy
    basic-slice view instead of a fancy-index copy."""
    if idx.size and int(idx[-1]) - int(idx[0]) == idx.size - 1:
        return body[int(idx[0]):int(idx[-1]) + 1]
    return body[idx]


def diff_leaf(old: np.ndarray, new: np.ndarray, op: str = "overwrite"
              ) -> LeafDiff:
    """Chunk-wise compare ``new`` against the fork snapshot ``old``.

    One vectorized compare over the chunk body plus a separate tail
    check — no pad copy of the full leaf, and payload gathers touch
    dirty chunks only."""
    old, new = np.asarray(old), np.asarray(new)
    assert old.shape == new.shape and old.dtype == new.dtype
    fo, fn = _flat_view(old), _flat_view(new)
    ob, ot = _body_tail(fo)
    nb, nt = _body_tail(fn)
    dirty = np.any(ob != nb, axis=1)
    idx = np.nonzero(dirty)[0].astype(np.int32)
    new_rows = _gather(nb, idx)
    old_rows = _gather(ob, idx)
    if ot.size and np.any(ot != nt):
        idx = np.concatenate([idx, np.asarray([ob.shape[0]],
                                              dtype=np.int32)])
        new_rows = np.concatenate([new_rows, _pad_chunk(nt)])
        old_rows = np.concatenate([old_rows, _pad_chunk(ot)])
    return LeafDiff(idx=idx, new=new_rows, old=old_rows,
                    shape=old.shape, dtype=old.dtype, op=op)


def _split_tail_idx(d: LeafDiff, n_full: int
                    ) -> Tuple[np.ndarray, bool]:
    """Row positions of body chunks in ``d`` and whether the last row is
    the ragged tail chunk."""
    has_tail = bool(d.idx.size) and int(d.idx[-1]) == n_full
    return (d.idx[:-1] if has_tail else d.idx), has_tail


def apply_leaf(main: np.ndarray, d: LeafDiff,
               inplace: bool = False) -> np.ndarray:
    """Merge a LeafDiff into the main copy (A0 -> A1, Table 3).

    An empty diff passes ``main`` through untouched; otherwise only the
    dirty chunks are gathered, merged and scattered back — the one
    O(state) cost left is the defensive copy, and ``inplace=True``
    (merge into the long-lived main snapshot, the protocol's real hot
    path) removes it too."""
    main = np.asarray(main)
    if d.idx.size == 0:
        return main
    out = main if inplace else main.copy()
    flat = _flat_view(out)
    body, tail = _body_tail(flat)
    body_idx, has_tail = _split_tail_idx(d, body.shape[0])
    k = body_idx.size
    if k:
        a0 = _gather(body, body_idx)
        merged = merge_scalarwise(a0, d.old[:k], d.new[:k], d.op)
        body[body_idx] = merged
    if has_tail:
        r = tail.size
        a0t = _pad_chunk(tail)
        mt = merge_scalarwise(a0t, d.old[-1:], d.new[-1:], d.op)
        tail[:] = mt[0, :r]
    return out


def apply_many(main: np.ndarray, diffs: Sequence[LeafDiff],
               inplace: bool = False) -> np.ndarray:
    """Merge several diffs of the same leaf into ``main`` in order
    (N parallel workers merging back, paper §4.2).

    Equivalent to folding ``apply_leaf`` but with one materialisation:
    chunks no diff touches are copied from ``main`` exactly once (or
    never, with ``inplace=True`` or when the diffs cover the leaf), so
    merge cost scales with Σ dirty bytes.  The first diff touching a
    chunk merges against ``main``'s value, later ones against the
    accumulated result — identical to sequential application."""
    main = np.asarray(main)
    diffs = [d for d in diffs if d.idx.size]
    if not diffs:
        return main
    if inplace:
        out = main
    else:
        # materialise the output without an O(state) copy: only chunks
        # NO diff touches are copied from main; dirty chunks are merged
        # into place below (the first writer reads its A0 from main)
        out = np.empty_like(main)
        flat_o = _flat_view(out)
        flat_m = _flat_view(main)
        body_o, tail_o = _body_tail(flat_o)
        n_full = body_o.shape[0]
        covered = np.zeros(n_full + (1 if tail_o.size else 0),
                           dtype=bool)
        for d in diffs:
            covered[d.idx] = True
        clean = np.nonzero(~covered[:n_full])[0]
        if clean.size:
            body_m, _ = _body_tail(flat_m)
            body_o[clean] = _gather(body_m, clean)
        if tail_o.size and not (covered.size > n_full
                                and covered[n_full]):
            tail_o[:] = flat_m[n_full * CHUNK:]
    flat = _flat_view(out)
    body, tail = _body_tail(flat)
    n_full = body.shape[0]
    flat_main = _flat_view(main)
    body_main, tail_main = _body_tail(flat_main)
    written = np.zeros(n_full + 1, dtype=bool)      # +1: tail slot
    for d in diffs:
        body_idx, has_tail = _split_tail_idx(d, n_full)
        k = body_idx.size
        if k:
            first = ~written[body_idx]
            if inplace or not first.any():
                a0 = _gather(body, body_idx)
            elif first.all():
                a0 = _gather(body_main, body_idx)
            else:
                a0 = _gather(body, body_idx).copy()
                a0[first] = body_main[body_idx[first]]
            body[body_idx] = merge_scalarwise(a0, d.old[:k],
                                              d.new[:k], d.op)
            written[body_idx] = True
        if has_tail:
            src = tail if (inplace or written[n_full]) else tail_main
            a0t = _pad_chunk(src)
            mt = merge_scalarwise(a0t, d.old[-1:], d.new[-1:], d.op)
            tail[:] = mt[0, :tail.size]
            written[n_full] = True
    return out


def diff_tree(old_tree, new_tree, op: str = "overwrite") -> Dict[str, Any]:
    """Diff two state pytrees -> {path: LeafDiff} for dirty leaves only."""
    flat_old = jax.tree_util.tree_flatten_with_path(old_tree)[0]
    flat_new = jax.tree_util.tree_leaves(new_tree)
    diffs = {}
    for (path, o), n in zip(flat_old, flat_new):
        d = diff_leaf(np.asarray(o), np.asarray(n), op=op)
        if d.idx.size:
            diffs[jax.tree_util.keystr(path)] = d
    return diffs


def apply_tree(main_tree, diffs: Dict[str, Any], inplace: bool = False):
    """Merge a diff dict into the main pytree; returns the merged tree.

    Untouched leaves pass through as-is (no copy), and the dirty
    leaves' merge maths are *stacked*: all dirty chunks sharing a
    (merge-op, dtype) are gathered across leaves into one batched
    ``merge_scalarwise`` call, so a tree with many small dirty leaves
    pays one vectorized pass instead of per-leaf dispatch."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(main_tree)
    keyed = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    touched = [(i, diffs[key]) for i, (key, _) in enumerate(keyed)
               if key in diffs and diffs[key].idx.size]
    out: List[Any] = [leaf for _, leaf in keyed]

    # group dirty leaves by (op, dtype): one stacked merge per group
    groups: Dict[Tuple[str, str], List[Tuple[int, LeafDiff]]] = {}
    for i, d in touched:
        groups.setdefault((d.op, np.dtype(d.dtype).str), []).append(
            (i, d))
    for (op, _), members in groups.items():
        a0_rows, old_rows, new_rows, spans = [], [], [], []
        for i, d in members:
            main = np.asarray(out[i])
            target = main if inplace else main.copy()
            out[i] = target
            flat_t = _flat_view(target)
            body, tail = _body_tail(flat_t)
            body_idx, has_tail = _split_tail_idx(d, body.shape[0])
            k = body_idx.size
            if k:
                a0_rows.append(_gather(body, body_idx))
                old_rows.append(d.old[:k])
                new_rows.append(d.new[:k])
            if has_tail:
                a0_rows.append(_pad_chunk(tail))
                old_rows.append(d.old[-1:])
                new_rows.append(d.new[-1:])
            spans.append((i, k, has_tail))
        merged = merge_scalarwise(np.concatenate(a0_rows),
                                  np.concatenate(old_rows),
                                  np.concatenate(new_rows), op)
        row = 0
        for i, k, has_tail in spans:
            target = out[i]
            flat_t = _flat_view(target)
            body, tail = _body_tail(flat_t)
            d = diffs[keyed[i][0]]
            if k:
                body[d.idx[:k]] = merged[row:row + k]
                row += k
            if has_tail:
                tail[:] = merged[row, :tail.size]
                row += 1
    return jax.tree_util.tree_unflatten(treedef, out)


def diff_nbytes(diffs: Dict[str, Any]) -> int:
    return sum(d.nbytes for d in diffs.values())


def tree_nbytes(tree) -> int:
    """Total host bytes of a state pytree (the full-snapshot size a
    delta is measured against)."""
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(tree)))


# ---------------------------------------------------------------------------
# TrackedFork — the mprotect write-tracking analogue for host buffers
# ---------------------------------------------------------------------------
class TrackedFork:
    """Chunk-granular copy-on-write fork of a host buffer.

    Faabric forks a Granule by marking the parent's pages read-only and
    trapping writes; here the "trap" is explicit — writes go through
    ``writable`` / ``__setitem__``, which materialise only the touched
    chunks (boundary chunks copy in from the base; fully-covered chunks
    are written directly) and record them in a dirty mask.  Fork cost
    and diff cost therefore scale with dirty bytes: ``diff`` builds a
    ``LeafDiff`` straight from the mask with no full-state compare
    (chunk-pessimistic, exactly like page-granular mprotect tracking;
    ``verify=True`` re-compares the dirty chunks to drop false
    positives).  The base buffer is never written."""

    def __init__(self, base: np.ndarray):
        self.base = np.asarray(base)
        self._flat_base = _flat_view(self.base)
        self._buf = np.empty_like(self.base)
        self._flat = _flat_view(self._buf)
        self._n_chunks = -(-self._flat.size // CHUNK)
        self._dirty = np.zeros(self._n_chunks, dtype=bool)

    def _materialize(self, lo: int, hi: int) -> None:
        """Mark chunks [lo, hi) elementwise range dirty; copy boundary
        (partially-covered) chunks in from the base first."""
        c0, c1 = lo // CHUNK, -(-hi // CHUNK)
        for c, edge_lo, edge_hi in ((c0, c0 * CHUNK, lo),
                                    (c1 - 1, hi, c1 * CHUNK)):
            if edge_lo < edge_hi and not self._dirty[c]:
                s = slice(c * CHUNK, min((c + 1) * CHUNK,
                                         self._flat.size))
                self._flat[s] = self._flat_base[s]
        self._dirty[c0:c1] = True

    def _span(self, key) -> Tuple[int, int]:
        if isinstance(key, slice):
            lo, hi, step = key.indices(self._flat.size)
            assert step == 1, "TrackedFork writes must be unit-stride"
            return lo, max(lo, hi)
        i = int(key)
        if i < 0:
            i += self._flat.size
        return i, i + 1

    def writable(self, key) -> np.ndarray:
        """A writable view of the fork's buffer for the given flat
        slice — the caller produces values directly into fork storage
        (e.g. ``np.multiply(base[sl], 1.01, out=fork.writable(sl))``),
        so a write costs one store, not a temporary plus a copy."""
        lo, hi = self._span(key)
        self._materialize(lo, hi)
        return self._flat[lo:hi]

    def __setitem__(self, key, values) -> None:
        lo, hi = self._span(key)
        self._materialize(lo, hi)
        self._flat[lo:hi] = values

    def __getitem__(self, key) -> np.ndarray:
        """Read-through: dirty chunks from the fork, clean from base."""
        lo, hi = self._span(key)
        c0, c1 = lo // CHUNK, -(-hi // CHUNK)
        if self._dirty[c0:c1].all():
            return self._flat[lo:hi]
        if not self._dirty[c0:c1].any():
            return self._flat_base[lo:hi]
        out = self._flat_base[lo:hi].copy()
        for c in range(c0, c1):
            if self._dirty[c]:
                s0 = max(lo, c * CHUNK)
                s1 = min(hi, (c + 1) * CHUNK)
                out[s0 - lo:s1 - lo] = self._flat[s0:s1]
        return out

    @property
    def dirty_chunks(self) -> np.ndarray:
        return np.nonzero(self._dirty)[0].astype(np.int32)

    def diff(self, op: str = "overwrite", verify: bool = False
             ) -> LeafDiff:
        """The fork's LeafDiff against its base, straight from the
        write-tracking mask — no state-sized compare.  ``new`` rows are
        zero-copy views into the fork buffer when the dirty set is a
        contiguous run."""
        idx = self.dirty_chunks
        if verify and idx.size:
            body_b, tail_b = _body_tail(self._flat_base)
            body_f, tail_f = _body_tail(self._flat)
            n_full = body_b.shape[0]
            body_idx = idx[idx < n_full]
            keep = np.any(body_b[body_idx] != body_f[body_idx], axis=1)
            kept = body_idx[keep]
            if idx.size and int(idx[-1]) == n_full \
                    and tail_b.size and np.any(tail_b != tail_f):
                kept = np.concatenate([kept, idx[-1:]])
            idx = kept.astype(np.int32)
        body_b, tail_b = _body_tail(self._flat_base)
        body_f, tail_f = _body_tail(self._flat)
        n_full = body_f.shape[0]
        body_idx = idx[idx < n_full]
        new_rows = _gather(body_f, body_idx)
        old_rows = _gather(body_b, body_idx)
        if idx.size and int(idx[-1]) == n_full:
            new_rows = np.concatenate([new_rows, _pad_chunk(tail_f)])
            old_rows = np.concatenate([old_rows, _pad_chunk(tail_b)])
        return LeafDiff(idx=idx, new=new_rows, old=old_rows,
                        shape=self.base.shape, dtype=self.base.dtype,
                        op=op)


# ---------------------------------------------------------------------------
# Fused diff+merge — routes large leaves through kernels/diff_merge
# ---------------------------------------------------------------------------
def _kernel_default(n_elems: int) -> bool:
    return (n_elems >= KERNEL_MIN_ELEMS
            and jax.default_backend() == "tpu")


def fused_diff_apply(main, fork, child, op: str = "sum",
                     use_kernel: Optional[bool] = None,
                     interpret: Optional[bool] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """One fused pass over a leaf: dirty detection against the fork
    snapshot + Table-3 merge into ``main``.  Returns
    ``(merged, dirty chunk mask)``.

    ``use_kernel=None`` routes leaves of ``KERNEL_MIN_ELEMS``+ elements
    through the ``kernels.diff_merge`` Pallas kernel when running on a
    TPU (one HBM-speed streaming pass) and keeps everything else on the
    vectorized host path; ``True``/``False`` force a side
    (``interpret`` is forwarded to the kernel for off-TPU testing)."""
    main = np.asarray(main)
    if use_kernel is None:
        use_kernel = _kernel_default(main.size)
    if use_kernel:
        from repro.kernels.diff_merge import ops as _kops
        merged, dirty = _kops.diff_merge_leaf(
            jnp.asarray(main), jnp.asarray(fork), jnp.asarray(child),
            op=op, interpret=interpret)
        return np.asarray(merged), np.asarray(dirty)
    d = diff_leaf(np.asarray(fork), np.asarray(child), op=op)
    merged = apply_leaf(main, d)
    n_chunks = -(-main.size // CHUNK)
    dirty = np.zeros(n_chunks, dtype=bool)
    dirty[d.idx] = True
    return merged, dirty


# ---------------------------------------------------------------------------
# Dense-mask (jit-side) diffs — consumed by kernels/diff_merge
# ---------------------------------------------------------------------------
def dense_diff(old, new):
    """jit-able chunk diff: returns (dirty_mask (nchunks,), delta) where
    delta = new - old (the merge-op payload for op=sum)."""
    flat_o = jnp.ravel(old)
    pad = (-flat_o.size) % CHUNK
    fo = jnp.pad(flat_o, (0, pad)).reshape(-1, CHUNK)
    fn = jnp.pad(jnp.ravel(new), (0, pad)).reshape(-1, CHUNK)
    mask = jnp.any(fo != fn, axis=1)
    return mask, (fn - fo)


def _dense_compute_dtype(dtype, op: str):
    """Dtype the dense merge maths run in: integers stay integers for
    the exact ops, f32/f64 leaves keep their own precision, and only
    low-precision floats (bf16/f16) promote to f32."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        if op in ("sum", "subtract", "overwrite"):
            return dtype
        return jnp.float32
    if dtype in (jnp.float32, jnp.float64):
        return dtype
    return jnp.float32


def dense_merge(main, mask, payload, op: str = "sum"):
    """Merge a dense-mask diff into ``main`` (jit-able path).

    payload semantics: for op in {sum, subtract}: payload = B1 - B0;
    for overwrite: payload = B1; multiply/divide: payload = B1 / B0.
    The maths run in a dtype derived from the *leaf* dtype
    (``_dense_compute_dtype``): integer leaves merge exactly for
    sum/subtract/overwrite and f64 leaves keep full precision — the old
    blanket float32 cast silently corrupted both."""
    cdt = _dense_compute_dtype(main.dtype, op)
    flat = jnp.ravel(main)
    pad = (-flat.size) % CHUNK
    fm = jnp.pad(flat, (0, pad)).reshape(-1, CHUNK).astype(cdt)
    p = payload.astype(cdt)
    if op == "sum":
        merged = fm + p
    elif op == "subtract":
        merged = fm - (-p)  # A1 = A0 - (B0 - B1) = A0 + (B1 - B0)
    elif op == "multiply":
        merged = fm * p
    elif op == "divide":
        merged = fm / jnp.where(p == 0, jnp.asarray(1.0, cdt), p)
    elif op == "overwrite":
        merged = p
    else:
        raise ValueError(op)
    out = jnp.where(mask[:, None], merged, fm)
    return out.reshape(-1)[: flat.size].reshape(main.shape).astype(main.dtype)


# ---------------------------------------------------------------------------
# Reference implementations (pre-vectorization, pinned by the parity
# suite in tests/test_diffsync.py — do not "optimise" these)
# ---------------------------------------------------------------------------
def reference_merge_scalarwise(a0, b0, b1, op: str):
    """Pre-PR ``merge_scalarwise``: float64 round-trip for every dtype."""
    if op == "overwrite":
        return np.asarray(b1, dtype=np.asarray(a0).dtype)
    a0d, b0d, b1d = _as_f64(a0), _as_f64(b0), _as_f64(b1)
    if op == "sum":
        out = a0d + (b1d - b0d)
    elif op == "subtract":
        out = a0d - (b0d - b1d)
    elif op == "multiply":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b0d == 0, a0d, a0d * (b1d / b0d))
    elif op == "divide":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b1d == 0, a0d, a0d / (b0d / b1d))
    else:
        raise ValueError(op)
    return out.astype(np.asarray(a0).dtype)


def _chunk_view(a: np.ndarray) -> np.ndarray:
    flat = np.ravel(a)
    pad = (-flat.size) % CHUNK
    if pad:
        flat = np.pad(flat, (0, pad))
    return flat.reshape(-1, CHUNK)


def reference_diff_leaf(old: np.ndarray, new: np.ndarray,
                        op: str = "overwrite") -> LeafDiff:
    """Pre-PR ``diff_leaf``: full pad copy + per-leaf chunk view."""
    assert old.shape == new.shape and old.dtype == new.dtype
    oc, nc = _chunk_view(old), _chunk_view(new)
    dirty = np.any(oc != nc, axis=1)
    idx = np.nonzero(dirty)[0].astype(np.int32)
    return LeafDiff(idx=idx, new=nc[idx].copy(), old=oc[idx].copy(),
                    shape=old.shape, dtype=old.dtype, op=op)


def reference_apply_leaf(main: np.ndarray, d: LeafDiff) -> np.ndarray:
    """Pre-PR ``apply_leaf``: full chunk-view copy of clean chunks."""
    mc = _chunk_view(main).copy()
    mc[d.idx] = reference_merge_scalarwise(mc[d.idx], d.old, d.new, d.op)
    return mc.reshape(-1)[: main.size].reshape(main.shape).astype(main.dtype)


def reference_apply_tree(main_tree, diffs: Dict[str, Any]):
    """Pre-PR ``apply_tree``: every leaf re-materialised."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(main_tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key in diffs:
            out.append(reference_apply_leaf(np.asarray(leaf), diffs[key]))
        else:
            out.append(np.asarray(leaf))
    return jax.tree_util.tree_unflatten(treedef, out)
