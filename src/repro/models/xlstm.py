"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential recurrence).

Training/prefill uses the *stabilised chunkwise* form of mLSTM: the
sequence is processed in chunks of ``CHUNK`` tokens; within a chunk the
computation is attention-like (quadratic in the chunk, MXU-friendly), and a
per-head matrix memory (C: (hd,hd), n: (hd,), m: ()) carries state across
chunks — mathematically identical to the token recurrence, including the
max-stabiliser.  The chunk loop is a Python loop (exact HLO FLOP
accounting); the fused Pallas version lives in ``kernels.mlstm``.

Tensor-parallel layout: q/k are per-head block-diagonal and replicated
(their hd_k contraction must be whole); v and the matrix-memory value axis
(hd_v) shard over ``model``.

sLSTM carries a true hidden-state recurrence (h feeds the gates), so the
sequence dimension is scanned; per-head recurrent weights are
block-diagonal.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, matmul, matmul_rp, rms_norm

D_CONV = 4
CHUNK = 1024
NEG = -1e30


def mlstm_dims(cfg):
    du = int(cfg.xlstm_proj_factor * cfg.d_model)
    hd = du // cfg.n_heads
    return du, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg):
    d = cfg.d_model
    du, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    kx, kz, kconv, kq, kk, kv, ki, kf, kd = jax.random.split(key, 9)
    dtype = cfg.param_dtype()
    return {
        "up_x": dense_init(kx, (d, du), dtype),
        "up_z": dense_init(kz, (d, du), dtype),
        "conv_w": dense_init(kconv, (D_CONV, du), dtype, scale=0.5),
        # block-diagonal per-head q/k/v (mLSTM cells are head-independent)
        "wq": dense_init(kq, (h, hd, hd), dtype, scale=hd ** -0.5),
        "wk": dense_init(kk, (h, hd, hd), dtype, scale=hd ** -0.5),
        "wv": dense_init(kv, (h, hd, hd), dtype, scale=hd ** -0.5),
        "wi": dense_init(ki, (du, h), jnp.float32),
        "wf": dense_init(kf, (du, h), jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # open forget gates at init
        "skip": jnp.ones((du,), dtype),
        "norm_w": jnp.ones((du,), dtype),
        "down": dense_init(kd, (du, d), dtype),
    }


def _conv1d(x, w):
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(D_CONV):
        shift = D_CONV - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xs.astype(jnp.float32) * w[k].astype(jnp.float32)
    return y.astype(x.dtype)


def _heads(x, h, hd):
    return x.reshape(*x.shape[:-1], h, hd)


def mlstm_chunk_body(q, k, v, logi, logf, state):
    """One stabilised chunk.  q,k,v: (B,q,H,hd) f32; logi/logf: (B,q,H).

    state: (c (B,H,hdv,hdk), n (B,H,hdk), m (B,H)).  Returns (h, new state).
    Exactly equivalent to the per-token recurrence.
    """
    bs, qq, h, hd = q.shape
    scale = hd ** -0.5
    c_in, n_in, m_in = state
    cumf = jnp.cumsum(logf, axis=1)                       # (B,q,H)
    total = cumf[:, -1]                                   # (B,H)

    # ---- intra-chunk decay matrix (stabilised) ----
    dt = (cumf[:, :, None, :] - cumf[:, None, :, :]
          + logi[:, None, :, :])                          # (B,i,j,H)
    causal = jnp.tril(jnp.ones((qq, qq), bool))
    dt = jnp.where(causal[None, :, :, None], dt, NEG)
    m_intra = jnp.max(dt, axis=2)                         # (B,i,H)
    b_inter = cumf + m_in[:, None, :]                     # (B,i,H)
    m_comb = jnp.maximum(m_intra, b_inter)
    d = jnp.exp(dt - m_comb[:, :, None, :])
    inter_scale = jnp.exp(b_inter - m_comb)               # (B,i,H)

    scores = jnp.einsum("bihd,bjhd->bijh", q, k) * scale  # (B,i,j,H)
    s = scores * d
    num = jnp.einsum("bijh,bjhd->bihd", s, v)
    num = num + inter_scale[..., None] * jnp.einsum(
        "bhde,bihe->bihd", c_in, q) * scale
    den = jnp.sum(s, axis=2) + inter_scale * jnp.einsum(
        "bhe,bihe->bih", n_in, q) * scale
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))
    ht = num / den[..., None]

    # ---- state update ----
    w = total[:, None, :] - cumf + logi                   # (B,j,H)
    m_out = jnp.maximum(m_in + total, jnp.max(w, axis=1))
    wexp = jnp.exp(w - m_out[:, None, :])
    carry = jnp.exp(m_in + total - m_out)
    c_out = carry[:, :, None, None] * c_in + jnp.einsum(
        "bjh,bjhd,bjhe->bhde", wexp, v, k)
    n_out = carry[:, :, None] * n_in + jnp.einsum(
        "bjh,bjhe->bhe", wexp, k)
    return ht, (c_out, n_out, m_out)


def mlstm_chunked(q, k, v, logi, logf, state=None, chunk: int = CHUNK,
                  use_scan: bool = False):
    """Full-sequence chunkwise mLSTM.

    Python chunk loop by default (exact HLO FLOP accounting); deploy mode
    uses lax.scan over chunks (buffer reuse, one chunk live at a time).
    """
    bs, l, h, hd = q.shape
    chunk = min(chunk, l)
    if state is None:
        state = (jnp.zeros((bs, h, hd, hd), jnp.float32),
                 jnp.zeros((bs, h, hd), jnp.float32),
                 jnp.full((bs, h), NEG, jnp.float32))
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if use_scan and l % chunk == 0 and l > chunk:
        nc = l // chunk
        move = lambda x: jnp.moveaxis(
            x.reshape(bs, nc, chunk, *x.shape[2:]), 1, 0)
        xs = tuple(move(a) for a in (qf, kf, vf, logi, logf))

        @jax.checkpoint
        def body(st, inp):
            ht, st = mlstm_chunk_body(*inp, st)
            return st, ht
        state, outs = jax.lax.scan(body, state, xs)
        return (jnp.moveaxis(outs, 0, 1).reshape(bs, l, h, hd)
                .astype(q.dtype), state)
    outs = []
    for i in range(0, l, chunk):
        j = min(i + chunk, l)
        ht, state = mlstm_chunk_body(qf[:, i:j], kf[:, i:j], vf[:, i:j],
                                     logi[:, i:j], logf[:, i:j], state)
        outs.append(ht)
    return jnp.concatenate(outs, axis=1).astype(q.dtype), state


def _gates(params, xm):
    logi = jnp.log(jax.nn.sigmoid(
        xm.astype(jnp.float32) @ params["wi"] + params["bi"]) + 1e-9)
    logf = jnp.log(jax.nn.sigmoid(
        xm.astype(jnp.float32) @ params["wf"] + params["bf"]) + 1e-9)
    return logi, logf


def mlstm_forward(params, x, cfg, state=None) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence mLSTM block body. x: (B,L,d)."""
    bs, l, _ = x.shape
    du, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    xm = matmul(x, params["up_x"])
    z = matmul(x, params["up_z"])
    xc = jax.nn.silu(_conv1d(xm, params["conv_w"]))
    q = jnp.einsum("blhd,hde->blhe", _heads(xc, h, hd), params["wq"])
    k = jnp.einsum("blhd,hde->blhe", _heads(xc, h, hd), params["wk"])
    v = jnp.einsum("blhd,hde->blhe", _heads(xm, h, hd), params["wv"])
    logi, logf = _gates(params, xm)
    st = None
    if state is not None:
        st = (state["c"], state["n"], state["m"])
    if cfg.use_pallas_kernels:
        from repro.kernels.mlstm import ops as mlstm_ops
        ht, st_fin = mlstm_ops.mlstm(q, k, v, logi, logf)
    else:
        ht, st_fin = mlstm_chunked(q, k, v, logi, logf, st,
                                   use_scan=cfg.deploy)
    ht = ht.reshape(bs, l, du) + params["skip"] * xc
    y = rms_norm(params["norm_w"], ht, cfg.norm_eps) * jax.nn.silu(z)
    conv_tail = jnp.pad(
        xm, ((0, 0), (D_CONV - 1, 0), (0, 0)))[:, -(D_CONV - 1):]
    new_state = {"c": st_fin[0], "n": st_fin[1], "m": st_fin[2],
                 "conv": conv_tail}
    return matmul_rp(y, params["down"], cfg), new_state


def init_mlstm_state(cfg, batch, dtype):
    du, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), NEG, jnp.float32),
        "conv": jnp.zeros((batch, D_CONV - 1, du), dtype),
    }


def mlstm_decode(params, x, state, cfg):
    """One-token mLSTM step via the chunk body with q=1."""
    bs = x.shape[0]
    du, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    xm = matmul(x[:, 0], params["up_x"])                  # (B,du)
    z = matmul(x[:, 0], params["up_z"])
    window = jnp.concatenate([state["conv"], xm[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                                params["conv_w"].astype(jnp.float32))
                     ).astype(x.dtype)
    q = jnp.einsum("bhd,hde->bhe", _heads(xc, h, hd), params["wq"])
    k = jnp.einsum("bhd,hde->bhe", _heads(xc, h, hd), params["wk"])
    v = jnp.einsum("bhd,hde->bhe", _heads(xm, h, hd), params["wv"])
    logi, logf = _gates(params, xm)
    ht, (c, n, m) = mlstm_chunk_body(
        q[:, None].astype(jnp.float32), k[:, None].astype(jnp.float32),
        v[:, None].astype(jnp.float32), logi[:, None], logf[:, None],
        (state["c"], state["n"], state["m"]))
    ht = ht[:, 0].reshape(bs, du).astype(x.dtype) + params["skip"] * xc
    y = rms_norm(params["norm_w"], ht, cfg.norm_eps) * jax.nn.silu(z)
    new_state = {"c": c, "n": n, "m": m, "conv": window[:, 1:]}
    return matmul_rp(y, params["down"], cfg)[:, None], new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    kw, kr, ku, kd2 = jax.random.split(key, 4)
    dtype = cfg.param_dtype()
    ffd = int(4 * d / 3)
    return {
        "w": dense_init(kw, (d, 4 * d), dtype),           # i,f,z,o from x
        "r": dense_init(kr, (h, hd, 4 * hd), dtype, scale=hd ** -0.5),
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "norm_w": jnp.ones((d,), dtype),
        "ff_up": dense_init(ku, (d, 2 * ffd), dtype),     # GeGLU
        "ff_down": dense_init(kd2, (ffd, d), dtype),
    }


def init_slstm_state(cfg, batch, dtype):
    d = cfg.d_model
    return {k: jnp.zeros((batch, d), jnp.float32) for k in ("c", "n", "h")} | {
        "m": jnp.full((batch, d), NEG, jnp.float32)}


def _slstm_cell(params, gx, state, cfg):
    """One sLSTM step.  gx: (B,4d) input-gate preactivations."""
    h_heads = state["h"].reshape(gx.shape[0], cfg.n_heads, -1)
    gr = jnp.einsum("bhd,hde->bhe", h_heads,
                    params["r"].astype(jnp.float32))
    g = gx + gr.reshape(gx.shape[0], -1)                    # (B,4d)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jnp.log(jax.nn.sigmoid(gf + params["bf"]) + 1e-9)
    m_new = jnp.maximum(logf + state["m"], gi)
    fi = jnp.exp(logf + state["m"] - m_new)
    ii = jnp.exp(gi - m_new)
    c = fi * state["c"] + ii * jnp.tanh(gz)
    n = fi * state["n"] + ii
    hy = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": hy, "m": m_new}


def slstm_forward(params, x, cfg, state=None) -> Tuple[jnp.ndarray, dict]:
    """Sequential sLSTM over the sequence. x: (B,L,d)."""
    bs, l, d = x.shape
    gx = matmul(x, params["w"]).astype(jnp.float32)         # (B,L,4d)
    st = state or init_slstm_state(cfg, bs, x.dtype)

    def step(s, g):
        s_new = _slstm_cell(params, g, s, cfg)
        return s_new, s_new["h"]
    st_fin, hs = jax.lax.scan(step, st, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B,L,d)
    y = rms_norm(params["norm_w"], y, cfg.norm_eps)
    up, gate = jnp.split(matmul(y, params["ff_up"]), 2, axis=-1)
    y = matmul(jax.nn.gelu(up) * gate, params["ff_down"])
    return y, st_fin


def slstm_decode(params, x, state, cfg):
    gx = matmul(x[:, 0], params["w"]).astype(jnp.float32)
    st = _slstm_cell(params, gx, state, cfg)
    y = rms_norm(params["norm_w"], st["h"].astype(x.dtype), cfg.norm_eps)
    up, gate = jnp.split(matmul(y, params["ff_up"]), 2, axis=-1)
    y = matmul(jax.nn.gelu(up) * gate, params["ff_down"])
    return y[:, None], st
