"""Process-local telemetry plane: spans, counters, gauges, histograms.

The reproduction's evaluation (like Faabric's §6) hinges on fine-grained
visibility — per-decision scheduling latency, migration cost, checkpoint
bytes, serve-queue depth — but recording must never perturb the thing it
measures.  The contract mirrors the CostModel's opt-in features
(``risk_tau_s=None``): the module-level default recorder is a **no-op**
whose every method returns immediately, so instrumented call sites are
zero-cost and all pinned traces stay bit-identical until a caller
explicitly installs a live recorder with :func:`enable` / :func:`recording`.

Two clocks share one span schema:

* ``clock="wall"`` — real elapsed time (``time.perf_counter``), used by
  live code paths (GangHandle lifecycle, placement decisions, probes).
* ``clock="virtual"`` — simulator time, attached after a run by
  :meth:`Telemetry.record_actions`, so simulated and live timelines
  render identically in the same viewer.

Exports:

* :meth:`Telemetry.to_chrome_trace` / :meth:`write_chrome_trace` — Chrome
  trace-event JSON (Perfetto-loadable): one track per gang, one per host,
  instant events for Actions, counter tracks for gauges.
* :meth:`Telemetry.summary` — metrics-summary dict folded into the
  ``results/`` benchmark schema.
* :func:`diff_traces` — align a predicted and a live Action stream,
  report the first divergence with surrounding context, and compute
  per-phase predicted-vs-measured time error (the ROADMAP item-2
  fidelity metric).

Calibration: :meth:`Telemetry.step_time` aggregates measured step times
per (host-kind, job-kind); :meth:`feed_cost_model` pushes them into
``CostModel.observe_step`` so the self-calibration loop has a data source.

The module imports nothing from the rest of ``repro`` (Action objects are
duck-typed via ``.kind`` / ``.payload``), so any layer may import it.
"""
from __future__ import annotations

import bisect
import difflib
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Telemetry", "get", "enable", "disable", "recording",
    "diff_traces", "spans_from_actions",
]

# Fixed histogram bucket bounds: 1 µs .. 100 s, four per decade.  Fixed
# (not adaptive) so summaries from different runs merge/compare cleanly.
HIST_BOUNDS: Tuple[float, ...] = tuple(
    round(1e-6 * 10 ** (i / 4.0), 12) for i in range(33))

# Cap per-gauge time series so a long serve run cannot grow unbounded;
# the last value is always kept exactly.
_GAUGE_SERIES_CAP = 4096


class _Histogram:
    __slots__ = ("counts", "n", "total", "min", "max")

    def __init__(self) -> None:
        self.counts = [0] * (len(HIST_BOUNDS) + 1)
        self.n = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_right(HIST_BOUNDS, value)] += 1
        self.n += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def percentile(self, q: float) -> float:
        """Upper bucket bound holding the q-th percentile (0..100)."""
        if self.n == 0:
            return 0.0
        rank = max(1, int(round(q / 100.0 * self.n)))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return HIST_BOUNDS[i] if i < len(HIST_BOUNDS) else self.max
        return self.max

    def to_dict(self) -> Dict[str, Any]:
        return {
            "count": self.n,
            "sum": self.total,
            "min": self.min if self.n else 0.0,
            "max": self.max if self.n else 0.0,
            "mean": (self.total / self.n) if self.n else 0.0,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "buckets": {
                ("%.3g" % HIST_BOUNDS[i]) if i < len(HIST_BOUNDS)
                else "+inf": c
                for i, c in enumerate(self.counts) if c
            },
        }


class _SpanCtx:
    """Context manager recording one wall-clock span on exit."""

    __slots__ = ("_tel", "name", "track", "attrs", "t0")

    def __init__(self, tel: "Telemetry", name: str, track: str,
                 attrs: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.track = track
        self.attrs = attrs
        self.t0 = 0.0

    def __enter__(self) -> "_SpanCtx":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tel.span_at(self.name, self.t0, time.perf_counter(),
                          track=self.track, clock="wall", **self.attrs)


class _NullCtx:
    __slots__ = ("attrs",)

    def __init__(self) -> None:
        self.attrs: Dict[str, Any] = {}

    def __enter__(self) -> "_NullCtx":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_CTX = _NullCtx()


class Telemetry:
    """Live recorder: spans + counters + gauges + histograms."""

    enabled = True

    def __init__(self) -> None:
        self.spans: List[Dict[str, Any]] = []
        self.instants: List[Dict[str, Any]] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.gauge_series: Dict[str, List[Tuple[float, float]]] = {}
        self.histograms: Dict[str, _Histogram] = {}
        # (host_kind, job_kind) -> [count, total_s]
        self.step_times: Dict[Tuple[str, str], List[float]] = {}
        self._t_origin = time.perf_counter()

    # ---- recording ----------------------------------------------------------
    def span(self, name: str, track: str = "main", **attrs):
        """Wall-clock span context manager: ``with tel.span("x"): ...``."""
        return _SpanCtx(self, name, track, attrs)

    def span_at(self, name: str, t0: float, t1: float, track: str = "main",
                clock: str = "wall", **attrs) -> None:
        """Record a span with explicit start/end (either clock)."""
        self.spans.append({"name": name, "t0": t0, "t1": t1,
                           "track": track, "clock": clock, "attrs": attrs})

    def instant(self, name: str, t: Optional[float] = None,
                track: str = "main", clock: str = "wall", **attrs) -> None:
        if t is None:
            t = time.perf_counter()
        self.instants.append({"name": name, "t": t, "track": track,
                              "clock": clock, "attrs": attrs})

    def count(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float,
              t: Optional[float] = None) -> None:
        self.gauges[name] = value
        series = self.gauge_series.setdefault(name, [])
        if len(series) < _GAUGE_SERIES_CAP:
            series.append((time.perf_counter() - self._t_origin
                           if t is None else t, float(value)))

    def observe(self, name: str, value: float) -> None:
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = _Histogram()
        hist.observe(value)

    def step_time(self, host_kind: str, job_kind: str,
                  seconds: float) -> None:
        """Measured per-step wall time for one (host-kind, job-kind)."""
        agg = self.step_times.setdefault((host_kind, job_kind), [0, 0.0])
        agg[0] += 1
        agg[1] += seconds
        self.observe(f"step_time_s/{host_kind}/{job_kind}", seconds)

    def record_actions(self, actions: Sequence[Any],
                       clock: str = "virtual") -> None:
        """Attach a simulator/live Action log as virtual-clock spans."""
        spans, instants = spans_from_actions(actions, clock=clock)
        self.spans.extend(spans)
        self.instants.extend(instants)

    # ---- calibration --------------------------------------------------------
    def step_time_aggregates(self) -> Dict[Tuple[str, str],
                                           Tuple[int, float]]:
        """(host_kind, job_kind) -> (count, mean seconds)."""
        return {k: (int(v[0]), v[1] / v[0])
                for k, v in self.step_times.items() if v[0]}

    def feed_cost_model(self, model: Any) -> int:
        """Push step-time aggregates into ``CostModel.observe_step``.

        Returns the number of (host-kind, job-kind) pairs fed."""
        observe = getattr(model, "observe_step", None)
        if observe is None:
            return 0
        fed = 0
        for (hk, jk), (n, mean_s) in self.step_time_aggregates().items():
            observe(hk, jk, mean_s, count=n)
            fed += 1
        return fed

    # ---- export -------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        tracks = {}
        for s in self.spans:
            tracks[s["track"]] = tracks.get(s["track"], 0) + 1
        span_s: Dict[str, float] = {}
        span_n: Dict[str, int] = {}
        for s in self.spans:
            span_s[s["name"]] = span_s.get(s["name"], 0.0) \
                + (s["t1"] - s["t0"])
            span_n[s["name"]] = span_n.get(s["name"], 0) + 1
        return {
            "spans_total": len(self.spans),
            "instants_total": len(self.instants),
            "span_counts": dict(sorted(span_n.items())),
            "span_seconds": {k: round(v, 9)
                             for k, v in sorted(span_s.items())},
            "tracks": dict(sorted(tracks.items())),
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self.histograms.items())},
            "step_time_aggregates": {
                f"{hk}/{jk}": {"count": n, "mean_s": mean}
                for (hk, jk), (n, mean)
                in sorted(self.step_time_aggregates().items())},
        }

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON dict (load in Perfetto / about:tracing).

        Virtual-clock events land in pid 1 ("virtual: gangs") and pid 2
        ("virtual: hosts"); wall-clock events in pid 10 ("wall").  One
        tid per track (gang / host / subsystem); Action instants render
        as 'i' events; gauges as 'C' counter tracks.
        """
        events: List[Dict[str, Any]] = []
        tids: Dict[Tuple[int, str], int] = {}
        pids_named = set()

        def pid_for(track: str, clock: str) -> int:
            if clock == "virtual":
                return 2 if track.startswith("host") else 1
            return 10

        def tid_for(pid: int, track: str) -> int:
            key = (pid, track)
            if key not in tids:
                tids[key] = len(tids) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": tids[key],
                               "args": {"name": track}})
            return tids[key]

        def ensure_pid(pid: int) -> None:
            if pid in pids_named:
                return
            pids_named.add(pid)
            label = {1: "virtual: gangs", 2: "virtual: hosts",
                     10: "wall"}.get(pid, str(pid))
            events.append({"ph": "M", "name": "process_name",
                           "pid": pid, "args": {"name": label}})

        def cat_of(name: str) -> str:
            return name.split(".", 1)[0].split("/", 1)[0]

        for s in self.spans:
            pid = pid_for(s["track"], s["clock"])
            ensure_pid(pid)
            t0 = s["t0"] if s["clock"] == "virtual" \
                else s["t0"] - self._t_origin
            events.append({
                "ph": "X", "name": s["name"], "cat": cat_of(s["name"]),
                "pid": pid, "tid": tid_for(pid, s["track"]),
                "ts": round(t0 * 1e6, 3),
                "dur": max(0.0, round((s["t1"] - s["t0"]) * 1e6, 3)),
                "args": _plain(s["attrs"]),
            })
        for ev in self.instants:
            pid = pid_for(ev["track"], ev["clock"])
            ensure_pid(pid)
            t = ev["t"] if ev["clock"] == "virtual" \
                else ev["t"] - self._t_origin
            events.append({
                "ph": "i", "s": "t", "name": ev["name"],
                "cat": cat_of(ev["name"]),
                "pid": pid, "tid": tid_for(pid, ev["track"]),
                "ts": round(t * 1e6, 3),
                "args": _plain(ev["attrs"]),
            })
        ensure_pid(10)
        ctr_tid = 0   # counter events render per-name, tid unused
        for name, series in sorted(self.gauge_series.items()):
            for t, v in series:
                events.append({"ph": "C", "name": name,
                               "cat": cat_of(name), "pid": 10,
                               "tid": ctr_tid, "ts": round(t * 1e6, 3),
                               "args": {name: v}})
        # monotonic counters: one final-total sample each, so the layer
        # is visible on the timeline even when its only signal is counts
        t_end = round((time.perf_counter() - self._t_origin) * 1e6, 3)
        for name, v in sorted(self.counters.items()):
            events.append({"ph": "C", "name": name,
                           "cat": cat_of(name), "pid": 10,
                           "tid": ctr_tid, "ts": t_end,
                           "args": {name: v}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def write_summary(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(_plain(self.summary()), f, indent=1, sort_keys=True)


class _NoopTelemetry(Telemetry):
    """Default recorder: every method returns immediately, records nothing.

    Instrumented call sites check ``tel.enabled`` before computing attrs,
    and even un-gated calls are a no-op — pinned traces stay bit-identical
    (the ``risk_tau_s=None`` contract).
    """

    enabled = False

    def __init__(self) -> None:
        super().__init__()

    def span(self, name, track="main", **attrs):
        return _NULL_CTX

    def span_at(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def count(self, *a, **k) -> None:
        pass

    def gauge(self, *a, **k) -> None:
        pass

    def observe(self, *a, **k) -> None:
        pass

    def step_time(self, *a, **k) -> None:
        pass

    def record_actions(self, *a, **k) -> None:
        pass


_NOOP = _NoopTelemetry()
_current: Telemetry = _NOOP


def get() -> Telemetry:
    """The active recorder (the module-level no-op unless enabled)."""
    return _current


def enable(recorder: Optional[Telemetry] = None) -> Telemetry:
    """Install (and return) a live recorder as the process default."""
    global _current
    _current = recorder if recorder is not None else Telemetry()
    return _current


def disable() -> None:
    """Restore the zero-cost no-op default."""
    global _current
    _current = _NOOP


class recording:
    """``with telemetry.recording() as tel: ...`` — scoped enable."""

    def __init__(self, recorder: Optional[Telemetry] = None):
        self.recorder = recorder if recorder is not None else Telemetry()

    def __enter__(self) -> Telemetry:
        self._prev = _current
        enable(self.recorder)
        return self.recorder

    def __exit__(self, *exc) -> None:
        global _current
        _current = self._prev


# ---- Action-stream utilities ------------------------------------------------

def _plain(value: Any) -> Any:
    """Coerce numpy scalars/arrays and tuples to JSON-plain Python."""
    if isinstance(value, dict):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    item = getattr(value, "item", None)
    if item is not None and getattr(value, "shape", None) == ():
        return item()
    tolist = getattr(value, "tolist", None)
    if tolist is not None:
        return _plain(tolist())
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    return str(value)


def _action_dict(action: Any) -> Dict[str, Any]:
    to_dict = getattr(action, "to_dict", None)
    if to_dict is not None:
        return to_dict()
    if isinstance(action, dict):
        return {"kind": action.get("kind"),
                "payload": _plain(action.get("payload", {}))}
    return {"kind": getattr(action, "kind", "?"),
            "payload": _plain(getattr(action, "payload", {}))}


# Action kinds that close a job's run segment; everything else with a
# job id is an instant on that gang's track.
_SEG_OPEN = ("start", "resume", "recover", "regrow")
_SEG_CLOSE = ("preempt", "finish", "host-fail", "shrink", "evacuate")
_HOST_KINDS = ("join", "drain", "retire")


def spans_from_actions(actions: Sequence[Any], clock: str = "virtual"
                       ) -> Tuple[List[Dict[str, Any]],
                                  List[Dict[str, Any]]]:
    """Convert an Action log into (spans, instants) in the span schema.

    A gang's run segments open on start/resume/recover/regrow and close
    on preempt/finish/shrink/evacuate/host-fail; every Action also emits
    an instant on its gang track (or host track for fleet events) so the
    full decision stream is visible on the timeline.
    """
    spans: List[Dict[str, Any]] = []
    instants: List[Dict[str, Any]] = []
    open_seg: Dict[Any, Tuple[float, Dict[str, Any]]] = {}
    t_max = 0.0
    for a in actions:
        kind = getattr(a, "kind", None) or (a.get("kind")
                                            if isinstance(a, dict) else "?")
        payload = getattr(a, "payload", None)
        if payload is None and isinstance(a, dict):
            payload = a.get("payload", {})
        payload = payload or {}
        t = float(payload.get("t", t_max))
        t_max = max(t_max, t)
        job = payload.get("job")
        if kind in _HOST_KINDS or job is None:
            hosts = payload.get("hosts", payload.get("host"))
            if not isinstance(hosts, (list, tuple)):
                hosts = [hosts] if hosts is not None else ["fleet"]
            for h in hosts:
                instants.append({"name": f"fleet.{kind}", "t": t,
                                 "track": f"host:{h}", "clock": clock,
                                 "attrs": _plain(payload)})
            continue
        track = f"gang:{job}"
        instants.append({"name": f"action.{kind}", "t": t, "track": track,
                         "clock": clock, "attrs": _plain(payload)})
        if kind in _SEG_OPEN:
            if job not in open_seg:
                open_seg[job] = (t, {"opened_by": kind})
        elif kind in _SEG_CLOSE and job in open_seg:
            t0, attrs = open_seg.pop(job)
            attrs["closed_by"] = kind
            spans.append({"name": "run", "t0": t0, "t1": t,
                          "track": track, "clock": clock, "attrs": attrs})
    for job, (t0, attrs) in open_seg.items():
        attrs["closed_by"] = "end-of-trace"
        spans.append({"name": "run", "t0": t0, "t1": t_max,
                      "track": f"gang:{job}", "clock": clock,
                      "attrs": attrs})
    return spans, instants


def _sig(action: Any) -> Tuple[Any, Any]:
    kind = getattr(action, "kind", None) or (action.get("kind")
                                             if isinstance(action, dict)
                                             else "?")
    payload = getattr(action, "payload", None)
    if payload is None and isinstance(action, dict):
        payload = action.get("payload", {})
    return (kind, (payload or {}).get("job"))


def diff_traces(predicted: Any, live: Any,
                context: int = 3) -> Dict[str, Any]:
    """Align two Action streams; report divergence + per-phase time error.

    ``predicted``/``live`` are Action sequences (or objects with an
    ``.actions`` attribute, e.g. ``TraceResult``).  Streams are aligned
    by ``(kind, job)`` signature with ``difflib.SequenceMatcher``; the
    **first divergence** is the earliest position where the aligned
    signatures differ (an insertion, deletion, or replacement), reported
    with ``context`` surrounding actions from both streams.  For aligned
    pairs, per-phase (= per Action kind) time error compares the two
    streams' ``payload["t"]`` stamps: mean/max absolute delta and the
    relative phase-span error.
    """
    pred = list(getattr(predicted, "actions", predicted))
    liv = list(getattr(live, "actions", live))
    psig = [_sig(a) for a in pred]
    lsig = [_sig(a) for a in liv]
    sm = difflib.SequenceMatcher(a=psig, b=lsig, autojunk=False)
    divergences = 0
    first: Optional[Dict[str, Any]] = None
    matched: List[Tuple[Any, Any]] = []
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "equal":
            matched.extend(zip(pred[i1:i2], liv[j1:j2]))
            continue
        divergences += max(i2 - i1, j2 - j1)
        if first is None:
            first = {
                "predicted_index": i1,
                "live_index": j1,
                "op": tag,
                "predicted": [_action_dict(a)
                              for a in pred[i1:min(i2, i1 + context)]],
                "live": [_action_dict(a)
                         for a in liv[j1:min(j2, j1 + context)]],
                "context_before": [_action_dict(a)
                                   for a in pred[max(0, i1 - context):i1]],
                "context_after": [_action_dict(a)
                                  for a in pred[i2:i2 + context]],
            }
    phases: Dict[str, Dict[str, Any]] = {}
    for p, l in matched:
        kind, _ = _sig(p)
        pt = (getattr(p, "payload", p.get("payload", {})
                      if isinstance(p, dict) else {})).get("t")
        lt = (getattr(l, "payload", l.get("payload", {})
                      if isinstance(l, dict) else {})).get("t")
        if pt is None or lt is None:
            continue
        ph = phases.setdefault(kind, {"count": 0, "sum_abs_dt_s": 0.0,
                                      "max_abs_dt_s": 0.0,
                                      "pred_min": float("inf"),
                                      "pred_max": float("-inf"),
                                      "live_min": float("inf"),
                                      "live_max": float("-inf")})
        dt = abs(float(lt) - float(pt))
        ph["count"] += 1
        ph["sum_abs_dt_s"] += dt
        ph["max_abs_dt_s"] = max(ph["max_abs_dt_s"], dt)
        ph["pred_min"] = min(ph["pred_min"], float(pt))
        ph["pred_max"] = max(ph["pred_max"], float(pt))
        ph["live_min"] = min(ph["live_min"], float(lt))
        ph["live_max"] = max(ph["live_max"], float(lt))
    phase_error: Dict[str, Any] = {}
    for kind, ph in sorted(phases.items()):
        pred_span = ph["pred_max"] - ph["pred_min"]
        live_span = ph["live_max"] - ph["live_min"]
        phase_error[kind] = {
            "count": ph["count"],
            "mean_abs_dt_s": ph["sum_abs_dt_s"] / ph["count"],
            "max_abs_dt_s": ph["max_abs_dt_s"],
            "predicted_span_s": pred_span,
            "live_span_s": live_span,
            "span_rel_error": (abs(live_span - pred_span) / pred_span
                               if pred_span > 0 else 0.0),
        }
    return {
        "n_predicted": len(pred),
        "n_live": len(liv),
        "aligned": len(matched),
        "divergences": divergences,
        "first_divergence": first,
        "phase_error": phase_error,
    }
