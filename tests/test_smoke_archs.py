"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU; asserts output shapes and no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), cfg.param_dtype())
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype())
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = jax.jit(
        lambda p, t: tf.forward(p, t, cfg,
                                {k: batch[k] for k in ("frames", "img")
                                 if k in batch}))(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = reduced_config(arch)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(0)
    state = jax.jit(lambda k: M.init_train_state(k, cfg, ocfg))(key)
    step = jax.jit(M.make_train_step(cfg, ocfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # same-batch loss must drop


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_positive(arch):
    cfg = get_config(arch)
    n = M.count_params(cfg)
    na = M.count_params(cfg, active_only=True)
    assert n > 0 and 0 < na <= n
    if cfg.n_experts:
        assert na < n


def test_grad_accum_matches_full_batch():
    cfg = reduced_config("llama3.2-1b")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(0)
    state = jax.jit(lambda k: M.init_train_state(k, cfg, ocfg))(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    s1, m1 = jax.jit(M.make_train_step(cfg, ocfg, grad_accum=1))(state, batch)
    s2, m2 = jax.jit(M.make_train_step(cfg, ocfg, grad_accum=2))(state, batch)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_grad_accum_scan_matches_unrolled():
    cfg = reduced_config("llama3.2-1b")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(0)
    state = jax.jit(lambda k: M.init_train_state(k, cfg, ocfg))(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    s1, _ = jax.jit(M.make_train_step(cfg, ocfg, grad_accum=2))(state, batch)
    cfg_d = cfg.with_(deploy=True)
    s2, _ = jax.jit(M.make_train_step(cfg_d, ocfg, grad_accum=2))(state,
                                                                  batch)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
