"""CI gate: every standardized benchmark artifact in results/ must
parse as JSON and carry a non-empty ``metrics`` table (schema in
``benchmarks/run.py``).  Covers both the committed full-size
``BENCH_*.json`` trajectory and freshly-produced ``SMOKE_*.json``.

Two stronger checks ride on top (the delta data plane's perf gate):

* **required metrics** — ``bench_shared_memory`` artifacts must report
  ``merge_apply_throughput`` and ``delta_checkpoint_bytes``; a refactor
  that silently drops the data-plane measurements fails the gate.
* **regression guard** — metrics listed in
  ``benchmarks/recorded_baselines.json`` (committed, since results/ is
  gitignored) must stay within 2x of their recorded value; a merge
  throughput collapse back toward the chunk-loop reference
  (~100x slower) fails loudly even at smoke tier.
"""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
BASELINES = os.path.join(os.path.dirname(__file__),
                         "recorded_baselines.json")

# bench name -> metrics every artifact of that bench must report
REQUIRED_METRICS = {
    "bench_shared_memory": ("merge_apply_throughput",
                            "delta_checkpoint_bytes"),
    "bench_message_passing": ("hierarchical_vs_flat_speedup",
                              "compressed_vs_flat_speedup",
                              "compressed_crossover_bytes",
                              "slowlink_bytes_flat",
                              "slowlink_bytes_hierarchical",
                              "codec_select_speedup"),
    "bench_makespan": ("collective_priced/improvement",),
    "bench_serving": ("continuous_vs_fixed/min_throughput_ratio",
                      "burst_autoscaler/p99_within_target",
                      "train_serve/drain_saves_work_s",
                      "train_serve/p99_within_target"),
    "bench_churn": tuple(
        [f"risk/{r}/{m}" for r in ("spot-heavy", "steady-join",
                                   "correlated-rack-failure")
         for m in ("lost_work_blind_s", "lost_work_aware_s",
                   "inflation_pct_aware", "improves")]
        + ["risk/correlated-rack-failure/shrink_recoveries",
           "risk/aware_identical_rerun", "risk/off_bit_identical"]),
}
REGRESSION_FACTOR = 2.0

# hard acceptance gates, full-tier (BENCH_*) artifacts only — smoke
# sizes are too small for the Fig 9 schedule gaps to show:
#  * the two-level schedule must beat flat >= 2x on the slow-link mesh,
#  * the compressed schedule must beat flat past a measured crossover,
#  * collective_time-scored placement must beat scalar-beta on the
#    net-heavy trace
FULL_TIER_GATES = {
    "bench_message_passing": (
        ("hierarchical_vs_flat_speedup", 2.0),
        ("compressed_vs_flat_speedup", 1.0),
        ("compressed_crossover_bytes", 0.0),
    ),
    "bench_makespan": (
        ("collective_priced/improvement", 0.0),
    ),
}

# gates enforced on BOTH tiers (BENCH_* and SMOKE_*): bench_serving
# and bench_churn run on deterministic virtual clocks, so their
# acceptance criteria — continuous batching strictly out-throughputs
# fixed batching at every offered load, the autoscaler holds the p99
# SLO under burst / combined train+serve load, and risk-aware placement
# + shrink-before-rollback loses no more work and no more makespan than
# the risk-blind arm in every churn regime (with the correlated-rack
# case recovering stranded gangs by shrinking, and the risk term
# staying bit-identical when off) — are exact even at smoke sizes
ALL_TIER_GATES = {
    "bench_serving": (
        ("continuous_vs_fixed/min_throughput_ratio", 1.0),
        ("burst_autoscaler/p99_within_target", 0.0),
        ("train_serve/drain_saves_work_s", 0.0),
        ("train_serve/p99_within_target", 0.0),
    ),
    "bench_churn": (
        ("risk/spot-heavy/improves", 0.0),
        ("risk/steady-join/improves", 0.0),
        ("risk/correlated-rack-failure/improves", 0.0),
        ("risk/correlated-rack-failure/shrink_recoveries", 0.0),
        ("risk/aware_identical_rerun", 0.0),
        ("risk/off_bit_identical", 0.0),
    ),
}


def _baselines() -> dict:
    try:
        with open(BASELINES) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    return {k: v for k, v in data.items() if isinstance(v, dict)}


def main() -> int:
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "BENCH_*.json"))
                   + glob.glob(os.path.join(RESULTS_DIR,
                                            "SMOKE_*.json")))
    if not paths:
        print("no BENCH_*/SMOKE_* artifacts found", file=sys.stderr)
        return 1
    bad = 0
    baselines = _baselines()
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {name}: unreadable ({e})", file=sys.stderr)
            bad += 1
            continue
        metrics = payload.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            print(f"FAIL {name}: empty or missing metrics",
                  file=sys.stderr)
            bad += 1
            continue
        bench = payload.get("bench")
        missing = [m for m in REQUIRED_METRICS.get(bench, ())
                   if m not in metrics]
        if missing:
            print(f"FAIL {name}: missing required metrics "
                  f"{missing}", file=sys.stderr)
            bad += 1
            continue
        regressed = []
        for metric, floor in baselines.get(bench, {}).items():
            cur = metrics.get(metric, {})
            value = cur.get("value") if isinstance(cur, dict) else None
            if not isinstance(value, (int, float)):
                continue
            if value * REGRESSION_FACTOR < floor:
                regressed.append(
                    f"{metric}={value} (recorded {floor}, floor "
                    f"{round(floor / REGRESSION_FACTOR, 2)})")
        if regressed:
            print(f"FAIL {name}: regression guard: "
                  f"{'; '.join(regressed)}", file=sys.stderr)
            bad += 1
            continue
        gates = list(ALL_TIER_GATES.get(bench, ()))
        if name.startswith("BENCH_"):
            gates += list(FULL_TIER_GATES.get(bench, ()))
        gated = []
        for metric, floor in gates:
            cur = metrics.get(metric, {})
            value = cur.get("value") if isinstance(cur, dict) \
                else None
            if not isinstance(value, (int, float)) \
                    or value <= floor:
                gated.append(f"{metric}={value} (must be > {floor})")
        if gated:
            print(f"FAIL {name}: acceptance gate: "
                  f"{'; '.join(gated)}", file=sys.stderr)
            bad += 1
            continue
        print(f"ok   {name}: {len(metrics)} metrics "
              f"(bench={payload.get('bench')}, "
              f"wall={payload.get('wall_s')}s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
