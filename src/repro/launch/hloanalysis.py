"""HLO-text analysis for the roofline: HBM-byte estimation and per-kind
collective byte counts from the SPMD-partitioned (per-device) module.

``cost_analysis()['bytes accessed']`` on the CPU backend counts every
un-fused elementwise op — traffic a TPU compile would fuse away — inflating
the memory term ~20x.  ``analyze`` instead models **perfect fusion**: all
fusable ops (elementwise chains, broadcasts, converts, CPU micro-fusions)
are coalesced into clusters via union-find, and HBM traffic is counted only
on edges that cross a cluster boundary or touch a genuinely
memory-resident op (dot/conv/reduce-window/scatter/collective/parameter).
Slices/gathers read only their result region.  This approximates TPU
HloCostAnalysis-with-fusion semantics; it is an estimate, and is documented
as such in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# ops that are NEVER fused away on TPU: their operands/results hit HBM
MATERIAL = {
    "dot", "convolution", "reduce-window", "scatter",
    "dynamic-update-slice", "sort", "rng", "custom-call", "while",
    "conditional", "parameter", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "cholesky",
    "triangular-solve", "fft",
}
# consumers that read only their result-sized region of the operand
REGION_READERS = {"slice", "dynamic-slice", "gather", "get-tuple-element"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:\w+\[[\d,]*\](?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\((.*)", )
_OPERAND = re.compile(r"%[\w.\-]+|\b[\w\-]+\.\d+\b")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _operands(rest: str) -> List[str]:
    depth, buf = 1, ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf += ch
    # strip literal braces (constants) to avoid matching numbers
    buf = re.sub(r"\{[^}]*\}", "", buf)
    return [o.lstrip("%") for o in _OPERAND.findall(buf)]


class _UF:
    def __init__(self):
        self.p: Dict[str, str] = {}

    def find(self, x: str) -> str:
        p = self.p
        while p.setdefault(x, x) != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: str, b: str) -> None:
        self.p[self.find(a)] = self.find(b)


def analyze(hlo: str) -> Dict[str, int]:
    """One pass over the HLO text; returns byte tallies."""
    nodes: Dict[str, Tuple[str, int, List[str]]] = {}
    order: List[str] = []
    in_entry = False
    for line in hlo.splitlines():
        # only the ENTRY computation: fusion bodies are counted at their
        # call sites, reducer/body computations are implementation detail
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if line and not line[0].isspace():
            in_entry = False
            continue
        if not in_entry:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shapes, opcode, rest = m.groups()
        name = name.lstrip("%")
        nodes[name] = (opcode, _shape_bytes(shapes), _operands(rest))
        order.append(name)

    fusable = lambda op: op not in MATERIAL and op not in REGION_READERS
    uf = _UF()
    for name in order:
        opcode, rb, ops = nodes[name]
        if not fusable(opcode):
            continue
        for o in ops:
            if o in nodes and fusable(nodes[o][0]):
                uf.union(name, o)

    out = {k: 0 for k in COLLECTIVES}
    hbm = 0
    consumed_cross: set = set()       # tensors materialized for a consumer
    read_edges: set = set()           # (tensor, consumer_cluster)
    for name in order:
        opcode, rb, ops = nodes[name]
        if opcode in COLLECTIVES:
            out[opcode] += rb
        if opcode in REGION_READERS:
            hbm += 2 * rb             # read region + write result
            consumed_cross.update(o for o in ops if o in nodes)
            continue
        if opcode in ("while", "conditional", "parameter", "constant"):
            continue
        my_cluster = uf.find(name) if fusable(opcode) else name
        for o in ops:
            if o not in nodes:
                continue
            o_op, o_rb, _ = nodes[o]
            o_cluster = uf.find(o) if fusable(o_op) else o
            if o_cluster == my_cluster:
                continue              # fused edge: free
            consumed_cross.add(o)
            if (o, my_cluster) not in read_edges:
                read_edges.add((o, my_cluster))
                hbm += o_rb           # cluster reads the tensor once
    # writes: every tensor read across a cluster boundary was materialized
    for o in consumed_cross:
        hbm += nodes[o][1]
    out["collective_bytes"] = sum(out[k] for k in COLLECTIVES)
    out["hbm_bytes"] = hbm
    return out
