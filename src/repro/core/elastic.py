"""Elastic scaling of the data-parallel world at control points.

Faabric adds/removes Granules when an application's parallelism changes; a
training job's analogue is growing/shrinking its data-parallel gang while
keeping the *global* batch size and the loss trajectory unchanged:

* params/optimizer state are placement-independent (replicated or
  re-factorised over the new mesh) — a snapshot restore onto new shardings;
* the deterministic data pipeline is keyed by (seed, step), so per-device
  batch slices re-partition cleanly at any step boundary;
* growth uses the scheduler to carve a larger sub-mesh; shrink releases
  chips back to the shared pool (the provider-utilisation story of §2.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import migration
from repro.core.placement import PlacementEngine


def make_dp_mesh(devices: Sequence[Any]) -> Mesh:
    """1-D data-parallel mesh over an explicit device list (a gang)."""
    return Mesh(np.asarray(devices), ("data",))


def replicated_shardings(state, mesh: Mesh):
    s = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: s, state)


def batch_shardings(batch, mesh: Mesh):
    s = NamedSharding(mesh, P("data"))
    return jax.tree.map(lambda _: s, batch)


def reshard_gang(state, new_devices: Sequence[Any]):
    """Re-factorise a DP gang onto a new device set (grow or shrink).

    Returns (new_state, new_mesh).  State is replicated across the DP gang,
    so this is a pure placement change — bit-exact by construction.
    """
    mesh = make_dp_mesh(new_devices)
    new_state = migration.migrate_live(state, replicated_shardings(state,
                                                                   mesh))
    return new_state, mesh


def shrink_worlds(n: int, floor: Optional[int] = None) -> List[int]:
    """Candidate world sizes for shrink-before-rollback, largest first:
    the gang's full width ``n`` (a refit onto surviving capacity keeps
    everything), then each power of two below it down to ``floor``
    (powers of two keep the global batch dividing evenly, the same
    snapping ``ElasticPolicy`` uses).  ``floor`` defaults to
    ``max(1, n // 4)``: shrinking more than 4x runs so slowly that a
    checkpoint rollback + full-width requeue wins once capacity
    returns."""
    if floor is None:
        floor = max(1, n // 4)
    worlds = [n]
    p = 1
    while p * 2 < n:
        p *= 2
    while p >= max(1, floor) and p < n:
        worlds.append(p)
        p //= 2
    return worlds


@dataclasses.dataclass
class ElasticPolicy:
    """Decides the DP world size from the cluster's free-chip signal.

    ``target_free``: leave this many chips for other tenants (the paper's
    shared-cluster economics); world size snaps to powers of two so the
    global batch divides evenly.

    The decision goes through the shared ``PlacementEngine`` — the same
    free-chip accounting the simulator and scheduler use: the budget
    comes from ``engine.idle_chips()``, and a grow is validated with a
    reservation probe.  The shipped greedy policies can always fragment
    a gang into any free chips, so the probe only rejects under future
    contiguity-constrained policies; it is released before returning,
    so a caller that needs to *hold* the chips across a multi-step
    rescale should keep its own ``engine.reserve`` open until commit.
    """
    min_world: int = 1
    max_world: int = 64
    target_free: int = 0

    def decide(self, world: int, engine: PlacementEngine,
               kind: Optional[str] = None) -> Optional[int]:
        """``kind`` is the tenant's job kind: the grow probe runs the
        engine's placement policy under the same per-kind beta the
        simulator and migration planner use (``engine.cost_model``), so
        an elastic grow lands exactly where a trace placement would."""
        budget = world + engine.idle_chips() - self.target_free
        new = self.min_world
        while new * 2 <= min(budget, self.max_world):
            new *= 2
        if new == world:
            return None
        if new > world:
            res = engine.reserve(new - world, kind=kind)
            if res is None:                 # gang not carveable right now
                return None
            engine.cancel(res)
        return new

    def decide_scaled(self, world: int, engine: PlacementEngine,
                      factor: float,
                      kind: Optional[str] = None) -> Optional[int]:
        """Directional variant for feedback controllers (the serve
        autoscaler): ask for ``world * factor`` chips instead of the
        whole free budget.  ``factor`` > 1 grows toward the SLO (capped
        by the free-chip budget and validated with a reserve probe like
        ``decide``), < 1 drains capacity back to the pool.  The result
        snaps to a power of two within [min_world, max_world]; returns
        None when no change is possible right now."""
        def p2floor(x: float) -> int:
            n = self.min_world
            while n * 2 <= x:
                n *= 2
            return n

        want = max(float(self.min_world),
                   min(float(self.max_world), world * factor))
        new = p2floor(want)
        if new > world:
            budget = world + engine.idle_chips() - self.target_free
            new = min(new, p2floor(budget))
        if new == world:
            return None
        if new > world:
            res = engine.reserve(new - world, kind=kind)
            if res is None:
                return None
            engine.cancel(res)
        return new
