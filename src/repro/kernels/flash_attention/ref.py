"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,S,hd); k,v: (B,KV,S,hd).  Materialised softmax attention."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * hd ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= qpos >= kpos
    if window:
        ok &= (qpos - kpos) < window
    logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(b, h, s, hd).astype(q.dtype)
