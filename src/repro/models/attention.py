"""GQA attention: full-sequence (train/prefill), blocked-causal for long
sequences, sliding-window, and single-token decode against a KV cache.

Grouped-query attention is computed *without* materialising repeated KV
heads: queries are reshaped to (B, S, kv, group, hd) and contracted against
(B, S, kv, hd) keys directly — less HBM traffic and exact FLOP accounting.

For causal sequences longer than ``BLOCK_Q`` the query axis is processed in
an unrolled block loop; block i only reads keys ``[lo, hi)`` allowed by the
causal/window structure, so the lowered HLO contains only useful FLOPs
(roughly the S^2/2 triangle rather than the full square).  This is the
pure-jnp analogue of the ``kernels.flash_attention`` Pallas kernel, which is
selected on TPU via ``cfg.use_pallas_kernels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense_init, matmul,
                                 matmul_rp)

NEG_INF = -1e30
BLOCK_Q = 1024  # blocked-causal query block (q-chunks of the lowered loop)


def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.hd()
    kq, kk, kv, ko = jax.random.split(key, 4)
    dtype = cfg.param_dtype()
    return {
        "wq": dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, d), dtype),
    }


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def sdpa(q, k, v, mask=None, causal=False, window: int = 0,
         q_offset: int = 0):
    """Grouped scaled-dot-product attention.

    q: (B,Sq,H,hd);  k,v: (B,Sk,KV,hd) with KV | H;  mask broadcastable to
    (B,KV,G,Sq,Sk).  ``q_offset``: absolute position of query 0 minus
    absolute position of key 0 (used by the blocked loop and decode).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[2]
    g = h // skv
    sk = k.shape[1]
    scale = hd ** -0.5
    qg = q.reshape(b, sq, skv, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal or window:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        ok = jnp.ones((sq, sk), bool)
        if causal:
            ok &= qpos >= kpos
        if window:
            ok &= (qpos - kpos) < window
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, sq, h, hd)


def sdpa_blocked(q, k, v, window: int = 0, block_q: int = BLOCK_Q):
    """Causal attention via an unrolled query-block loop.

    Each block only contracts against the keys its causal/window footprint
    allows, bounding live memory to (B,KV,G,block_q,hi) and keeping the
    lowered FLOPs ~S^2/2.
    """
    b, sq, h, hd = q.shape
    outs = []
    for i in range(0, sq, block_q):
        hi = min(i + block_q, sq)
        lo = max(0, i - window + 1) if window else 0
        qi = q[:, i:hi]
        ki, vi = k[:, lo:hi], v[:, lo:hi]
        outs.append(sdpa(qi, ki, vi, causal=True, window=window,
                         q_offset=i - lo))
    return jnp.concatenate(outs, axis=1)


def sdpa_blocked_scan(q, k, v, window: int = 0, block_q: int = BLOCK_Q):
    """Deploy-mode blocked attention: lax.scan over uniform query blocks.

    Blocks attend the full key range with dynamic causal masking (uniform
    shapes for the loop); buffer reuse across iterations bounds live memory
    to one block.  FLOP accounting uses the unrolled twin above.
    """
    b, sq, h, hd = q.shape
    # cap the live logits tile: bq x Sk <= 4M elements per (b, head)
    block_q = max(128, min(block_q, (1 << 22) // sq))
    nb = sq // block_q
    qb = jnp.moveaxis(q.reshape(b, nb, block_q, h, hd), 1, 0)

    @jax.checkpoint
    def body(_, inp):
        i, qi = inp
        off = i * block_q
        skv = k.shape[2]
        qg = qi.reshape(b, block_q, skv, h // skv, hd)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32) * hd ** -0.5
        qpos = jnp.arange(block_q)[:, None] + off
        kpos = jnp.arange(sq)[None, :]
        ok = qpos >= kpos
        if window:
            ok &= (qpos - kpos) < window
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v,
                         preferred_element_type=jnp.float32).astype(q.dtype)
        return None, out.reshape(b, block_q, h, hd)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def attention(params, x, cfg, positions, *, causal=True, window=0,
              kv_x=None, use_rope=True):
    """Full attention over a sequence (training / prefill).

    kv_x: optional separate kv source (cross-attention).
    Returns (out, (k, v)) so prefill can build the cache.
    """
    hd = cfg.hd()
    q = _split_heads(matmul(x, params["wq"]), cfg.n_heads, hd)
    src = kv_x if kv_x is not None else x
    k = _split_heads(matmul(src, params["wk"]), cfg.n_kv_heads, hd)
    v = _split_heads(matmul(src, params["wv"]), cfg.n_kv_heads, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas_kernels and causal and kv_x is None:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    elif causal and kv_x is None and q.shape[1] > BLOCK_Q:
        blocked = sdpa_blocked_scan if cfg.deploy else sdpa_blocked
        out = blocked(q, k, v, window=window)
    else:
        out = sdpa(q, k, v, causal=causal and kv_x is None, window=window)
    out = out.reshape(*x.shape[:-1], cfg.n_heads * hd)
    return matmul_rp(out, params["wo"], cfg), (k, v)


def init_kv_cache(cfg, batch, max_len, dtype, window: int = 0):
    """Ring-buffer KV cache. With ``window`` the buffer is window-sized."""
    size = min(max_len, window) if window else max_len
    hd = cfg.hd()
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
    }


def decode_attention(params, x, cache, cfg, positions, *, window=0,
                     kv_x=None, use_rope=True):
    """One-token decode step: append to cache, attend over it.

    x: (B,1,d); positions: (B,1) absolute position of the new token.
    Returns (out, new_cache).
    """
    hd = cfg.hd()
    q = _split_heads(matmul(x, params["wq"]), cfg.n_heads, hd)
    if kv_x is not None:
        # Cross-attention: cache holds the (static) encoder/image K/V.
        out = sdpa(q, cache["k"], cache["v"])
        out = out.reshape(*x.shape[:-1], cfg.n_heads * hd)
        return matmul_rp(out, params["wo"], cfg), cache
    k_new = _split_heads(matmul(x, params["wk"]), cfg.n_kv_heads, hd)
    v_new = _split_heads(matmul(x, params["wv"]), cfg.n_kv_heads, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    size = cache["k"].shape[1]
    slot = (positions[:, 0] % size) if window else positions[:, 0]
    bidx = jnp.arange(x.shape[0])
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    # Valid-position mask: ring buffer slot j holds a token iff it has been
    # written and (windowed) is within ``window`` of the current position.
    pos = positions[:, 0][:, None]                      # (B,1)
    j = jnp.arange(size)[None, :]                       # (1,size)
    if window:
        # slot j holds absolute position: the largest p<=pos with p%size==j
        age = (pos - j) % size                          # 0..size-1
        abs_pos = pos - age
        valid = (abs_pos >= 0) & (pos - abs_pos < window)
    else:
        valid = j <= pos
    mask = valid[:, None, None, None, :]                # (B,KV,G,1,size)
    out = sdpa(q, k, v, mask=mask)
    out = out.reshape(*x.shape[:-1], cfg.n_heads * hd)
    return matmul_rp(out, params["wo"], cfg), {"k": k, "v": v}
