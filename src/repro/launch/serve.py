"""Serving launcher: batched prefill + decode on a reduced config.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
        --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.models import transformer as tf
from repro.runtime.serve_loop import Request, ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(key)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.batch)]
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)),
            cfg.param_dtype())
    if cfg.family == "vlm":
        extras["img"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_img_tokens, cfg.d_model)),
            cfg.param_dtype())

    loop = ServeLoop(cfg, params, max_len=args.max_len)
    t0 = time.time()
    done = loop.run(reqs, extras=extras)
    dt = time.time() - t0
    print(json.dumps({
        "requests": len(done),
        "prefill_tokens": loop.stats.prefill_tokens,
        "decoded_tokens": loop.stats.decoded_tokens,
        "wall_s": round(dt, 2),
        "decode_tok_per_s": round(loop.stats.decoded_tokens / dt, 1),
        "sample_output": done[0].out[:8]}, indent=1))


if __name__ == "__main__":
    main()
