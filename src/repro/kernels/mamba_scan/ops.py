"""jit'd wrapper matching the model's SSD call signature."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, *, chunk: int = 64, interpret: bool | None = None):
    """Model layout: x (B,L,H,P), dt (B,L,H), a (H,), b/c (B,L,N).

    Returns y (B,L,H,P), final state (B,H,P,N) — same as
    ``models.ssm.ssd_chunked``."""
    if interpret is None:
        interpret = _interpret_default()
    xk = jnp.moveaxis(x, 2, 1)                       # (B,H,L,P)
    dtk = jnp.moveaxis(dt, 2, 1)[..., None]          # (B,H,L,1)
    ak = a[:, None, None]                            # (H,1,1)
    y, s_fin = _k.ssd_scan(xk, dtk, ak.astype(jnp.float32), b, c,
                           chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y, 1, 2), s_fin
