"""Multi-tenant fabric end-to-end: a training gang and a serving gang
share one device pool, interleave step-by-step, and a high-priority
arrival preempts the trainer — which checkpoints, waits, and resumes
bit-exactly (paper §2.1/§3.4 + the rFaaS-style lease reclamation).

Run:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/multi_tenant_fabric.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import reduced_config
from repro.core.fabric import Fabric
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.gang_workloads import ServeWorkload, TrainWorkload


def main():
    cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
    dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8, seed=0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    fabric = Fabric(chips_per_host=2)
    print(f"fabric: {len(fabric.devices)} chips on "
          f"{fabric.engine.hosts} hosts")

    # tenant 1: a training gang on 6 chips; tenant 2: a serving gang on 2
    train = fabric.allocate("train0", 6, priority=0)
    serve = fabric.allocate("serve0", 2, priority=1)
    twl = TrainWorkload(cfg, ocfg, dcfg, total_steps=6)
    twl.bind(train); twl.init_state(train)
    swl = ServeWorkload(cfg, prompt_len=8, new_tokens=4, batch=2,
                        max_len=16)
    swl.bind(serve); swl.init_state(serve)

    # interleave both tenants; after 3 train steps a high-priority gang
    # arrives and does not fit -> the engine plans a preemption
    for step in range(3):
        twl.run_step(train)
        swl.run_step(serve)
    victims = fabric.preemption_plan(6, priority=5)
    print("high-priority arrival (6 chips): evict", victims)
    snap = train.preempt(twl.state, twl.steps_done)
    print(f"  checkpointed train0 at step {snap.step} "
          f"({snap.nbytes/1e6:.1f} MB, fp {snap.fingerprint})")

    hi = fabric.allocate("hi0", 6, priority=5)
    hwl = TrainWorkload(cfg, ocfg, dcfg, total_steps=2)
    hwl.bind(hi); hwl.init_state(hi)
    while not (hwl.done and swl.done):
        if not hwl.done:
            hwl.run_step(hi)
        if not swl.done:
            swl.run_step(serve)
    hi.release()
    print("  high-priority gang done:", [round(l, 4) for l in hwl.losses])

    state, step = train.resume()       # fingerprint-verified restore
    twl.state = state
    twl.bind(train)
    while not twl.done:
        twl.run_step(train)
    print(f"train0 resumed at step {step}, losses:",
          [round(l, 4) for l in twl.losses])
    print("serve0 outputs:", [r.out for r in swl.requests])

    train.release(); serve.release()
    assert fabric.idle_chips() == fabric.engine.total_chips
    # reference: the same 6 steps uninterrupted match bit-for-bit
    ref_h = fabric.allocate("ref", 6)
    ref = TrainWorkload(cfg, ocfg, dcfg, total_steps=6)
    ref.bind(ref_h); ref.init_state(ref_h)
    while not ref.done:
        ref.run_step(ref_h)
    ref_h.release()
    np.testing.assert_allclose(ref.losses, twl.losses, atol=1e-6)
    print("preempted-and-resumed losses match uninterrupted run ✓")


if __name__ == "__main__":
    main()
