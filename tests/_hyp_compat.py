"""Property tests with an example-based fallback.

When ``hypothesis`` is installed, ``hyp_or_examples`` wraps a test in the
usual ``@settings(...) @given(...)`` pair.  On minimal environments
(no hypothesis), the same test body runs as a plain
``pytest.mark.parametrize`` over a hand-picked example set — the suite
still collects and the invariants still get exercised, just without the
random search.
"""
import inspect

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    given = settings = st = None
    HAVE_HYPOTHESIS = False


def hyp_or_examples(build_strategies, examples, max_examples=40):
    """Decorator: ``build_strategies(st)`` must return the positional
    strategy tuple for ``@given``; ``examples`` is the fallback list of
    argument tuples (or bare values for single-argument tests)."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(*build_strategies(st))(fn))
        argnames = [p for p in inspect.signature(fn).parameters]
        return pytest.mark.parametrize(",".join(argnames), examples)(fn)
    return deco
