"""Pure-jnp oracle for the chunk-select codec kernel.

Kept in operation-for-operation lockstep with ``kernel._select_kernel``
(same first-argmax-via-min-lane formulation) so kernel and reference —
and therefore the shard_map collective body, which uses this form
inline — agree bit-for-bit, ties included."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunk_select_ref(x):
    """x: (k, m) -> (vals (k, 1), col (k, 1) int32, resid (k, m))."""
    k, m = x.shape
    mag = jnp.abs(x)
    lane = jax.lax.broadcasted_iota(jnp.int32, (k, m), 1)
    rowmax = jnp.max(mag, axis=1, keepdims=True)
    col = jnp.min(jnp.where(mag == rowmax, lane, m), axis=1,
                  keepdims=True)
    picked = lane == col
    vals = jnp.sum(jnp.where(picked, x, 0), axis=1,
                   keepdims=True).astype(x.dtype)
    resid = jnp.where(picked, jnp.zeros_like(x), x)
    return vals, col.astype(jnp.int32), resid
