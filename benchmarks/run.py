"""Benchmark driver: one module per paper table/figure.

Prints ``bench,name,value,unit,paper_ref`` CSV lines; ``--only`` selects
one benchmark; results also land in results/bench.csv.
"""
from __future__ import annotations

import argparse
import csv
import importlib
import os
import sys
import time

BENCHES = [
    "bench_makespan",         # Fig 10
    "bench_scaling",          # Fig 11
    "bench_shared_memory",    # Fig 12
    "bench_message_passing",  # Fig 13 / Fig 9
    "bench_migration",        # Fig 14
]

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "bench.csv")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES)
    args = ap.parse_args()
    rows = []
    current = ""

    def report(name, value, unit="", note=""):
        rows.append((current, name, value, unit, note))
        print(f"{current},{name},{value},{unit},{note}")

    print("bench,name,value,unit,paper_ref")
    for mod_name in ([args.only] if args.only else BENCHES):
        current = mod_name
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.time()
        mod.run(report)
        rows.append((mod_name, "bench_wall", round(time.time() - t0, 1),
                     "s", ""))
    os.makedirs(os.path.dirname(os.path.abspath(OUT)), exist_ok=True)
    with open(OUT, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["bench", "name", "value", "unit", "paper_ref"])
        w.writerows(rows)
    print(f"# wrote {len(rows)} rows to {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
