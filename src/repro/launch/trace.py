"""Trace launcher: execute a multi-tenant arrival trace for real.

Replays an arrival-time trace — Poisson arrivals, priority classes,
preemption — through ``core.fabric.Fabric.run_trace``: real concurrent
train/serve gangs share the CPU host fabric, scheduled by the same
event loop and placement engine the discrete-event simulator uses, and
the live per-job completion order is compared against the simulator's
prediction for the same trace and policy.

Example:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.trace --jobs 6 \
        --arrival-rate 0.05 --chips-per-host 2 --seed 0
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import reduced_config
from repro.core import simulator as sim
from repro.core.fabric import Fabric
from repro.core.placement import derive_capacities
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.gang_workloads import workload_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chips-per-host", type=int, default=2)
    ap.add_argument("--policy", default="binpack",
                    choices=["binpack", "spread", "locality"])
    ap.add_argument("--arrival-rate", type=float, default=0.05)
    ap.add_argument("--no-preempt", action="store_true")
    ap.add_argument("--train-steps", type=int, default=3)
    ap.add_argument("--serve-tokens", type=int, default=3)
    ap.add_argument("--host-regime", default="uniform",
                    choices=["uniform", "mixed-gen"],
                    help="mixed-gen models half the hosts as an older "
                         "generation at s=0.5 (CostModel speeds)")
    ap.add_argument("--sched", default="central",
                    choices=["central", "sharded"],
                    help="scheduler architecture: one engine scanning "
                         "every host, or host-group shards with summary-"
                         "index forwarding (the Fig 11 fix)")
    ap.add_argument("--shard-hosts", type=int, default=None,
                    help="hosts per shard for --sched sharded "
                         "(default: placement.DEFAULT_SHARD_HOSTS)")
    args = ap.parse_args()

    speeds = None
    if args.host_regime == "mixed-gen":
        n_hosts = len(derive_capacities(len(jax.devices()),
                                        args.chips_per_host))
        speeds = sim.hetero_speeds(n_hosts)
    shard_hosts = None
    if args.sched == "sharded":
        from repro.core.placement import DEFAULT_SHARD_HOSTS
        shard_hosts = args.shard_hosts or DEFAULT_SHARD_HOSTS
    fabric = Fabric(chips_per_host=args.chips_per_host,
                    policy=args.policy, speeds=speeds,
                    shard_hosts=shard_hosts)
    n_chips = fabric.engine.total_chips
    # mixed train/serve trace sized to the local fabric, two priority
    # classes (9:1 high) — the §2.1 shared-cluster economics, live
    jobs = sim.mixed_trace(args.jobs, seed=args.seed,
                           chips_per_host=args.chips_per_host,
                           arrival_rate=args.arrival_rate,
                           priority_classes=[(0, 0.9), (5, 0.1)])
    for job in jobs:
        job.parallelism = max(2, min(job.parallelism, n_chips))

    cfg = reduced_config(args.arch).with_(n_layers=1, vocab=128)
    dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8,
                      seed=args.seed)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)

    preempt = not args.no_preempt
    predicted = fabric.predict_trace(jobs, preempt=preempt)
    ex = fabric.run_trace(
        jobs, workload_factory(cfg, ocfg, dcfg,
                               train_steps=args.train_steps,
                               serve_tokens=args.serve_tokens),
        preempt=preempt)
    live = ex.result
    print(json.dumps({
        "devices": len(jax.devices()),
        "hosts": fabric.engine.hosts,
        "sched": args.sched,
        "shard_hosts": (None if shard_hosts is None
                        else fabric.engine.hosts_per_shard),
        "host_speeds": (None if fabric.engine.speeds is None
                        else list(fabric.engine.speeds)),
        "jobs": len(jobs),
        "predicted_order": predicted.finish_order,
        "live_order": live.finish_order,
        "order_matches": live.finish_order == predicted.finish_order,
        "preemptions": live.preemptions,
        "virtual_makespan_s": round(live.makespan, 2),
        "per_job_makespan_s": {k: round(v, 2)
                               for k, v in ex.job_makespans(jobs).items()},
        "live_steps": {k: rec.get("steps", 0)
                       for k, rec in ex.live.items()},
        "resumes_verified": sum(r.get("resumes_verified", 0)
                                for r in ex.live.values()),
        "wall_s": round(ex.wall_s, 1)}, indent=1))


if __name__ == "__main__":
    main()
