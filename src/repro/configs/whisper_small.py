"""whisper-small: 12L enc + 12L dec, d768 12H (kv=12) d_ff=3072 vocab=51865.

Enc-dec; conv frontend is a STUB -- input_specs() provides precomputed
frame embeddings (B, 1500, d_model).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    rope_theta=10_000.0,
)
