"""AdamW over parameter pytrees, with cosine LR schedule and global-norm
clipping.  Optimizer state (m, v) is kept in f32 regardless of param dtype;
the update is applied in f32 and cast back.

Pure-pytree (no optax dependency): ``init(params) -> state``;
``apply(grads, state, params, step) -> (new_params, new_state)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def apply(grads, state, params, cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = cosine_lr(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step_ = step_ + cfg.weight_decay * p32
        return (p32 - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
