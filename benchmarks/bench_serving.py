"""Serving under open-loop load: continuous batching vs the fixed-batch
baseline, and the SLO-driven autoscaler under burst traffic.

Three measurements, all on deterministic virtual clocks (gates are
valid at smoke tier too — no wall-clock noise):

* **continuous vs fixed** — the same open-loop request stream replayed
  through a real ``ContinuousServeLoop`` and the old fixed-batch
  ``ServeLoop`` on a tiny model, at several offered loads.  The
  continuous engine admits into freed slots mid-generation instead of
  draining every batch to its slowest member, so its tokens/virtual-s
  strictly dominates at every load point (the ``throughput_ratio``
  gate) and its tail latency collapses.

* **burst autoscaler** — ``ServeFleetSim``: flash-crowd arrivals
  (``burst`` regime) against serve gangs the ``ServeAutoscaler``
  grows/shrinks through a real ``PlacementEngine``.  Acceptance: p99
  per-token latency stays under the SLO target while the fleet breathes
  (grow and shrink actions both fire).

* **train+serve drain-not-die** — the combined trace: an elastic
  training tenant owns most of the fleet; serve bursts reclaim chips
  via *drain* (shrink at a control point, zero lost work) vs *preempt*
  (rollback to last checkpoint).  Serve SLOs hold identically in both
  modes; the difference is exactly the training work a kill would have
  burned, and training backfills the chips when the burst passes.
"""
from __future__ import annotations

HOSTS = 4
CHIPS = 8
# fleet config stamped into results/BENCH_bench_serving.json by run.py
FLEET = {"hosts": HOSTS, "chips_per_host": CHIPS, "policy": "binpack",
         "engine_arch": "llama3.2-1b (n_layers=1, vocab=128)",
         "arrival_regimes": ["poisson", "burst"]}


def _tiny_cfg():
    from repro.configs.registry import reduced_config
    return reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)


def _stream(n, rate, seed, regime="poisson", ragged=False):
    from repro.runtime.admission import request_stream
    # equal-length prompts keep the fixed baseline admissible; decode
    # budgets stay ragged — that is where drain-to-slowest loses
    return request_stream(n, rate, seed, regime=regime, vocab=128,
                          prompt_lens=(4, 12) if ragged else (8, 8),
                          max_new=(3, 10))


def _engines_head_to_head(report, tiny):
    import jax

    from repro.models import transformer as tf
    from repro.runtime.admission import run_fixed_batch, run_open_loop
    from repro.runtime.serve_loop import ContinuousServeLoop, ServeLoop

    cfg = _tiny_cfg()
    params = jax.jit(lambda k: tf.init_params(k, cfg))(
        jax.random.PRNGKey(0))
    n = 10 if tiny else 24
    slots = 4
    step_s = 0.05
    loads = (1.0, 4.0) if tiny else (0.5, 2.0, 8.0)
    ratios = []
    for load in loads:
        cont = ContinuousServeLoop(cfg, params, slots=slots, max_len=32)
        rc = run_open_loop(cont, _stream(n, load, seed=3), step_s=step_s)
        fixed = ServeLoop(cfg, params, max_len=32)
        rf = run_fixed_batch(fixed, _stream(n, load, seed=3), slots,
                             step_s=step_s)
        assert rc.finished == n and rf.finished == n
        ratio = rc.tokens_per_s / max(rf.tokens_per_s, 1e-12)
        ratios.append(ratio)
        report(f"continuous_vs_fixed/load_{load}/tokens_per_s_continuous",
               round(rc.tokens_per_s, 3), "tok/virtual-s",
               f"{n} reqs, {slots} slots, admit-on-free-slot")
        report(f"continuous_vs_fixed/load_{load}/tokens_per_s_fixed",
               round(rf.tokens_per_s, 3), "tok/virtual-s",
               f"batch={slots}, drain-to-slowest")
        report(f"continuous_vs_fixed/load_{load}/throughput_ratio",
               round(ratio, 3), "x",
               "acceptance: > 1 (continuous strictly dominates)")
        report(f"continuous_vs_fixed/load_{load}/p99_ms_continuous",
               round(rc.token_lat_p99 * 1e3, 2), "ms/token", "")
        report(f"continuous_vs_fixed/load_{load}/p99_ms_fixed",
               round(rf.token_lat_p99 * 1e3, 2), "ms/token", "")
        report(f"continuous_vs_fixed/load_{load}/ttft_p99_ms_continuous",
               round(rc.ttft_p99 * 1e3, 2), "ms", "")
        report(f"continuous_vs_fixed/load_{load}/ttft_p99_ms_fixed",
               round(rf.ttft_p99 * 1e3, 2), "ms",
               "queue wait for a full batch + prior drain")
    report("continuous_vs_fixed/min_throughput_ratio",
           round(min(ratios), 3), "x",
           f"worst case over loads {list(loads)}; gate: > 1.0")


def _burst_autoscaler(report, tiny):
    from repro.runtime.admission import ServeSLO
    from repro.runtime.serve_fleet import ServeFleetSim

    n = 150 if tiny else 400
    rate = 6.0
    slo = ServeSLO(target_p99_s=0.6)
    sim = ServeFleetSim(hosts=HOSTS, chips_per_host=CHIPS, slo=slo,
                        base_world=2, min_world=1, max_world=16,
                        cooldown_s=0.5, control_interval_s=0.5)
    rep = sim.run(_stream(n, rate, seed=7, regime="burst", ragged=True))
    assert rep.finished == n, "requests stranded"
    p99_ms = rep.token_lat_p99 * 1e3
    report("burst_autoscaler/p99_ms", round(p99_ms, 2), "ms/token",
           f"target {slo.target_p99_s * 1e3} ms under 4x flash crowds")
    report("burst_autoscaler/p50_ms", round(rep.token_lat_p50 * 1e3, 2),
           "ms/token", "")
    report("burst_autoscaler/p99_within_target",
           int(p99_ms <= slo.target_p99_s * 1e3), "bool",
           "acceptance: SLO held while the fleet breathes")
    report("burst_autoscaler/slo_attainment",
           round(rep.slo_attainment, 4), "frac",
           "per-request token latency <= target")
    report("burst_autoscaler/peak_world", rep.peak_world, "chips",
           "grown into the burst")
    report("burst_autoscaler/min_world", rep.min_world, "chips",
           "shrunk back between bursts")
    report("burst_autoscaler/grew", rep.grew, "actions", "")
    report("burst_autoscaler/shrank", rep.shrank, "actions",
           "acceptance: both directions fire (elastic, not one-way)")
    report("burst_autoscaler/tokens_per_s", round(rep.tokens_per_s, 2),
           "tok/virtual-s", "")


def _train_serve_contention(report, tiny):
    from repro.runtime.admission import ServeSLO
    from repro.runtime.serve_fleet import (ServeFleetSim,
                                           VirtualTrainTenant)

    n = 150 if tiny else 400
    rate = 6.0
    slo = ServeSLO(target_p99_s=0.6)
    out = {}
    for mode in ("drain", "preempt"):
        sim = ServeFleetSim(hosts=HOSTS, chips_per_host=CHIPS, slo=slo,
                            base_world=2, min_world=1, max_world=16,
                            cooldown_s=0.5, control_interval_s=0.5)
        train = VirtualTrainTenant("train-0", sim.engine,
                                   world=HOSTS * CHIPS - 4,
                                   min_world=4, ckpt_interval_s=8.0)
        out[mode] = sim.run(
            _stream(n, rate, seed=7, regime="burst", ragged=True),
            train=train, train_mode=mode)
        assert out[mode].finished == n, "requests stranded"
    for mode, rep in out.items():
        p99_ms = rep.token_lat_p99 * 1e3
        report(f"train_serve/{mode}/serve_p99_ms", round(p99_ms, 2),
               "ms/token", "serve SLO must hold in both modes")
        report(f"train_serve/{mode}/slo_attainment",
               round(rep.slo_attainment, 4), "frac", "")
        report(f"train_serve/{mode}/train_progress",
               round(rep.train_progress, 1), "chip-s",
               "effective training work kept")
        report(f"train_serve/{mode}/train_lost_work_s",
               round(rep.train_lost_work, 2), "chip-s",
               "rolled back at reclaims (drain: 0 by construction)")
        report(f"train_serve/{mode}/train_min_world",
               rep.train_min_world, "chips",
               "deepest reclaim trough (chips lent to serve)")
        report(f"train_serve/{mode}/train_backfilled",
               round(rep.train_backfilled, 1), "chips",
               "grown back after the burst passed")
    drain, pre = out["drain"], out["preempt"]
    saves = pre.train_lost_work - drain.train_lost_work
    report("train_serve/drain_saves_work_s", round(saves, 2), "chip-s",
           "acceptance: > 0 — the near-checkpoint victim drains, "
           "not dies")
    report("train_serve/p99_within_target",
           int(drain.token_lat_p99 <= slo.target_p99_s
               and pre.token_lat_p99 <= slo.target_p99_s), "bool",
           "acceptance: serve SLO held while training backfills")


def run(report, tiny=False):
    _engines_head_to_head(report, tiny)
    _burst_autoscaler(report, tiny)
    _train_serve_contention(report, tiny)
