"""Fleet churn: makespan inflation vs reclaim rate, drain deadlines,
and the checkpoint-cadence sweep (core.fleet).

Three measurements on a saturated (queue-dominated) mixed arrival
trace, each averaged over three churn-schedule seeds:

* **makespan inflation vs reclaim rate** — the spot-heavy regime
  (Poisson lease reclaims with like-for-like rejoins) at increasing
  disruption rates, central vs sharded: how much a churning fleet costs
  against the churn-free baseline, and whether the decentralised
  engine's shard-local decisions absorb churn better.

* **drain-deadline length** — the same reclaim wave with 0..30 s drain
  windows: a longer warning converts checkpoint-rollback *recoveries*
  (lost work) into graceful *evacuations* (a migration charge).

* **checkpoint-interval sweep** (Young/Daly) — under a hard-failure
  wave (no drain warning), sweep the periodic checkpoint cadence:
  checkpoint too often and the ``CostModel.checkpoint_cost_s`` overhead
  inflates every gang; too rarely and each failure rolls a gang far
  back (``TraceResult.lost_work_s`` grows monotonically with the
  interval).  The makespan optimum is interior, and
  ``fleet.optimal_checkpoint_interval`` (tau* = sqrt(2·delta·MTBF), fed
  by ``churn_mtbf``) lands near it.

* **risk-aware vs risk-blind** — every churn regime run twice at the
  same Young/Daly cadence: once with the stock placement (stranded
  gangs roll back to their checkpoint), once with
  ``CostModel.risk_tau_s`` pricing expected lost work into every
  placement decision and shrink-before-rollback refitting stranded
  gangs into surviving capacity (DESIGN.md §13).  The aware arm must
  lose no more work and inflate the makespan no more in each regime,
  and the correlated-rack case must recover at least one stranded gang
  by shrinking instead of rolling back.
"""
from __future__ import annotations

import numpy as np

from repro.core import fleet as F
from repro.core import simulator as S

SHARD_HOSTS = 16
SEEDS = (11, 19, 31)
# the risk-aware section averages over more churn schedules: a single
# rack failure's effect on a queue-dominated tail is high-variance, so
# three seeds are not enough to separate the arms
RISK_SEEDS = SEEDS + (43, 53)
# fleet config stamped into results/BENCH_bench_churn.json by run.py
FLEET = {"hosts": 32, "chips_per_host": 8,
         "sched": ["central", "sharded"], "shard_hosts": SHARD_HOSTS,
         "policy": "binpack", "regimes": list(F.CHURN_REGIMES),
         "schedule_seeds": list(SEEDS),
         "risk_schedule_seeds": list(RISK_SEEDS)}


def _sim(hosts, sched="central", ckpt=None, cost_model=None,
         shrink=False):
    return S.Simulator(hosts, 8, "granular", migrate=True,
                       policy="binpack", sched=sched,
                       shard_hosts=SHARD_HOSTS,
                       cost_model=cost_model,
                       checkpoint_interval=ckpt,
                       shrink_recovery=shrink)


def _fail_schedule(hosts, horizon, seed, rate, cph=8, rejoin=4.0):
    """Hard-failure wave: Poisson host failures (no drain warning) over
    the upper half of the fleet, each replaced by a like-for-like join
    a lease-turnaround later — the regime the checkpoint-cadence sweep
    needs (reclaims would evacuate gracefully and lose nothing)."""
    rng = np.random.default_rng([seed, 41])
    removable = list(range((hosts + 1) // 2, hosts))
    rng.shuffle(removable)
    events, t = [], 0.0
    while removable:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            break
        k = min(len(removable), int(rng.integers(1, 3)))
        hs = sorted(removable.pop() for _ in range(k))
        events.append(F.FleetEvent(t, "fail", hosts=hs))
        events.append(F.FleetEvent(t + rejoin, "join",
                                   capacities=[cph] * k))
    return events


def _mean_over_seeds(sim_fn, jobs, make_events):
    mks, recs, evs, losts, ovhs = [], [], [], [], []
    for seed in SEEDS:
        sim = sim_fn()
        r = sim.run(list(jobs), fleet_events=make_events(seed))
        assert len(r.finish_order) == len(jobs), "jobs stranded"
        mks.append(r.makespan)
        recs.append(r.recoveries)
        evs.append(r.evacuations)
        losts.append(r.lost_work_s)
        # the quantity Young/Daly minimises: gang-seconds paused for
        # checkpoint saves + gang-seconds rolled back at failures
        n_ckpt = sum(1 for a in r.actions if a.kind == "checkpoint")
        ovhs.append(n_ckpt * sim.model.checkpoint_cost_s
                    + r.lost_work_s)
    return (float(np.mean(mks)), float(np.mean(recs)),
            float(np.mean(evs)), float(np.mean(losts)),
            float(np.mean(ovhs)))


def run(report, tiny=False):
    hosts = 12 if tiny else 32
    njobs = 40 if tiny else 160
    # queue-dominated: arrivals outpace service, so reclaimed capacity
    # and rolled-back work genuinely extend the critical path
    jobs = S.mixed_trace(njobs, seed=5, chips_per_host=8,
                         arrival_rate=njobs / 150.0)
    base = {sched: _sim(hosts, sched).run(list(jobs))
            for sched in ("central", "sharded")}
    horizon = base["central"].makespan
    for sched in ("central", "sharded"):
        report(f"baseline/makespan_{sched}",
               round(base[sched].makespan, 1), "s", "churn-free")

    # ---- makespan inflation vs reclaim rate (spot-heavy) ----
    rates = (0.01, 0.04) if tiny else (0.005, 0.01, 0.02, 0.04)
    for rate in rates:
        for sched in ("central", "sharded"):
            mk, rec, ev, lost, _ = _mean_over_seeds(
                lambda: _sim(hosts, sched), jobs,
                lambda seed: F.churn_schedule(
                    "spot-heavy", hosts, 8, horizon, seed=seed,
                    rate=rate, drain_s=5.0))
            infl = (mk - base[sched].makespan) \
                / base[sched].makespan * 100.0
            report(f"reclaim_rate/{rate}/inflation_pct_{sched}",
                   round(infl, 2), "% makespan",
                   f"mean over {len(SEEDS)} schedules, 5s drains")
            if sched == "central":
                report(f"reclaim_rate/{rate}/recoveries", round(rec, 1),
                       "jobs", "requeued from checkpoint")
                report(f"reclaim_rate/{rate}/evacuations", round(ev, 1),
                       "gangs", "graceful drain moves")
                report(f"reclaim_rate/{rate}/lost_work_s",
                       round(lost, 1), "s", "work rolled back")

    # ---- drain-deadline length: recoveries -> evacuations ----
    drains = (0.0, 8.0) if tiny else (0.0, 2.0, 8.0, 30.0)
    rate = 0.02
    for drain_s in drains:
        mk, rec, ev, _, _ = _mean_over_seeds(
            lambda: _sim(hosts), jobs,
            lambda seed: F.churn_schedule(
                "spot-heavy", hosts, 8, horizon, seed=seed + 2,
                rate=rate, drain_s=drain_s))
        infl = (mk - base["central"].makespan) \
            / base["central"].makespan * 100.0
        report(f"drain_s/{drain_s}/inflation_pct", round(infl, 2),
               "% makespan", f"reclaim rate {rate}/s")
        report(f"drain_s/{drain_s}/evacuations", round(ev, 1),
               "gangs", "graceful moves (longer drains -> more)")
        report(f"drain_s/{drain_s}/recoveries", round(rec, 1),
               "jobs", "hard rollbacks (longer drains -> fewer)")

    # ---- checkpoint-interval sweep (Young/Daly) ----
    fail_rate = 0.04
    taus = (4.0, 16.0, 64.0) if tiny else (2.0, 4.0, 8.0, 16.0, 32.0,
                                           64.0, 128.0)
    best_tau, best_ovh = None, float("inf")
    for tau in taus:
        mk, rec, _, lost, ovh = _mean_over_seeds(
            lambda: _sim(hosts, ckpt=tau), jobs,
            lambda seed: _fail_schedule(hosts, horizon, seed + 6,
                                        fail_rate))
        report(f"ckpt_interval/{tau}/makespan", round(mk, 1),
               "s", f"~{round(rec)} failures/run")
        report(f"ckpt_interval/{tau}/lost_work_s", round(lost, 1),
               "s", "rolled back at failures (monotone in tau)")
        report(f"ckpt_interval/{tau}/overhead_s", round(ovh, 1),
               "s", "checkpoint pauses + lost work (the Young/Daly "
                    "objective)")
        if ovh < best_ovh:
            best_tau, best_ovh = tau, ovh
    mk, _, _, lost, ovh = _mean_over_seeds(
        lambda: _sim(hosts), jobs,
        lambda seed: _fail_schedule(hosts, horizon, seed + 6,
                                    fail_rate))
    report("ckpt_interval/none/makespan", round(mk, 1), "s",
           "failures roll back to job start")
    report("ckpt_interval/none/overhead_s", round(ovh, 1), "s",
           "pure lost work: worse than every swept cadence")
    report("ckpt_interval/best_tau", best_tau, "s",
           "acceptance: interior optimum (edges of the sweep lose)")
    events = _fail_schedule(hosts, horizon, SEEDS[0] + 6, fail_rate)
    mtbf = F.churn_mtbf(events, horizon, hosts=hosts)
    tau_star = F.optimal_checkpoint_interval(mtbf,
                                             checkpoint_cost_s=0.5)
    report("ckpt_interval/young_daly_tau", round(tau_star, 1), "s",
           f"sqrt(2*delta*MTBF), MTBF={round(mtbf, 1)}s")

    # ---- delta vs full checkpoints (the delta data plane) ----
    # (a) measured bytes: a GangHandle ships a (base, delta*) chain for
    # a training-state-sized gang with ~1%-per-step clustered updates;
    # a hard failure replays the chain, fingerprint-verified per link
    from repro.core.fabric import GangHandle
    from repro.core.placement import CostModel
    from repro.core import snapshot as snap_mod

    class _StubFabric:  # chain bookkeeping only — no devices involved
        def host_of(self, d):
            return 0

        def reclaim(self, devs):
            pass

    rng = np.random.default_rng(7)
    n = (1 if tiny else 16) * 2 ** 20 // 4
    state = {"w": rng.normal(size=n).astype(np.float32),
             "step": np.int64(0)}
    h = GangHandle(_StubFabric(), "bench")
    h.status = "running"
    h.ckpt_rebase_every = 8
    for s in range(8):
        off = int(rng.integers(0, n - n // 100))
        state = {"w": np.array(state["w"], copy=True),
                 "step": np.int64(s)}
        state["w"][off:off + n // 100] += 0.01
        h.checkpoint(state, s)
    deltas = [st["bytes"] for st in h.ckpt_stats
              if st["kind"] == "delta"]
    full = h.ckpt_stats[0]["full_bytes"]
    frac = float(np.mean(deltas)) / full
    snap = h.fail([])  # consumes the chain: base + 7 replayed deltas
    exact = snap.fingerprint == snap_mod.take("bench", 7,
                                              state).fingerprint
    report("delta_ckpt/avg_delta_bytes", round(float(np.mean(deltas))
                                               / 2 ** 20, 3), "MiB",
           f"full snapshot = {round(full / 2**20, 1)} MiB")
    report("delta_ckpt/bytes_vs_full", round(frac, 4), "of full",
           "acceptance: <=0.2 (>=5x smaller)")
    report("delta_ckpt/recovery_bit_exact", int(exact), "bool",
           "hard-fail replay of base+deltas, per-link fingerprints")

    # (b) cadence: Young/Daly consumes the amortised delta cost, so the
    # optimal interval tightens by sqrt(cost ratio)
    cm_delta = CostModel(ckpt_delta_fraction=round(frac, 2) or 0.01,
                         ckpt_rebase_every=8)
    tau_delta = F.optimal_checkpoint_interval(mtbf, cost_model=cm_delta)
    report("delta_ckpt/young_daly_tau_full", round(tau_star, 1), "s",
           "full-cost checkpoints")
    report("delta_ckpt/young_daly_tau_delta", round(tau_delta, 1), "s",
           "amortised delta cost: tighter cadence, less lost work")

    # (c) makespan under spot-heavy churn with no drain warning (every
    # reclaim hard-fails), each model at its own Young/Daly cadence —
    # and the determinism check: a delta fraction of 1.0 must charge
    # exactly like the full-cost model, Action log included
    def hard_events(seed):
        return F.churn_schedule("spot-heavy", hosts, 8, horizon,
                                seed=seed + 9, rate=0.04, drain_s=0.0)

    mk_full, _, _, lost_full, _ = _mean_over_seeds(
        lambda: _sim(hosts, ckpt=tau_star), jobs, hard_events)
    mk_delta, _, _, lost_delta, _ = _mean_over_seeds(
        lambda: _sim(hosts, ckpt=tau_delta, cost_model=cm_delta),
        jobs, hard_events)
    report("delta_ckpt/makespan_full", round(mk_full, 1), "s",
           f"full-cost model at tau={round(tau_star, 1)}s, 0s drains")
    report("delta_ckpt/makespan_delta", round(mk_delta, 1), "s",
           f"delta model at tau={round(tau_delta, 1)}s, 0s drains")
    report("delta_ckpt/lost_work_full_s", round(lost_full, 1), "s", "")
    report("delta_ckpt/lost_work_delta_s", round(lost_delta, 1), "s",
           "tighter cadence rolls back less work per failure")
    ev0 = hard_events(SEEDS[0])
    r_full = _sim(hosts, ckpt=8.0).run(list(jobs), fleet_events=ev0)
    r_one = _sim(hosts, ckpt=8.0,
                 cost_model=CostModel(ckpt_delta_fraction=1.0)).run(
        list(jobs), fleet_events=ev0)
    report("delta_ckpt/actions_identical_at_fraction_1",
           int(r_one.actions == r_full.actions), "bool",
           "delta charging is deterministic: fraction=1.0 reproduces "
           "the full-cost Action log event for event")

    # ---- risk-aware placement + shrink-before-rollback ----
    # Two arms per churn regime at the same Young/Daly cadence: the
    # risk-blind stock placement (every stranded gang rolls back to its
    # checkpoint) vs CostModel.risk_tau_s pricing expected lost work
    # into placements plus shrink-before-rollback refitting stranded
    # gangs into surviving capacity.  Any saving is pure placement +
    # recovery — checkpoint charging is identical across the arms.
    tau_ck = tau_star

    def _risk_arm(cost_model, shrink, make_events):
        mks, recs, losts, shrs = [], [], [], []
        for seed in RISK_SEEDS:
            sim = _sim(hosts, ckpt=tau_ck, cost_model=cost_model,
                       shrink=shrink)
            r = sim.run(list(jobs), fleet_events=make_events(seed))
            assert len(r.finish_order) == len(jobs), "jobs stranded"
            mks.append(r.makespan)
            recs.append(r.recoveries)
            losts.append(r.lost_work_s)
            shrs.append(r.shrinks)
        return (float(np.mean(mks)), float(np.mean(recs)),
                float(np.mean(losts)), float(np.mean(shrs)))

    def _regime_events(regime):
        def make(seed):
            return F.churn_schedule(regime, hosts, 8, horizon,
                                    seed=seed + 13, rate=0.02,
                                    drain_s=5.0)
        return make

    for regime in F.CHURN_REGIMES:
        make = _regime_events(regime)
        mk_b, rec_b, lost_b, _ = _risk_arm(None, False, make)
        mk_a, rec_a, lost_a, shr_a = _risk_arm(
            CostModel(risk_tau_s=tau_ck), True, make)
        infl_b = (mk_b - base["central"].makespan) \
            / base["central"].makespan * 100.0
        infl_a = (mk_a - base["central"].makespan) \
            / base["central"].makespan * 100.0
        report(f"risk/{regime}/lost_work_blind_s", round(lost_b, 1),
               "s", "work rolled back, risk-blind placement")
        report(f"risk/{regime}/lost_work_aware_s", round(lost_a, 1),
               "s", "risk term + shrink-before-rollback")
        report(f"risk/{regime}/inflation_pct_blind", round(infl_b, 2),
               "% makespan", "vs the churn-free baseline")
        report(f"risk/{regime}/inflation_pct_aware", round(infl_a, 2),
               "% makespan", "vs the churn-free baseline")
        report(f"risk/{regime}/recoveries_blind", round(rec_b, 1),
               "jobs", "checkpoint rollbacks")
        report(f"risk/{regime}/recoveries_aware", round(rec_a, 1),
               "jobs", "rollbacks shrink could not avert")
        report(f"risk/{regime}/shrinks", round(shr_a, 1), "gangs",
               "stranded gangs refit into surviving capacity")
        report(f"risk/{regime}/improves",
               int(lost_a <= lost_b and mk_a <= mk_b), "bool",
               "acceptance: aware arm loses no more work and no more "
               "makespan than blind")
        if regime == "correlated-rack-failure":
            report("risk/correlated-rack-failure/shrink_recoveries",
                   round(shr_a, 1), "gangs",
                   "acceptance: >=1 gang stranded by the rack failure "
                   "recovers by shrinking, not rolling back")

    # determinism pins: the risk-aware path replays bit-identically,
    # and the default-off CostModel (risk_tau_s=None, no shrink) stays
    # action-for-action identical to the stock simulator
    make = _regime_events("correlated-rack-failure")
    ra = _sim(hosts, ckpt=tau_ck, cost_model=CostModel(
        risk_tau_s=tau_ck), shrink=True).run(
        list(jobs), fleet_events=make(SEEDS[0]))
    rb = _sim(hosts, ckpt=tau_ck, cost_model=CostModel(
        risk_tau_s=tau_ck), shrink=True).run(
        list(jobs), fleet_events=make(SEEDS[0]))
    report("risk/aware_identical_rerun",
           int(ra.actions == rb.actions), "bool",
           "risk-aware + shrink replays bit-identically")
    r_off = _sim(hosts, ckpt=tau_ck, cost_model=CostModel()).run(
        list(jobs), fleet_events=make(SEEDS[0]))
    r_stock = _sim(hosts, ckpt=tau_ck).run(
        list(jobs), fleet_events=make(SEEDS[0]))
    report("risk/off_bit_identical",
           int(r_off.actions == r_stock.actions), "bool",
           "risk term default-off reproduces the stock Action log")
