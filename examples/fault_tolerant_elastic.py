"""Fault tolerance + elasticity end-to-end: a training gang survives an
injected node failure (gang restart from snapshot, bit-exact) and then
shrinks from 8 to 4 Granules at a control point without perturbing the
loss trajectory (paper §3.3/§3.4, implemented).

Run:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/fault_tolerant_elastic.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import FaabricTrainRuntime, RuntimeConfig


def main():
    shutil.rmtree("/tmp/repro-fte", ignore_errors=True)
    cfg = reduced_config("granite-moe-1b-a400m")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    ocfg = AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=24)

    print(f"devices: {len(jax.devices())}")
    # reference run: no faults
    ref = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
        total_steps=24, checkpoint_every=6,
        ckpt_dir="/tmp/repro-fte/ref")).run(seed=0)[1]

    # chaos run: node failure at step 8, elastic shrink at step 16
    world = len(jax.devices())
    chaos_rt = RuntimeConfig(
        total_steps=24, checkpoint_every=6, ckpt_dir="/tmp/repro-fte/chaos",
        inject_failures={8: "simulated host loss"},
        rescale_at={16: max(world // 2, 1)})
    chaos = FaabricTrainRuntime(cfg, ocfg, dcfg, chaos_rt).run(seed=0)[1]

    print(f"recoveries={chaos['recoveries']} rescales={chaos['rescales']}")
    print(f"ref   losses: {[round(l, 3) for l in ref['losses'][:6]]} ...")
    print(f"chaos losses: {[round(l, 3) for l in chaos['losses'][:6]]} ...")
    # exact up to the rescale point (recovery is bit-exact) ...
    np.testing.assert_allclose(ref["losses"][:16], chaos["losses"][:16],
                               atol=1e-4)
    # ... and statistically unchanged after it: MoE capacity grouping is
    # per-Granule, so a different world size legitimately drops different
    # tokens (same effect as re-bucketing EP groups on a real resize).
    np.testing.assert_allclose(ref["losses"][16:], chaos["losses"][16:],
                               atol=0.25)
    print("OK: recovery bit-exact; rescale loss-invariant up to MoE "
          "capacity regrouping")


if __name__ == "__main__":
    main()
