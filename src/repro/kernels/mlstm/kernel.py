"""Chunkwise-parallel mLSTM kernel (Pallas): stabilised matrix-memory scan.

Same sequential-chunk-grid pattern as the Mamba2 kernel: grid
(batch, head, chunk) with the chunk dimension sequential; the per-head
matrix memory C (hd x hd), normaliser n (hd) and max-stabiliser m persist
in VMEM scratch.  Within a chunk the computation is the attention-like
stabilised parallel form (exactly ``models.xlstm.mlstm_chunk_body``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  cfin_ref, nfin_ref, mfin_ref,
                  c_scr, n_scr, m_scr, *, nc: int, scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[0, 0].astype(jnp.float32)          # (q, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    logi = li_ref[0, 0].astype(jnp.float32)      # (q, 1)
    logf = lf_ref[0, 0].astype(jnp.float32)      # (q, 1)
    qq = q.shape[0]

    m_in = m_scr[0, 0]
    cumf = jnp.cumsum(logf, axis=0)              # (q, 1)
    total = cumf[-1, 0]

    # intra decay matrix (stabilised)
    dt = cumf - cumf.T + logi.T                  # (i, j)
    ii = jax.lax.broadcasted_iota(jnp.int32, (qq, qq), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (qq, qq), 1)
    dt = jnp.where(ii >= jj, dt, NEG)
    m_intra = jnp.max(dt, axis=1, keepdims=True)          # (q, 1)
    b_inter = cumf + m_in                                 # (q, 1)
    m_comb = jnp.maximum(m_intra, b_inter)
    d = jnp.exp(dt - m_comb)
    inter_scale = jnp.exp(b_inter - m_comb)               # (q, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s * d
    num = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    num = num + inter_scale * jax.lax.dot_general(
        q, c_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    den = (jnp.sum(s, axis=1, keepdims=True)
           + inter_scale * jax.lax.dot_general(
               q, n_scr[...], (((1,), (1,)), ((), ())),
               preferred_element_type=jnp.float32) * scale)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_comb))
    h_ref[0, 0] = (num / den).astype(h_ref.dtype)

    # state update
    w = total - cumf + logi                      # (q, 1)
    m_out = jnp.maximum(m_in + total, jnp.max(w))
    wexp = jnp.exp(w - m_out)                    # (q, 1)
    carry = jnp.exp(m_in + total - m_out)
    c_scr[...] = carry * c_scr[...] + jax.lax.dot_general(
        v * wexp, k, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (hd_v, hd_k)
    n_scr[...] = carry * n_scr[...] + jax.lax.dot_general(
        wexp, k, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (1, hd_k)
    m_scr[0, 0] = m_out

    @pl.when(ci == nc - 1)
    def _finish():
        cfin_ref[0, 0] = c_scr[...]
        nfin_ref[0, 0] = n_scr[...]
        mfin_ref[0, 0] = m_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, logi, logf, *, chunk: int = 128,
               interpret: bool = False):
    """q,k,v: (B,H,L,hd); logi/logf: (B,H,L,1).

    Returns h (B,H,L,hd), (C (B,H,hd,hd), n (B,H,1,hd), m (B,H,1,1))."""
    bs, h, l, hd = q.shape
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk
    grid = (bs, h, nc)
    kernel = functools.partial(_mlstm_kernel, nc=nc, scale=hd ** -0.5)
    seq_spec = pl.BlockSpec((1, 1, chunk, hd),
                            lambda bb, hh, ci: (bb, hh, ci, 0))
    gate_spec = pl.BlockSpec((1, 1, chunk, 1),
                             lambda bb, hh, ci: (bb, hh, ci, 0))
    fin = lambda p_, q_: pl.BlockSpec((1, 1, p_, q_),
                                      lambda bb, hh, ci: (bb, hh, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=[seq_spec, fin(hd, hd), fin(1, hd), fin(1, 1)],
        out_shape=[jax.ShapeDtypeStruct((bs, h, l, hd), q.dtype),
                   jax.ShapeDtypeStruct((bs, h, hd, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bs, h, 1, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bs, h, 1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32),
                        pltpu.VMEM((1, hd), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, logi, logf)
