"""Locality-aware collectives (paper §5.3, Fig 9) as shard_map programs.

Faabric's VM-leader all-reduce sends one message per remote VM per step and
uses fast in-memory queues within a VM.  The TPU mapping: the **pod** is the
VM (slow DCI/DCN links between pods ↔ cross-VM network), the intra-pod ICI
is the in-memory queue.  The two-level schedule becomes:

    reduce-scatter over the fast (intra-pod) axis      [each chip owns 1/n]
    all-reduce over the slow (cross-pod) axis          [shard-sized traffic]
    all-gather over the fast axis                      [redistribute]

which moves ``bytes/n_fast`` over the slow link instead of ``bytes`` —
the generalisation of "one leader message per VM".  An optional top-k
delta compression (``optim.compress``) shrinks the slow hop further
(beyond-paper, DESIGN.md §5).

All functions here are *per-device* (inside shard_map).  ``build_*`` helpers
wrap them in shard_map over a mesh for direct use.
"""
from __future__ import annotations

import re
import time as _time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import comms, compat, telemetry
from repro.core.compat import shard_map
from repro.kernels.collective_codec import ops as codec_ops


# ---------------------------------------------------------------------------
# Pytree <-> padded flat vector (gradient bucketing)
# ---------------------------------------------------------------------------
# flatten spec cached per (treedef, leaf layout, pad_to): a gang syncs
# the same tree structure every step, so the spec derivation (a Python
# walk over every leaf) runs once per structure, not once per trace
_SPEC_CACHE: Dict[Tuple, Tuple] = {}


def flatten_spec(tree, pad_to: int = 1):
    """(spec, pad) for ``flatten_tree``/``unflatten_tree`` of ``tree``,
    cached per tree structure."""
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, tuple((tuple(l.shape), str(jnp.dtype(l.dtype)))
                          for l in leaves), pad_to)
    hit = _SPEC_CACHE.get(key)
    if hit is None:
        sizes = [int(l.size) for l in leaves]
        pad = (-sum(sizes)) % pad_to
        hit = ((treedef, sizes, [l.shape for l in leaves],
                [l.dtype for l in leaves]), pad)
        _SPEC_CACHE[key] = hit
    return hit


def flatten_tree(tree, pad_to: int = 1):
    """Concatenate all leaves into one f32 vector, padded to a multiple of
    ``pad_to`` (bucketing: one collective for the whole tree)."""
    spec, pad = flatten_spec(tree, pad_to)
    leaves = jax.tree.leaves(tree)
    vec = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    if pad:
        vec = jnp.pad(vec, (0, pad))
    return vec, spec


def unflatten_tree(vec, spec):
    treedef, sizes, shapes, dtypes = spec
    cuts = np.cumsum(sizes)
    # one split instead of a per-leaf slice loop
    parts = jnp.split(vec[:int(cuts[-1])], cuts[:-1].tolist())
    return jax.tree.unflatten(
        treedef, [p.reshape(shp).astype(dt)
                  for p, shp, dt in zip(parts, shapes, dtypes)])


# ---------------------------------------------------------------------------
# Per-device collective bodies (call inside shard_map)
# ---------------------------------------------------------------------------
def hierarchical_psum(vec, fast_axis: str, slow_axis: Optional[str]):
    """Two-level all-reduce of a flat vector (paper Fig 9 schedule)."""
    vec = jax.lax.psum_scatter(vec, fast_axis, scatter_dimension=0,
                               tiled=True)
    if slow_axis is not None:
        vec = jax.lax.psum(vec, slow_axis)
    return jax.lax.all_gather(vec, fast_axis, axis=0, tiled=True)


def flat_psum(vec, axes: Sequence[str]):
    """Single flat all-reduce over all axes (the baseline schedule)."""
    return jax.lax.psum(vec, tuple(axes))


def reference_topk_select(vec, frac: float):
    """The pre-tuner codec: a *global* ``top_k`` over the whole shard —
    an O(n log n) sort that cost more than the slow link saved (ROADMAP
    item 5).  Kept as the measured reference the chunk-select codec
    must beat (``bench_message_passing`` times both)."""
    k = max(1, int(vec.size * frac))
    mag = jnp.abs(vec)
    _, idx = jax.lax.top_k(mag, k)
    sel = vec[idx]
    residual = vec.at[idx].set(0.0)
    return sel, idx, residual


def compressed_hierarchical_psum(vec, fast_axis: str, slow_axis: str,
                                 frac: float, resid_shard=None):
    """Two-level all-reduce with threshold-select delta compression on
    the slow hop.

    After the intra-pod reduce-scatter, each chip owns a disjoint shard.
    The shard is chunked and each chunk ships only its largest-magnitude
    element across the pod boundary — a fixed-size sparse (idx, val)
    message, ``frac`` of the shard (merge-op = sum on sparse diffs, the
    paper's byte-wise-diff protocol generalised to sparse deltas).  The
    codec is the vectorized ``kernels/collective_codec`` chunk-select —
    one O(n) streaming pass, not the old global ``top_k`` sort.  The
    unselected remainder stays local as an error-feedback residual
    (``resid_shard``) added to the next step's shard, preserving
    convergence; with ``frac=1.0`` the chunk width degenerates to 1 and
    the result is bit-exact to ``hierarchical_psum``.
    """
    shard = jax.lax.psum_scatter(vec, fast_axis, scatter_dimension=0,
                                 tiled=True)
    if resid_shard is not None:
        shard = shard + resid_shard
    sel, idx, residual = codec_ops.select_codec(shard, frac=float(frac))
    # ship only (idx, val) over the slow link; sum-merge on arrival
    all_sel = jax.lax.all_gather(sel, slow_axis, axis=0)       # (pods, k)
    all_idx = jax.lax.all_gather(idx, slow_axis, axis=0)
    merged = jnp.zeros_like(shard).at[all_idx.reshape(-1)].add(
        all_sel.reshape(-1))
    out = jax.lax.all_gather(merged, fast_axis, axis=0, tiled=True)
    return out, residual


def ring_allreduce(vec, axis: str):
    """Bandwidth-optimal ring all-reduce via explicit collective-permutes
    (2*(n-1) steps: reduce-scatter ring + all-gather ring).  This is the
    ppermute mapping of the paper's p2p messaging layer."""
    n = compat.axis_size(axis)
    if n == 1:
        return vec
    me = jax.lax.axis_index(axis)
    chunks = vec.reshape(n, -1)
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(c, chunks):
        # at step s, rank r sends chunk (r - s) mod n
        send_idx = (me - c) % n
        recv_idx = (me - c - 1) % n
        sent = jax.lax.ppermute(chunks[send_idx], axis, perm_fwd)
        return chunks.at[recv_idx].add(sent)

    for s in range(n - 1):
        chunks = rs_step(s, chunks)

    def ag_step(c, chunks):
        send_idx = (me - c + 1) % n
        recv_idx = (me - c) % n
        sent = jax.lax.ppermute(chunks[send_idx], axis, perm_fwd)
        return chunks.at[recv_idx].set(sent)

    for s in range(n - 1):
        chunks = ag_step(s, chunks)
    return chunks.reshape(vec.shape)


# ---------------------------------------------------------------------------
# Mesh-level wrappers
# ---------------------------------------------------------------------------
def dp_axes(mesh: Mesh) -> Tuple[str, Optional[str]]:
    """(fast_axis, slow_axis) for the data-parallel dimension of a mesh."""
    names = mesh.axis_names
    slow = "pod" if "pod" in names else None
    return "data", slow


def padded_size(tree, n_fast: int) -> int:
    total = sum(l.size for l in jax.tree.leaves(tree))
    return total + (-total) % n_fast


def init_residual_buffer(mesh: Mesh, tree):
    """Zero error-feedback buffer: (n_pods, padded_flat_size) f32, sharded
    P('pod', 'data') so each chip holds its own scattered shard."""
    fast, slow = dp_axes(mesh)
    n_pods = mesh.shape[slow] if slow else 1
    n_total = n_pods * mesh.shape[fast]
    return jnp.zeros((n_pods, padded_size(tree, n_total)), jnp.float32)


def tree_sync_body(tree, mode: str, fast: str, slow: Optional[str],
                   n_total: int, compress_frac: Optional[float] = None,
                   resid_shard=None):
    """Per-device gradient sync of a pytree (call inside shard_map).

    Returns (mean tree, new residual shard or None)."""
    vec, spec = flatten_tree(tree, pad_to=n_total)  # divisible by n_fast too
    if mode == "flat":
        out, resid = flat_psum(vec, [a for a in (fast, slow) if a]), None
    elif mode == "ring":
        out = ring_allreduce(vec, fast)
        if slow is not None:
            out = jax.lax.psum(out, slow)
        resid = None
    elif mode == "hierarchical":
        out, resid = hierarchical_psum(vec, fast, slow), None
    elif mode == "compressed":
        assert slow is not None and compress_frac is not None
        out, resid = compressed_hierarchical_psum(
            vec, fast, slow, compress_frac, resid_shard=resid_shard)
    else:
        raise ValueError(mode)
    return unflatten_tree(out / n_total, spec), resid


def build_tree_allreduce(mesh: Mesh, mode: str = "hierarchical",
                         compress_frac: Optional[float] = None) -> Callable:
    """Returns f(tree, resid) -> (tree_mean, new_resid): all-reduce-mean a
    tree whose leaves carry a leading device axis of size n_devices (one
    private copy per device).  ``resid`` is the (n_pods, n_pad) error
    feedback buffer for mode='compressed' (pass None otherwise)."""
    fast, slow = dp_axes(mesh)
    axes = [a for a in (fast, slow) if a is not None]
    n_total = int(np.prod([mesh.shape[a] for a in axes]))

    def per_device(tree, resid):
        rs = resid[0] if resid is not None else None
        out, new_rs = tree_sync_body(tree, mode, fast, slow, n_total,
                                     compress_frac, rs)
        return out, (new_rs[None] if new_rs is not None else None)

    # every device holds its own (different) copy: specs are fully sharded
    spec_in = P(tuple(a for a in (("pod",) if slow else ()) + (fast,)))
    resid_spec = P(slow, fast) if slow else None

    def allreduce(tree, resid=None):
        return shard_map(per_device, mesh=mesh,
                         in_specs=(jax.tree.map(lambda _: spec_in, tree),
                                   resid_spec),
                         out_specs=(jax.tree.map(lambda _: spec_in, tree),
                                    (resid_spec if mode == "compressed"
                                     else None)),
                         check_vma=False)(tree, resid)

    return allreduce


_HLO_SIZES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
              "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
              "f8e4m3": 1, "f8e5m2": 1, "s16": 2, "u16": 2}
HLO_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute")
# one collective *instruction definition* per match: the result shape is
# everything between '=' and the op name, which must be immediately
# followed by its operand list '('.  The lazy shape group accepts tuple
# shapes (with layout annotations, whose nested parens truncated the old
# single-level `\([^)]*\)` alternative), and requiring `kind(` stops
# fusion lines that merely *reference* a `%collective-permute.N` operand
# from being counted as collectives (they were, inflating ring schedules
# ~5x).
_HLO_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>.*?)\s*"
    r"(?P<kind>" + "|".join(HLO_COLLECTIVE_KINDS) + r")\((?P<rest>.*)$",
    re.M)
_HLO_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_HLO_GROUPS = re.compile(r"replica_groups=(\{[\d,{}]*\})")
_HLO_PAIRS = re.compile(r"source_target_pairs=\{([\d,{}]*)\}")


def _shape_bytes(shape_text: str) -> int:
    nbytes = 0
    for dt, dims in _HLO_SHAPE.findall(shape_text):
        if dt not in _HLO_SIZES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _HLO_SIZES[dt]
    return nbytes


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in an HLO dump — the
    ``collective term`` source for the roofline analysis."""
    out = {k: 0 for k in HLO_COLLECTIVE_KINDS}
    for m in _HLO_INSTR.finditer(hlo_text):
        out[m.group("kind")] += _shape_bytes(m.group("shape"))
    out["total"] = sum(out[k] for k in HLO_COLLECTIVE_KINDS)
    return out


def slowlink_bytes_from_hlo(hlo_text: str, pod_of: Sequence[int]) -> int:
    """Per-rank bytes a compiled schedule moves across the pod (slow
    link) boundary: the result bytes of every collective instruction
    whose replica group — or permute pair — spans pods.  This is the
    *measured* replacement for the old hardcoded analytical
    ``slowlink_bytes_*`` table in ``bench_message_passing``.

    ``pod_of`` maps device id -> pod id.  collective-permutes count
    only their crossing fraction of pairs (a fast-axis ring whose edges
    all stay inside one pod contributes zero)."""
    pod_of = list(pod_of)
    n_pods = len(set(pod_of))
    total = 0.0
    for m in _HLO_INSTR.finditer(hlo_text):
        nbytes = _shape_bytes(m.group("shape"))
        rest = m.group("rest")
        pm = _HLO_PAIRS.search(rest)
        if pm is not None:
            pairs = re.findall(r"\{(\d+),(\d+)\}", pm.group(1))
            if pairs:
                crossing = sum(pod_of[int(a)] != pod_of[int(b)]
                               for a, b in pairs)
                total += nbytes * crossing / len(pairs)
            continue
        gm = _HLO_GROUPS.search(rest)
        if gm is not None:
            groups = [[int(r) for r in g.split(",") if r]
                      for g in re.findall(r"\{([\d,]*)\}", gm.group(1))]
            groups = [g for g in groups if g]
            if groups:
                if any(len({pod_of[r] for r in g}) > 1 for g in groups):
                    total += nbytes
                continue
        # empty/unparseable groups mean "all devices": spans iff pods > 1
        if n_pods > 1:
            total += nbytes
    return int(total)


# ---------------------------------------------------------------------------
# Topology-tuned schedule dispatch (ROADMAP item 5, DESIGN.md §11)
# ---------------------------------------------------------------------------
def mesh_pod_of(mesh: Mesh) -> list:
    """device id -> pod index for a (pod, data) gang mesh (pod rows)."""
    devs = np.asarray(mesh.devices)
    if devs.ndim == 1:
        devs = devs[None]
    pod_of = {}
    for p, row in enumerate(devs):
        for d in np.ravel(row):
            pod_of[d.id] = p
    return [pod_of[i] for i in sorted(pod_of)]


def measure_schedule(mesh: Mesh, mode: str, nbytes: int,
                     compress_frac: float = 0.05, reps: int = 3,
                     link: Optional[comms.LinkProfile] = None,
                     emulate_slow: Optional[bool] = None) -> dict:
    """One-shot measured probe of one collective schedule.

    Times ``reps`` all-reduces of an ``nbytes`` tree on ``mesh`` and
    measures the schedule's slow-link bytes from its compiled HLO
    (``slowlink_bytes_from_hlo``).  When the fleet has no *real* slow
    link (the forced-host CPU fabric), ``emulate_slow`` adds the
    modeled slow-link transfer time — measured bytes over the profile's
    slow-link bandwidth — so schedules are compared under the topology
    they are tuned for.  Returns
    ``{"wall_s", "slowlink_bytes", "effective_s"}`` per all-reduce.
    """
    link = link or comms.LinkProfile()
    if emulate_slow is None:
        emulate_slow = jax.default_backend() == "cpu"
    n_dev = mesh.devices.size
    n = max(n_dev, int(nbytes) // 4)
    n += (-n) % n_dev
    tree = {"g": jnp.ones((n_dev, n // n_dev), jnp.float32)}
    fn = jax.jit(build_tree_allreduce(mesh, mode, compress_frac))
    resid = (init_residual_buffer(mesh, jax.tree.map(lambda x: x[0], tree))
             if mode == "compressed" else None)
    out, new_resid = fn(tree, resid)
    jax.block_until_ready(out)
    if new_resid is not None:
        # the fed-back residual is mesh-sharded while the initial one is
        # single-device; warm up the steady-state sharding so the timed
        # loop never recompiles
        resid = new_resid
        out, new_resid = fn(tree, resid)
        jax.block_until_ready(out)
    t0 = _time.perf_counter()
    for _ in range(reps):
        out, new_resid = fn(tree, resid)
        if new_resid is not None:
            resid = new_resid
    jax.block_until_ready(out)
    wall = (_time.perf_counter() - t0) / max(1, reps)
    hlo = fn.lower(tree, resid).compile().as_text()
    slow_b = slowlink_bytes_from_hlo(hlo, mesh_pod_of(mesh))
    eff = wall + (slow_b / link.slow_bps if emulate_slow else 0.0)
    return {"wall_s": wall, "slowlink_bytes": slow_b, "effective_s": eff}


class CollectiveTuner:
    """Per-(topology, message-size-bucket) collective schedule dispatch.

    The table maps ``(Topology.key, size_bucket)`` to the schedule the
    comms layer should run — flat / ring / hierarchical / compressed —
    seeded from the analytical cost model in ``core.comms`` (slow-link
    bytes x per-link bandwidth + per-step latency) and refined by
    one-shot measured probes (``probe``/``record_probe``), which
    overwrite the analytical estimate for the probed (topology, bucket,
    mode) and re-derive the dispatch entry.

    ``Fabric`` owns one; ``GangHandle`` re-derives a gang's entries
    after every placement change (attach / migrate / evacuate /
    rescale) via ``on_placement_change`` and drops them on release.
    """

    def __init__(self, link: Optional[comms.LinkProfile] = None,
                 compress_frac: float = 0.05,
                 modes: Sequence[str] = comms.MODES):
        self.link = link or comms.LinkProfile()
        self.compress_frac = float(compress_frac)
        self.modes = tuple(modes)
        # (topo.key, bucket) -> (mode, predicted seconds)
        self.table: Dict[Tuple[Tuple[int, int, int], int],
                         Tuple[str, float]] = {}
        # (topo.key, bucket) -> {mode: measured seconds} probe overrides
        self.measured: Dict[Tuple[Tuple[int, int, int], int],
                            Dict[str, float]] = {}
        self.gangs: Dict[str, comms.Topology] = {}
        self.rederivations = 0

    # ---- derivation --------------------------------------------------------
    def _derive(self, topo: comms.Topology, bucket: int,
                modes: Optional[Sequence[str]] = None
                ) -> Tuple[str, float]:
        entry = comms.best_schedule(
            topo, comms.bucket_nbytes(bucket), self.link,
            self.compress_frac, modes or self.modes,
            measured=self.measured.get((topo.key, bucket)))
        if modes is None:
            self.table[(topo.key, bucket)] = entry
        return entry

    def on_placement_change(self, job_id: str,
                            placement: Sequence[Tuple[int, int]]
                            ) -> comms.Topology:
        """Re-derive the dispatch entries for a gang whose placement
        just changed (attach / migrate / evacuate / rescale)."""
        tel = telemetry.get()
        t0 = _time.perf_counter() if tel.enabled else 0.0
        topo = comms.Topology.from_placement(placement)
        self.gangs[job_id] = topo
        self.rederivations += 1
        for b in range(comms.MIN_BUCKET, comms.MAX_BUCKET + 1):
            self._derive(topo, b)
        if tel.enabled:
            tel.count("collective.rederivations")
            tel.span_at("collective.rederive", t0, _time.perf_counter(),
                        track="collectives", clock="wall", job=job_id,
                        hosts=topo.hosts, chips=topo.chips)
        return topo

    def forget(self, job_id: str) -> None:
        self.gangs.pop(job_id, None)

    # ---- dispatch ----------------------------------------------------------
    def _topo(self, gang_or_placement) -> comms.Topology:
        if isinstance(gang_or_placement, comms.Topology):
            return gang_or_placement
        if isinstance(gang_or_placement, str):
            topo = self.gangs.get(gang_or_placement)
            return topo if topo is not None else comms.Topology(1, 1, 1)
        return comms.Topology.from_placement(gang_or_placement)

    def mode_for(self, gang_or_placement, nbytes: Optional[int] = None,
                 allowed: Optional[Sequence[str]] = None) -> str:
        """The schedule to run for one collective: dispatch-table
        lookup by (gang topology, size bucket), deriving on miss.
        ``allowed`` restricts the choice (a single-axis mesh cannot run
        the pod-level compressed/hierarchical schedules)."""
        topo = self._topo(gang_or_placement)
        bucket = comms.size_bucket(nbytes)
        if allowed is not None and set(allowed) != set(self.modes):
            mode = self._derive(topo, bucket, modes=tuple(allowed))[0]
        else:
            entry = self.table.get((topo.key, bucket))
            if entry is None:
                entry = self._derive(topo, bucket)
            mode = entry[0]
        tel = telemetry.get()
        if tel.enabled:
            tel.count(f"collective.dispatch.{mode}")
        return mode

    def predicted_time(self, gang_or_placement,
                       nbytes: Optional[int] = None) -> float:
        """Seconds for the dispatched (best) schedule — the quantity
        ``CostModel.collective_time`` prices placements with."""
        topo = self._topo(gang_or_placement)
        bucket = comms.size_bucket(nbytes)
        entry = self.table.get((topo.key, bucket))
        if entry is None:
            entry = self._derive(topo, bucket)
        return entry[1]

    # ---- measured refinement ----------------------------------------------
    def record_probe(self, gang_or_placement, nbytes: int, mode: str,
                     seconds: float) -> None:
        """Fold one measured (topology, bucket, mode) timing into the
        table: the measurement overrides the analytical estimate and
        the dispatch entry is re-derived."""
        topo = self._topo(gang_or_placement)
        bucket = comms.size_bucket(nbytes)
        self.measured.setdefault((topo.key, bucket), {})[mode] = \
            float(seconds)
        self._derive(topo, bucket)
        tel = telemetry.get()
        if tel.enabled:
            tel.count("collective.probes")
            tel.observe(f"collective.probe_s.{mode}", float(seconds))
            tel.instant("collective.probe", track="collectives",
                        mode=mode, bucket=bucket,
                        seconds=float(seconds))

    def probe(self, mesh: Mesh, nbytes: int = comms.DEFAULT_NBYTES,
              modes: Optional[Sequence[str]] = None, reps: int = 2
              ) -> Dict[str, float]:
        """Measure every available schedule once on ``mesh`` and refine
        the dispatch entry for its topology (expensive: compiles one
        program per mode — a one-shot calibration, not a hot path)."""
        devs = np.asarray(mesh.devices)
        pods = devs.shape[0] if devs.ndim > 1 else 1
        chips = devs.size
        topo = comms.Topology(pods, chips, max(1, chips // max(1, pods)))
        out: Dict[str, float] = {}
        for mode in (modes or self.modes):
            if mode == "compressed" and pods <= 1:
                continue
            m = measure_schedule(mesh, mode, nbytes,
                                 self.compress_frac, reps, self.link)
            out[mode] = m["effective_s"]
            self.record_probe(topo, nbytes, mode, m["effective_s"])
        return out
