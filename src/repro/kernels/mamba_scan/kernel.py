"""Mamba2 chunked selective-scan kernel (Pallas, SSD algorithm).

TPU adaptation of the SSD chunked scan (DESIGN.md §6): the grid iterates
(batch, head, chunk) with the chunk dimension sequential; the (P, N)
selective state persists in VMEM scratch across chunk steps, so the
inter-chunk recurrence never leaves the chip.  Within a chunk everything is
(q x q) / (q x N) / (q x P) matmul work on the MXU.

Per chunk (all f32 in VMEM):
    cum     = cumsum(dt * a)                   (q,)
    decay   = exp(cum_i - cum_j) masked i>=j   (q, q)
    y_intra = ((C B^T) .* decay .* dt_j) x
    y_inter = exp(cum) * (C . state)
    state   = exp(total) * state + B^T ((exp(total - cum) dt) .* x)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref,
                s_scr, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (q, 1)
    a = a_ref[0, 0]                              # (1, 1) f32
    b = b_ref[0].astype(jnp.float32)             # (q, N)
    c = c_ref[0].astype(jnp.float32)             # (q, N)
    q = x.shape[0]

    da = dt * a                                  # (q, 1), negative
    cum = jnp.cumsum(da, axis=0)                 # (q, 1)
    total = cum[-1:, :]                          # (1, 1)

    # within-chunk
    seg = cum - cum.T                            # (q, q): cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(jnp.where(ii >= jj, seg, -1e30))  # mask before exp
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m = scores * decay * dt.T                    # (q, q)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cum) * C . S_in   (S_in: (P, N) scratch)
    y = y + jnp.exp(cum) * jax.lax.dot_general(
        c, s_scr[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S = exp(total) S + sum_j w_j x_j B_j^T
    w = jnp.exp(total - cum) * dt                # (q, 1)
    s_new = jax.lax.dot_general(x * w, b, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s_scr[...] = jnp.exp(total) * s_scr[...] + s_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        sfin_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 64, interpret: bool = False):
    """x: (B,H,L,P); dt: (B,H,L,1); a: (H,1,1); b,c: (B,L,N).

    Returns y: (B,H,L,P), final state (B,H,P,N)."""
    bs, h, l, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk
    grid = (bs, h, nc)
    kernel = functools.partial(_ssd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, ci: (bb, hh, ci,
                                                               0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bb, hh, ci: (bb, hh, ci,
                                                               0)),
            pl.BlockSpec((1, 1, 1), lambda bb, hh, ci: (hh, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, ci: (bb, hh, ci,
                                                               0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bs, h, l, p), x.dtype),
                   jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)
