"""Delta-checkpoint chains (ISSUE 6): CheckpointManager ``(base,
delta*)`` mode, GangHandle chain replay on hard failure, and the
CostModel/Young-Daly cadence coupling.

Bit-exactness is the invariant everywhere: a chain restore must
fingerprint-match the full snapshot it replaces, and the configured
(deterministic) delta cost must leave simulated and live Action logs
identical — the live-trace identity itself is pinned in
``test_fabric.py``'s churn tests, which now run through the chain
replay path."""
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import fleet as fleet_mod
from repro.core import snapshot as snap_mod
from repro.core.fabric import GangHandle
from repro.core.placement import CostModel
from repro.core.simulator import Job, Simulator


def _state(seed=0, f64=False):
    # manager tests restore through ``snap_mod.restore`` (jnp.asarray),
    # which downcasts f64 with x64 off — keep those leaves jnp-stable;
    # the GangHandle tests work on raw snapshots and use f64 freely
    rng = np.random.default_rng(seed)
    mdt = np.float64 if f64 else np.float32
    return {"w": rng.normal(size=(300, 40)).astype(np.float32),
            "m": rng.normal(size=(130,)).astype(mdt),
            "step": np.int32(0)}


def _mutate(state, s):
    out = {k: np.array(v, copy=True) for k, v in state.items()}
    out["w"][s % 300, :5] += 1.0
    out["step"] = type(state["step"])(s)
    return out


# ---------------------------------------------------------------------------
# CheckpointManager delta_chain mode
# ---------------------------------------------------------------------------
def test_manager_delta_chain_bit_exact(tmp_path):
    """base + N deltas + rebase: every step restores bit-exactly, and
    the chain kinds follow the rebase policy."""
    mgr = CheckpointManager(str(tmp_path), "job", keep=3,
                            delta_chain=True, rebase_every=3)
    state, states = _state(), []
    for s in range(7):
        state = _mutate(state, s)
        mgr.save(s, state)
        states.append(state)
    assert [st["kind"] for st in mgr.stats] == \
        ["full", "delta", "delta", "full", "delta", "delta", "full"]
    for s in range(7):
        restored, step = mgr.restore(s)
        assert step == s
        for k in state:
            np.testing.assert_array_equal(np.asarray(restored[k]),
                                          states[s][k])


def test_manager_delta_chain_detects_corruption(tmp_path):
    """A tampered chain link fails the fingerprint check on restore."""
    import pickle
    mgr = CheckpointManager(str(tmp_path), "job", delta_chain=True,
                            rebase_every=8)
    state = _state()
    for s in range(3):
        state = _mutate(state, s)
        mgr.save(s, state)
    # corrupt the last delta's payload on disk (an earlier link's
    # corruption could be masked by a later overwrite of the chunk)
    entry = mgr._manifest()[2]
    with open(entry["path"], "rb") as f:
        payload = pickle.load(f)
    next(iter(payload["diffs"].values())).new[0, 0] += 1.0
    with open(entry["path"], "wb") as f:
        pickle.dump(payload, f, protocol=4)
    with pytest.raises(RuntimeError, match="not bit-exact"):
        mgr.restore(2)


def test_manager_delta_bytes_much_smaller(tmp_path):
    mgr = CheckpointManager(str(tmp_path), "job", delta_chain=True,
                            rebase_every=16)
    state = _state()
    for s in range(6):
        state = _mutate(state, s)
        mgr.save(s, state)
    deltas = [st["bytes"] for st in mgr.stats if st["kind"] == "delta"]
    full = mgr.stats[0]["full_bytes"]
    assert deltas and max(deltas) * 2 < full


def test_manager_incremental_mode_unchanged(tmp_path):
    """The pre-existing diff-vs-last-full mode still round-trips."""
    mgr = CheckpointManager(str(tmp_path), "job", incremental_every=3)
    state, states = _state(1), []
    for s in range(5):
        state = _mutate(state, s)
        mgr.save(s, state)
        states.append(state)
    restored, step = mgr.restore(4)
    for k in state:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      states[4][k])


# ---------------------------------------------------------------------------
# GangHandle (base, delta*) chain: replay on hard failure is bit-exact
# ---------------------------------------------------------------------------
class _StubFabric:
    def host_of(self, d):
        return 0

    def reclaim(self, devs):
        pass


def _handle(rebase_every=4):
    h = GangHandle(_StubFabric(), "gang")
    h.status = "running"
    h.ckpt_rebase_every = rebase_every
    return h


def test_gang_handle_chain_kinds_and_replay():
    h = _handle(rebase_every=3)
    state = _state(2, f64=True)
    for s in range(5):
        state = _mutate(state, s)
        h.checkpoint(state, s)
    assert [st["kind"] for st in h.ckpt_stats] == \
        ["full", "delta", "delta", "full", "delta"]
    # hard failure: replay base+deltas, fingerprint-verified
    snap = h.fail([])
    assert snap.step == 4
    ref = snap_mod.take("gang", 4, state)
    assert snap.fingerprint == ref.fingerprint
    assert snap_mod.verify(snap, ref)
    # the chain was consumed: the post-recovery checkpoint rebases
    h.status = "running"
    h.snapshot = None
    h.checkpoint(state, 5)
    assert h.ckpt_stats[-1]["kind"] == "full"


def test_gang_handle_chain_replay_catches_divergence():
    h = _handle(rebase_every=8)
    state = _state(3, f64=True)
    for s in range(3):
        state = _mutate(state, s)
        h.checkpoint(state, s)
    # corrupt a recorded delta payload: replay must not silently
    # hand back a wrong rollback point
    next(iter(h._ckpt_deltas[0]["diffs"].values())).new[0, 0] += 1.0
    with pytest.raises(RuntimeError, match="diverged"):
        h.fail([])


def test_gang_handle_layout_change_forces_rebase():
    h = _handle(rebase_every=8)
    state = _state(4, f64=True)
    h.checkpoint(state, 0)
    h.checkpoint(_mutate(state, 1), 1)
    assert h.ckpt_stats[-1]["kind"] == "delta"
    # a rescale-style layout change (new leaf shape) cannot diff
    grown = {"w": np.zeros((600, 40), dtype=np.float32),
             "m": np.zeros((130,), dtype=np.float64),
             "step": np.int64(2)}
    h.checkpoint(grown, 2)
    assert h.ckpt_stats[-1]["kind"] == "full"


# ---------------------------------------------------------------------------
# CostModel delta charging + Young/Daly coupling
# ---------------------------------------------------------------------------
def test_cost_model_checkpoint_cost_indexing():
    full = CostModel()                     # delta checkpointing off
    assert full.checkpoint_cost(0) == full.checkpoint_cost(3) \
        == full.checkpoint_cost_s
    m = CostModel(checkpoint_cost_s=0.5, ckpt_delta_fraction=0.1,
                  ckpt_rebase_every=4)
    # index 0 (start baseline) and every 4th are full; between: delta
    costs = [m.checkpoint_cost(i) for i in range(9)]
    assert costs[0] == costs[4] == costs[8] == 0.5
    assert all(c == pytest.approx(0.05) for i, c in enumerate(costs)
               if i % 4)
    eff = m.effective_checkpoint_cost_s()
    assert eff == pytest.approx(0.5 * (1 + 3 * 0.1) / 4)
    assert eff < m.checkpoint_cost_s


def test_young_daly_tightens_with_delta_cost():
    m = CostModel(checkpoint_cost_s=0.5, ckpt_delta_fraction=0.1,
                  ckpt_rebase_every=8)
    tau_full = fleet_mod.optimal_checkpoint_interval(800.0, 0.5)
    tau_delta = fleet_mod.optimal_checkpoint_interval(800.0,
                                                      cost_model=m)
    assert tau_delta < tau_full
    # tau scales as sqrt of the cost ratio
    ratio = m.effective_checkpoint_cost_s() / 0.5
    assert tau_delta == pytest.approx(tau_full * np.sqrt(ratio))


def test_observed_delta_fraction_stats_only():
    m = CostModel(ckpt_delta_fraction=0.2)
    assert m.observed_delta_fraction() is None
    m.observe_checkpoint(10, 100)
    m.observe_checkpoint(30, 100)
    assert m.observed_delta_fraction() == pytest.approx(0.2)
    # observation never changes what the trace charges
    assert m.checkpoint_cost(1) == pytest.approx(
        m.checkpoint_cost_s * 0.2)


def test_simulator_delta_charging_cuts_overhead():
    """Same trace, same cadence: delta-cost checkpoints lose less
    progress per tick, so the makespan shrinks — and with the fraction
    at 1.0 the charging is identical to the full-cost model."""
    from repro.core.fleet import FleetEvent
    jobs = [Job("a", "mpi-compute", 4, 400.0, arrival=0.0),
            Job("b", "mpi-compute", 4, 400.0, arrival=0.0)]
    events = [FleetEvent(30.0, "fail", hosts=[0])]

    def run(model):
        sim = Simulator(4, 4, "granular",
                        cost_model=model, checkpoint_interval=5.0)
        return sim.run(jobs, fleet_events=events)

    res_full = run(CostModel())
    res_one = run(CostModel(ckpt_delta_fraction=1.0))
    assert res_one.actions == res_full.actions
    assert res_one.makespan == res_full.makespan
    res_delta = run(CostModel(ckpt_delta_fraction=0.05,
                              ckpt_rebase_every=8))
    n_ckpts = sum(1 for a in res_delta.actions
                  if a.kind == "checkpoint")
    assert n_ckpts >= 2
    assert res_delta.makespan < res_full.makespan
