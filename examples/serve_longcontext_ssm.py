"""Long-context serving with a sub-quadratic arch (xlstm reduced config):
prefill a prompt, then decode far beyond it with O(1) per-token state —
the mechanism behind the long_500k assigned shape (DESIGN.md §4).

Also demonstrates decode-state snapshotting: a serving Granule migrates
mid-generation (snapshot -> restore) and continues bit-exactly.

Run:
    PYTHONPATH=src python examples/serve_longcontext_ssm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import reduced_config
from repro.core import snapshot as snap_mod
from repro.models import model as M
from repro.models import transformer as tf


def main():
    cfg = reduced_config("xlstm-1.3b")
    key = jax.random.PRNGKey(0)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(key)
    serve = jax.jit(M.make_serve_step(cfg))

    b, prompt_len, gen = 2, 32, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len),
                                0, cfg.vocab)
    # "prefill" by decoding the prompt (state is O(1) in context length)
    states = tf.init_decode_state(cfg, b, prompt_len + gen,
                                  cfg.param_dtype())
    for t in range(prompt_len):
        logits, states = serve(params, states, tokens[:, t:t + 1],
                               jnp.full((b, 1), t, jnp.int32))
    state_bytes = sum(x.nbytes for x in jax.tree.leaves(states))
    print(f"recurrent state: {state_bytes/2**20:.1f} MiB "
          f"(constant in context length)")

    cur = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
    out_a = []
    for t in range(prompt_len, prompt_len + gen // 2):
        logits, states = serve(params, states, cur,
                               jnp.full((b, 1), t, jnp.int32))
        cur = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out_a.append(int(cur[0, 0]))

    # migrate the serving Granule mid-generation: snapshot decode state
    snap = snap_mod.take("serve-job", prompt_len + gen // 2,
                         {"states": states, "cur": cur})
    restored = snap_mod.restore(snap)
    states2, cur2 = restored["states"], restored["cur"]

    out_b, out_b2 = [], []
    st = states
    for t in range(prompt_len + gen // 2, prompt_len + gen):
        logits, st = serve(params, st, cur, jnp.full((b, 1), t, jnp.int32))
        cur = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out_b.append(int(cur[0, 0]))
        logits2, states2 = serve(params, states2, cur2,
                                 jnp.full((b, 1), t, jnp.int32))
        cur2 = jnp.argmax(logits2[:, 0], -1)[:, None].astype(jnp.int32)
        out_b2.append(int(cur2[0, 0]))

    assert out_b == out_b2, "migrated Granule diverged"
    print(f"generated {len(out_a) + len(out_b)} tokens; "
          f"post-migration continuation bit-exact: {out_b == out_b2}")
    print("sample:", (out_a + out_b)[:12])


if __name__ == "__main__":
    main()
