"""Pure-jnp oracle for the diff_merge kernel (Table 3 semantics).

Kept in lockstep with ``kernel._dm_kernel``: same ``compute_dtype``
rule (integer leaves merge exactly for sum/subtract/overwrite; bf16
promotes to f32; f32/f64 keep their precision) and the same merge
formulas, so kernel-vs-ref tests pin both the maths and the dtype
handling.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.diff_merge.kernel import compute_dtype


def diff_merge_ref(a0, b0, b1, *, op: str = "sum"):
    cdt = compute_dtype(a0.dtype, op)
    a0f = a0.astype(cdt)
    b0f = b0.astype(cdt)
    b1f = b1.astype(cdt)
    if op == "sum":
        merged = a0f + (b1f - b0f)
    elif op == "subtract":
        merged = a0f - (b0f - b1f)
    elif op == "multiply":
        merged = a0f * jnp.where(b0f == 0, 1.0, b1f / b0f)
    elif op == "divide":
        merged = a0f / jnp.where(b1f == 0, 1.0,
                                 jnp.where(b0f == 0, 1.0, b0f / b1f))
    elif op == "overwrite":
        merged = b1f
    else:
        raise ValueError(op)
    dirty = jnp.any(b0 != b1, axis=1, keepdims=True)
    a1 = jnp.where(dirty, merged, a0f).astype(a0.dtype)
    return a1, dirty
