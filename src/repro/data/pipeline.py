"""Deterministic, checkpointable synthetic token pipeline.

Every batch is a pure function of ``(seed, step)`` — no iterator state
beyond the step counter.  This is what makes Faabric-style migration,
elastic resize and gang restart *bit-exact*: any Granule placed anywhere
can regenerate exactly the batch slice it owes for step ``s``.

The synthetic distribution is a Zipf-like unigram mix with short-range
repetition structure so cross-entropy actually decreases during the
end-to-end examples (a pure-uniform stream would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 128
    global_batch: int = 8
    zipf_a: float = 1.2
    repeat_p: float = 0.3          # P[token t copies token t-k]
    repeat_k: int = 8


def _unigram_logits(cfg: DataConfig):
    ranks = jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32)
    return -cfg.zipf_a * jnp.log(ranks)


def make_batch(cfg: DataConfig, step: int,
               extras: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Global batch for ``step``; identical for any world layout."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    kz, kr, kc = jax.random.split(key, 3)
    b, s = cfg.global_batch, cfg.seq_len
    base = jax.random.categorical(
        kz, _unigram_logits(cfg), shape=(b, s + 1))
    # overlay copy-structure: with prob repeat_p, token t = token t-k
    rep = jax.random.bernoulli(kr, cfg.repeat_p, (b, s + 1))
    shifted = jnp.roll(base, cfg.repeat_k, axis=1)
    toks = jnp.where(rep, shifted, base).astype(jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    for name, spec in (extras or {}).items():
        kc, sub = jax.random.split(kc)
        batch[name] = jax.random.normal(sub, spec.shape, spec.dtype)
    return batch


def shard_slice(batch, rank: int, world: int):
    """The per-Granule slice of a global batch (rank-addressed, stable
    across migration: slices depend only on (rank, world))."""
    def one(x):
        per = x.shape[0] // world
        return x[rank * per:(rank + 1) * per]
    return jax.tree.map(one, batch)


@dataclasses.dataclass
class Cursor:
    """The *only* pipeline state — goes into every snapshot/checkpoint."""
    step: int = 0

    def advance(self) -> "Cursor":
        return Cursor(self.step + 1)
