"""Control points (paper §3.2) for the training/serving runtime.

Faabric interrupts applications at syscalls/API calls; a JAX training job's
natural interruption point is the **step boundary** — the gradient
all-reduce already synchronises the gang, so it is a barrier control point
with no in-flight messages (paper §5.2's precondition for migration).

``ControlPointRunner`` is consulted by the runtime loop at every step
boundary (via ``GangHandle.control_point``) and may emit actions:

    checkpoint   periodic / incremental snapshot
    migrate      consolidate a fragmented gang (locality)
    rescale      grow/shrink the data-parallel world (elasticity;
                 routed through the gang handle's shared engine)
    recover      gang-restart from the last snapshot after a failure

``Action`` is the shared vocabulary of the whole scheduling stack: the
trace simulator logs its start/preempt/resume/migrate/finish decisions
as the same records, so simulated and live schedules diff directly.

Straggler mitigation: an EWMA of step times flags steps slower than
``straggler_factor`` x the moving average; persistent stragglers trigger a
migrate action (the paper's locality argument applied to slow hosts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core import telemetry


def _plain(value: Any) -> Any:
    """Coerce numpy scalars/arrays (and tuples) to plain Python."""
    if isinstance(value, dict):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    item = getattr(value, "item", None)
    if item is not None and getattr(value, "shape", None) == ():
        return item()
    tolist = getattr(value, "tolist", None)
    if tolist is not None:
        return _plain(tolist())
    return value


@dataclasses.dataclass
class Action:
    kind: str                      # checkpoint | migrate | rescale | recover
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-plain dict: payload values coerced to Python scalars."""
        return {"kind": self.kind, "payload": _plain(self.payload)}

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "Action":
        return cls(kind=data["kind"], payload=dict(data.get("payload", {})))


class EwmaStragglerDetector:
    """Flags steps slower than factor x EWMA; K consecutive flags fire."""

    def __init__(self, alpha: float = 0.2, factor: float = 2.0,
                 patience: int = 3):
        self.alpha = alpha
        self.factor = factor
        self.patience = patience
        self.ewma: Optional[float] = None
        self.strikes = 0
        self.flagged = 0

    def observe(self, step_time: float) -> bool:
        if self.ewma is None:
            self.ewma = step_time
            return False
        tel = telemetry.get()
        slow = step_time > self.factor * self.ewma
        # slow steps do not pollute the baseline estimate
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
            self.strikes = 0
            if tel.enabled:
                tel.gauge("straggler.ewma_s", self.ewma)
            return False
        self.strikes += 1
        if self.strikes >= self.patience:
            self.strikes = 0
            self.flagged += 1
            if tel.enabled:
                tel.count("straggler.flagged")
                tel.gauge("straggler.ewma_s", self.ewma)
                tel.instant("straggler.flag", track="control",
                            ewma_s=self.ewma, step_time_s=step_time)
            return True
        return False


class ControlPointRunner:
    """Evaluates triggers at step-boundary control points."""

    def __init__(self, checkpoint_every: int = 100,
                 straggler: Optional[EwmaStragglerDetector] = None,
                 failure_probe: Optional[Callable[[], bool]] = None,
                 elastic_probe: Optional[Callable[[int], Optional[int]]] = None):
        self.checkpoint_every = checkpoint_every
        self.straggler = straggler or EwmaStragglerDetector()
        self.failure_probe = failure_probe
        self.elastic_probe = elastic_probe
        self.history: List[Action] = []
        self.straggler_migrations = 0

    def on_step(self, step: int, step_time: float,
                world_size: int) -> List[Action]:
        actions: List[Action] = []
        if self.failure_probe is not None and self.failure_probe():
            actions.append(Action("recover", {"step": step}))
            self._log(actions)
            return actions          # recovery preempts everything else
        if self.checkpoint_every and step > 0 \
                and step % self.checkpoint_every == 0:
            actions.append(Action("checkpoint", {"step": step}))
        if self.straggler.observe(step_time):
            self.straggler_migrations += 1
            tel = telemetry.get()
            if tel.enabled:
                tel.count("straggler.migrations")
            actions.append(Action("migrate", {"reason": "straggler",
                                              "step": step}))
        if self.elastic_probe is not None:
            new_world = self.elastic_probe(world_size)
            if new_world is not None and new_world != world_size:
                actions.append(Action("rescale", {"from": world_size,
                                                  "to": new_world,
                                                  "step": step}))
        self._log(actions)
        return actions

    def _log(self, actions: List[Action]) -> None:
        self.history.extend(actions)
