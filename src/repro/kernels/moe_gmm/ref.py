"""Pure-jnp oracle for the fused expert-FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, w2, w3, *, act: str = "silu"):
    """x: (E, M, d); w1/w3: (E, d, ff); w2: (E, ff, d)."""
    xf = x.astype(jnp.float32)
    h = jnp.einsum("emd,edf->emf", xf, w1.astype(jnp.float32))
    if act == "silu":
        up = jnp.einsum("emd,edf->emf", xf, w3.astype(jnp.float32))
        h = jax.nn.silu(h) * up
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("emf,efd->emd", h, w2.astype(jnp.float32))
    return y.astype(x.dtype)
