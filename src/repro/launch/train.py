"""End-to-end training launcher.

Runs the Faabric gang runtime (``runtime.train_loop``) on the host fabric:
every local device is a Granule; gradients sync with the paper's
hierarchical collective schedule; control points handle checkpointing,
failure recovery and elastic rescale.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --reduced --steps 100 --sync compressed --pods 2
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import FaabricTrainRuntime, RuntimeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sync", default="hierarchical",
                    choices=["hierarchical", "flat", "ring", "compressed"])
    ap.add_argument("--compress-frac", type=float, default=0.05)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (recovery demo)")
    ap.add_argument("--rescale", default="",
                    help="step:world pairs, e.g. '20:4,40:8'")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    rescale = {}
    if args.rescale:
        for pair in args.rescale.split(","):
            s, w = pair.split(":")
            rescale[int(s)] = int(w)
    rt = RuntimeConfig(
        total_steps=args.steps, sync_mode=args.sync,
        compress_frac=args.compress_frac, pods=args.pods,
        checkpoint_every=args.checkpoint_every, ckpt_dir=args.ckpt_dir,
        inject_failures=({args.fail_at: "cli"} if args.fail_at >= 0 else {}),
        rescale_at=rescale)

    runtime = FaabricTrainRuntime(cfg, ocfg, dcfg, rt)
    print(f"arch={args.arch} devices={len(runtime.devices)} "
          f"mesh={dict(runtime.mesh.shape)} sync={args.sync}")
    t0 = time.time()
    _, out = runtime.run(seed=args.seed)
    dt = time.time() - t0
    losses = out["losses"]
    print(json.dumps({
        "first_loss": round(losses[0], 4), "last_loss": round(losses[-1], 4),
        "steps": len(losses), "recoveries": out["recoveries"],
        "rescales": out["rescales"], "wall_s": round(dt, 1),
        "tokens_per_s": round(args.global_batch * args.seq_len
                              * len(losses) / dt, 1)}, indent=1))


if __name__ == "__main__":
    main()
