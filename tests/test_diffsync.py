"""Property-based tests (hypothesis, with example fallback) for the
byte-wise diff protocol — Table 3 merge-op algebra and diff/apply
invariants (paper §4)."""
import jax
import numpy as np

import _hyp_compat as hc
from repro.core import diffsync as D


def _arr(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).normal(
        size=n).astype(np.float32) + 2.0


def _arrays(st):
    return st.integers(1, 4000).flatmap(
        lambda n: st.builds(
            lambda seed: np.random.default_rng(seed).normal(
                size=n).astype(np.float32) + 2.0,
            st.integers(0, 2 ** 16)))


_EXAMPLE_ARRAYS = [_arr(1, 0), _arr(7, 1), _arr(400, 2), _arr(4000, 3)]


@hc.hyp_or_examples(
    lambda st: (_arrays(st), st.integers(0, 2 ** 16)),
    examples=[(a, s) for s, a in enumerate(_EXAMPLE_ARRAYS)])
def test_sum_merge_is_grad_accumulation(a0, seed):
    """A1 = A0 + (B1 - B0): merging N children == summing their deltas."""
    rng = np.random.default_rng(seed)
    b0 = a0.copy()
    deltas = [np.zeros_like(a0) for _ in range(3)]
    for d in deltas:
        idx = rng.integers(0, a0.size, size=max(1, a0.size // 7))
        d[idx] = rng.normal(size=idx.size).astype(np.float32)
    main = a0.copy()
    for d in deltas:
        main = D.apply_leaf(main, D.diff_leaf(b0, b0 + d, op="sum"))
    np.testing.assert_allclose(main, a0 + sum(deltas), atol=1e-5)


@hc.hyp_or_examples(lambda st: (_arrays(st),), examples=_EXAMPLE_ARRAYS)
def test_overwrite_roundtrip(a0):
    """diff(old, new) applied to old reproduces new exactly."""
    rng = np.random.default_rng(1)
    new = a0.copy()
    idx = rng.integers(0, a0.size, size=max(1, a0.size // 5))
    new[idx] += 1.0
    d = D.diff_leaf(a0, new, op="overwrite")
    np.testing.assert_array_equal(D.apply_leaf(a0, d), new)


@hc.hyp_or_examples(lambda st: (_arrays(st),), examples=_EXAMPLE_ARRAYS)
def test_clean_state_empty_diff(a0):
    d = D.diff_leaf(a0, a0.copy())
    assert d.idx.size == 0
    np.testing.assert_array_equal(D.apply_leaf(a0, d), a0)


@hc.hyp_or_examples(
    lambda st: (_arrays(st), st.sampled_from(["sum", "subtract"])),
    examples=[(_EXAMPLE_ARRAYS[1], "sum"), (_EXAMPLE_ARRAYS[2], "subtract"),
              (_EXAMPLE_ARRAYS[3], "sum")])
def test_sum_subtract_inverse(a0, op):
    """subtract(A0, B0, B1) == sum(A0, B1, B0): Table 3 algebra."""
    rng = np.random.default_rng(2)
    b0 = a0.copy()
    b1 = b0 + rng.normal(size=a0.shape).astype(np.float32)
    via_sub = D.apply_leaf(a0, D.diff_leaf(b0, b1, op="subtract"))
    via_sum = D.apply_leaf(a0, D.diff_leaf(b1, b0, op="sum"))
    np.testing.assert_allclose(via_sub + via_sum, 2 * a0, atol=1e-4)


@hc.hyp_or_examples(lambda st: (st.integers(0, 2 ** 16),),
                    examples=[0, 7, 12345], max_examples=30)
def test_multiply_merge(seed):
    rng = np.random.default_rng(seed)
    a0 = rng.uniform(1, 2, 2048).astype(np.float32)
    b0 = rng.uniform(1, 2, 2048).astype(np.float32)
    scale = rng.uniform(0.5, 2.0)
    b1 = (b0 * scale).astype(np.float32)
    merged = D.apply_leaf(a0, D.diff_leaf(b0, b1, op="multiply"))
    np.testing.assert_allclose(merged, a0 * scale, rtol=1e-4)


@hc.hyp_or_examples(lambda st: (st.integers(0, 2 ** 16),),
                    examples=[1, 42, 65535], max_examples=20)
def test_tree_diff_only_ships_dirty_bytes(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": rng.normal(size=(64, 64)).astype(np.float32),
            "b": rng.normal(size=(10,)).astype(np.float32)}
    new = {"a": tree["a"].copy(), "b": tree["b"].copy()}
    new["a"][0, 0] += 1.0
    diffs = D.diff_tree(tree, new)
    assert len(diffs) == 1                    # only leaf 'a' is dirty
    assert D.diff_nbytes(diffs) < tree["a"].nbytes + tree["b"].nbytes
    merged = D.apply_tree(tree, diffs)
    np.testing.assert_array_equal(merged["a"], new["a"])
    np.testing.assert_array_equal(merged["b"], tree["b"])


def test_dense_diff_matches_sparse():
    rng = np.random.default_rng(0)
    old = rng.normal(size=5000).astype(np.float32)
    new = old.copy()
    new[100:200] += 1.5
    import jax.numpy as jnp
    mask, delta = jax.jit(D.dense_diff)(jnp.asarray(old), jnp.asarray(new))
    sparse = D.diff_leaf(old, new, op="sum")
    np.testing.assert_array_equal(np.nonzero(np.asarray(mask))[0],
                                  sparse.idx)
    merged = jax.jit(lambda m, ms, p: D.dense_merge(m, ms, p, op="sum"))(
        jnp.asarray(old), mask, delta)
    np.testing.assert_allclose(np.asarray(merged), new, atol=1e-6)
