"""Trace launcher: execute a multi-tenant arrival trace for real.

Replays an arrival-time trace — Poisson arrivals, priority classes,
preemption — through ``core.fabric.Fabric.run_trace``: real concurrent
train/serve gangs share the CPU host fabric, scheduled by the same
event loop and placement engine the discrete-event simulator uses, and
the live per-job completion order is compared against the simulator's
prediction for the same trace.

``--churn`` overlays a fleet-churn regime (``core.fleet``): hosts lease
in and out mid-trace — spot reclaims drain and evacuate live gangs,
hard failures roll gangs back to their last snapshot (bit-exact
resume), and joins pull staged spare devices into the pool.  Composes
with ``--sched sharded`` (incl. ``--shard-hosts auto``) and
``--host-regime mixed-gen``.  ``--risk-aware`` adds the CostModel risk
term (placement spreads away from short-lease / flaky / blast-
correlated hosts) and shrink-before-rollback recovery; ``--adapt-
cadence`` folds measured delta-checkpoint bytes back into the live
Young/Daly interval (DESIGN.md §13).

Example:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.trace --jobs 6 \
        --arrival-rate 0.05 --chips-per-host 2 --seed 0 \
        --churn spot-heavy --checkpoint-interval 8
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.registry import reduced_config
from repro.core import fleet as fleet_mod
from repro.core import simulator as sim
from repro.core import telemetry
from repro.core.fabric import Fabric
from repro.core.placement import derive_capacities
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.gang_workloads import workload_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chips-per-host", type=int, default=2)
    ap.add_argument("--policy", default="binpack",
                    choices=["binpack", "spread", "locality"])
    ap.add_argument("--arrival-rate", type=float, default=0.05)
    ap.add_argument("--arrival-regime", default="poisson",
                    choices=list(sim.ARRIVAL_REGIMES),
                    help="open-loop arrival process for the trace "
                         "(poisson, diurnal sinusoid, or on/off burst)")
    ap.add_argument("--no-preempt", action="store_true")
    ap.add_argument("--train-steps", type=int, default=3)
    ap.add_argument("--serve-tokens", type=int, default=3)
    ap.add_argument("--host-regime", default="uniform",
                    choices=["uniform", "mixed-gen"],
                    help="mixed-gen models half the hosts as an older "
                         "generation at s=0.5 (CostModel speeds)")
    ap.add_argument("--sched", default="central",
                    choices=["central", "sharded"],
                    help="scheduler architecture: one engine scanning "
                         "every host, or host-group shards with summary-"
                         "index forwarding (the Fig 11 fix)")
    ap.add_argument("--shard-hosts", default=None,
                    help="hosts per shard for --sched sharded: an int, "
                         "or 'auto' for adaptive sizing that re-balances "
                         "under churn (default: "
                         "placement.DEFAULT_SHARD_HOSTS)")
    ap.add_argument("--steal-budget", type=int, default=0,
                    help="cap on cross-shard split/escalation attempts "
                         "per queue pump (0 = unbounded)")
    ap.add_argument("--churn", default="none",
                    choices=("none",) + fleet_mod.CHURN_REGIMES,
                    help="fleet-churn regime overlaid on the trace "
                         "(core.fleet.churn_schedule)")
    ap.add_argument("--churn-rate", type=float, default=0.02,
                    help="disruptive-event rate (events/s) for the "
                         "Poisson churn regimes")
    ap.add_argument("--drain-s", type=float,
                    default=fleet_mod.DEFAULT_DRAIN_S,
                    help="drain window for lease reclaims")
    ap.add_argument("--checkpoint-interval", type=float, default=None,
                    help="periodic checkpoint cadence in virtual "
                         "seconds (default: Young/Daly from the churn "
                         "rate when churn is on, else off)")
    ap.add_argument("--ckpt-delta-fraction", type=float, default=None,
                    help="configured cost of a delta checkpoint as a "
                         "fraction of a full one (CostModel."
                         "ckpt_delta_fraction); enables delta-chain "
                         "charging and tightens the Young/Daly cadence. "
                         "Default: full-cost checkpoints")
    ap.add_argument("--ckpt-rebase-every", type=int, default=8,
                    help="full rebase every N checkpoints when delta "
                         "checkpointing is configured (bounds the "
                         "recovery replay chain)")
    ap.add_argument("--risk-aware", action="store_true",
                    help="risk-aware placement + shrink-before-rollback "
                         "(DESIGN.md §13): the CostModel risk term "
                         "steers gangs away from short-lease / flaky / "
                         "blast-correlated hosts (risk_tau_s = the "
                         "checkpoint cadence), and stranded gangs "
                         "reshard onto surviving capacity before any "
                         "checkpoint rollback")
    ap.add_argument("--adapt-cadence", action="store_true",
                    help="re-derive the live Young/Daly checkpoint "
                         "interval from measured delta bytes after each "
                         "rebase window (live only; Action logs then "
                         "diverge from the prediction by design)")
    ap.add_argument("--emit-trace", metavar="PATH", default=None,
                    help="record telemetry and write a Chrome trace-"
                         "event JSON (Perfetto-loadable) to PATH; a "
                         "metrics summary (with the predicted-vs-live "
                         "diff_traces report) lands next to it at "
                         "PATH + '.summary.json'")
    args = ap.parse_args()

    tel = (telemetry.enable() if args.emit_trace else telemetry.get())

    all_devices = list(jax.devices())
    # churn regimes with joins draw from staged spares: generate the
    # schedule against the reduced starting fleet, hold back the devices
    # its joins will need
    fleet_events = None
    spares = []
    devices = all_devices
    hosts0 = 0
    # one horizon for the churn schedule AND the Young/Daly estimate
    horizon = max(60.0, args.jobs / max(args.arrival_rate, 1e-6))
    if args.churn != "none":
        # spares must back every join: the regimes reclaim at most half
        # the starting fleet (like-for-like rejoins), so a third of the
        # devices staged as spares always suffices
        total_hosts = max(1, len(all_devices) // args.chips_per_host)
        n_spare_hosts = min(total_hosts - 1, -(-total_hosts // 3))
        n_spare = max(0, n_spare_hosts) * args.chips_per_host
        devices = all_devices[:len(all_devices) - n_spare]
        assert devices, "fleet too small for churn spares"
        hosts0 = len(derive_capacities(len(devices),
                                       args.chips_per_host))
        fleet_events = fleet_mod.churn_schedule(
            args.churn, hosts0, args.chips_per_host, horizon,
            seed=args.seed, rate=args.churn_rate, drain_s=args.drain_s)
        # drop joins the spare pool cannot back
        budget, kept = n_spare, []
        for ev in fleet_events:
            if ev.kind == "join":
                need = sum(ev.capacities)
                if need > budget:
                    continue
                budget -= need
            kept.append(ev)
        fleet_events = kept
        spares = all_devices[len(devices):]

    speeds = None
    if args.host_regime == "mixed-gen":
        n_hosts = len(derive_capacities(len(devices),
                                        args.chips_per_host))
        speeds = sim.hetero_speeds(n_hosts)
    shard_hosts = None
    if args.sched == "sharded":
        from repro.core.placement import DEFAULT_SHARD_HOSTS
        raw = args.shard_hosts
        shard_hosts = ("auto" if raw == "auto"
                       else int(raw) if raw else DEFAULT_SHARD_HOSTS)
    fabric = Fabric(devices=devices, chips_per_host=args.chips_per_host,
                    policy=args.policy, speeds=speeds,
                    shard_hosts=shard_hosts,
                    steal_budget=args.steal_budget, spares=spares)
    n_chips = fabric.engine.total_chips
    cost_model = fabric.engine.cost_model
    if args.ckpt_delta_fraction is not None:
        # delta checkpointing: both predicted and live traces charge
        # the configured fraction (Action logs stay identical), and
        # Young/Daly below consumes the cheaper amortised cost
        cost_model.ckpt_delta_fraction = args.ckpt_delta_fraction
        cost_model.ckpt_rebase_every = max(1, args.ckpt_rebase_every)
    ckpt_interval = args.checkpoint_interval
    if ckpt_interval is None and fleet_events:
        mtbf = fleet_mod.churn_mtbf(fleet_events, horizon, hosts=hosts0)
        tau = fleet_mod.optimal_checkpoint_interval(
            mtbf, cost_model=cost_model)
        ckpt_interval = None if tau == float("inf") else tau
    if args.risk_aware:
        # the risk term's expected-lost-work scale is the gang
        # checkpoint cadence; with no cadence a failure forfeits the
        # run, so the horizon stands in
        cost_model.risk_tau_s = (ckpt_interval if ckpt_interval
                                 is not None else horizon)
    # mixed train/serve trace sized to the local fabric, two priority
    # classes (9:1 high) — the §2.1 shared-cluster economics, live
    jobs = sim.mixed_trace(args.jobs, seed=args.seed,
                           chips_per_host=args.chips_per_host,
                           arrival_rate=args.arrival_rate,
                           priority_classes=[(0, 0.9), (5, 0.1)],
                           arrival_regime=args.arrival_regime)
    # under churn, cap gang sizes at half the starting fleet (the churn
    # generator never touches more than half the hosts, so every job
    # stays schedulable through the deepest reclaim trough)
    cap = n_chips if args.churn == "none" else max(2, n_chips // 2)
    for job in jobs:
        job.parallelism = max(2, min(job.parallelism, cap))

    cfg = reduced_config(args.arch).with_(n_layers=1, vocab=128)
    dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8,
                      seed=args.seed)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)

    preempt = not args.no_preempt
    predicted = fabric.predict_trace(jobs, preempt=preempt,
                                     fleet_events=fleet_events,
                                     checkpoint_interval=ckpt_interval,
                                     shrink_recovery=args.risk_aware)
    ex = fabric.run_trace(
        jobs, workload_factory(cfg, ocfg, dcfg,
                               train_steps=args.train_steps,
                               serve_tokens=args.serve_tokens),
        preempt=preempt, fleet_events=fleet_events,
        checkpoint_interval=ckpt_interval,
        shrink_recovery=args.risk_aware,
        adapt_cadence=args.adapt_cadence)
    live = ex.result
    diff = telemetry.diff_traces(predicted, live)
    if args.emit_trace:
        tel.write_chrome_trace(args.emit_trace)
        summary = tel.summary()
        summary["diff_traces"] = diff
        summary["observed_step_times"] = {
            f"{hk}/{jk}": {"count": n, "mean_s": mean}
            for (hk, jk), (n, mean)
            in sorted(cost_model.observed_step_times().items())}
        with open(args.emit_trace + ".summary.json", "w") as f:
            json.dump(telemetry._plain(summary), f, indent=1,
                      sort_keys=True)
    print(json.dumps({
        "devices": len(fabric.devices),
        "hosts": fabric.engine.hosts,
        "sched": args.sched,
        "shard_hosts": (None if shard_hosts is None
                        else fabric.engine.hosts_per_shard),
        "steal_budget": args.steal_budget,
        "host_speeds": (None if fabric.engine.speeds is None
                        else list(fabric.engine.speeds)),
        "jobs": len(jobs),
        "arrival_regime": args.arrival_regime,
        "churn": args.churn,
        "churn_events": 0 if not fleet_events else len(fleet_events),
        "checkpoint_interval_s": (None if ckpt_interval is None
                                  else round(ckpt_interval, 2)),
        "ckpt_delta_fraction": args.ckpt_delta_fraction,
        "delta_checkpoints": sum(r.get("delta_checkpoints", 0)
                                 for r in ex.live.values()),
        "ckpt_bytes_shipped": sum(r.get("ckpt_bytes", 0)
                                  for r in ex.live.values()),
        "ckpt_bytes_full_equiv": sum(r.get("ckpt_full_bytes", 0)
                                     for r in ex.live.values()),
        "observed_delta_fraction": (
            None if cost_model.observed_delta_fraction() is None
            else round(cost_model.observed_delta_fraction(), 4)),
        "predicted_order": predicted.finish_order,
        "live_order": live.finish_order,
        "order_matches": live.finish_order == predicted.finish_order,
        "diff_divergences": diff["divergences"],
        "emit_trace": args.emit_trace,
        "risk_aware": args.risk_aware,
        "adapt_cadence": args.adapt_cadence,
        "preemptions": live.preemptions,
        "recoveries": live.recoveries,
        "evacuations": live.evacuations,
        "shrinks": live.shrinks,
        "regrows": live.regrows,
        "lost_work_s": round(live.lost_work_s, 2),
        "virtual_makespan_s": round(live.makespan, 2),
        "per_job_makespan_s": {k: round(v, 2)
                               for k, v in ex.job_makespans(jobs).items()},
        "live_steps": {k: rec.get("steps", 0)
                       for k, rec in ex.live.items()},
        "resumes_verified": sum(r.get("resumes_verified", 0)
                                for r in ex.live.values()),
        "wall_s": round(ex.wall_s, 1)}, indent=1))


if __name__ == "__main__":
    main()
