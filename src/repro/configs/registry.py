"""--arch <id> registry: resolves architecture ids to ArchConfig objects."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, SHAPES, cell_applicable

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6p6b",
    "llama3.2-1b": "llama32_1b",
    "llama3.2-3b": "llama32_3b",
    "glm4-9b": "glm4_9b",
    "minitron-4b": "minitron_4b",
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-1.3b": "xlstm_1p3b",
    "whisper-small": "whisper_small",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced_config(arch_id: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (small layers/width/experts)."""
    cfg = get_config(arch_id)
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        remat=False,
        scan_layers=False,
        dtype="float32",  # CPU backend cannot execute bf16 dots
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, moe_d_ff=64)
    if cfg.family == "hybrid":
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32,
                  shared_attn_every=2, n_layers=4)
    if cfg.family == "ssm":
        kw.update(slstm_every=2, n_layers=4)
    if cfg.family == "audio":
        kw.update(n_enc_layers=2, enc_seq=32)
    if cfg.family == "vlm":
        kw.update(cross_attn_every=2, n_img_tokens=16)
    return cfg.with_(**kw)


def iter_cells():
    """Yield every assigned (arch, shape, applicable, reason) cell - 40 total."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            yield arch_id, shape, ok, reason
