"""Paper Fig 10: 100-job traces (mpi + omp) on a 32-host shared cluster.

Reports makespan per policy, median idle-chip fraction, and job execution
time percentiles — Faabric's chip-granular Granule scheduling vs the
fixed-slice (k-containers-per-VM) baselines.
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator as S


def run(report):
    for kind, paper_note in (("mpi-compute", "Fig10a mpi"),
                             ("omp", "Fig10b omp")):
        jobs = S.generate_trace(100, kind, seed=0)
        res = S.run_baselines(jobs, hosts=32)
        fa = res["faabric"].makespan
        for name, r in res.items():
            report(f"makespan/{kind}/{name}", round(r.makespan, 1), "s",
                   paper_note)
            report(f"idle_median/{kind}/{name}",
                   round(float(np.median(r.idle_cdf())), 3), "frac",
                   paper_note)
            report(f"exec_p50/{kind}/{name}",
                   round(float(np.percentile(r.exec_times, 50)), 1), "s",
                   paper_note)
        for name, r in res.items():
            if name != "faabric":
                report(f"faabric_vs/{kind}/{name}",
                       round((r.makespan - fa) / r.makespan * 100, 1),
                       "% lower makespan", paper_note)
        report(f"migrations/{kind}", res["faabric"].migrations, "count",
               paper_note)
