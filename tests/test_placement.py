"""PlacementEngine invariants: migration-plan edge cases, preemption-safe
reservations, policy behaviour, the shared CostModel (per-host speeds +
per-job-kind beta), and the multi-tenant simulator semantics (arrival
times, priority classes, backfill) built on top of it."""
import hashlib

import numpy as np
import pytest

from repro.core import simulator as S
from repro.core.elastic import ElasticPolicy
from repro.core.placement import (BinpackPolicy, CostModel,
                                  FixedSlicePolicy, LocalityScoredPolicy,
                                  PlacementEngine, derive_capacities,
                                  placement_cross_host_fraction,
                                  resolve_policy)


# ---------------------------------------------------------------------------
# migration planning
# ---------------------------------------------------------------------------
def test_overlapping_migration_plans_do_not_double_book():
    """Two fragmented gangs whose naive consolidation targets the same
    host: plans are committed against a scratch free map, so applying
    every emitted plan must keep each host within capacity."""
    eng = PlacementEngine(2, 6)
    a = eng.bind("A", [(0, 2), (1, 2)])
    b = eng.bind("B", [(0, 2), (1, 2)])
    plans = dict(eng.migration_plan([a, b]))
    assert set(plans) == {"A", "B"}
    # both consolidate to a single host — but not the same one
    hosts_a = [h for h, _ in plans["A"]]
    hosts_b = [h for h, _ in plans["B"]]
    assert len(hosts_a) == 1 and len(hosts_b) == 1
    assert hosts_a != hosts_b
    for alloc, jid in ((a, "A"), (b, "B")):
        alloc = eng.apply_migration(alloc, plans[jid])
        assert alloc.fragmentation() == 1
    assert (eng.free >= 0).all()
    assert (eng.free <= eng.chips_per_host).all()
    assert eng.idle_chips() == eng.total_chips - 8


def test_slice_allocations_are_never_migrated():
    eng = PlacementEngine(2, 8)
    blockers = [eng.allocate(f"b{i}", 4) for i in range(2)]
    sliced = eng.allocate("s", 8, policy=FixedSlicePolicy(4))
    assert sliced.slice_size == 4
    assert sliced.fragmentation() == 2       # forced across both hosts
    for blk in blockers:
        eng.release(blk)
    # consolidation would now be possible, but slices must stay put
    assert eng.migration_plan([sliced]) == []


def test_plan_that_frees_zero_hosts_is_not_emitted():
    eng = PlacementEngine(2, 8)
    gang = eng.bind("g", [(0, 6), (1, 6)])
    # 12 chips cannot fit on one 8-chip host: any re-placement still
    # spans 2 hosts, i.e. frees nothing — no plan
    assert eng.migration_plan([gang]) == []


def test_migration_plan_consolidates_when_hosts_free_up():
    eng = PlacementEngine(2, 8)
    blockers = [eng.allocate(f"b{i}", 6) for i in range(2)]
    gang = eng.allocate("g", 4)              # 2 free chips on each host
    assert gang.fragmentation() == 2
    for blk in blockers:
        eng.release(blk)
    plans = eng.migration_plan([gang])
    assert plans and plans[0][0] == "g"
    new = eng.apply_migration(gang, plans[0][1])
    assert new.fragmentation() == 1 and new.n == 4


# ---------------------------------------------------------------------------
# reservations (preemption-safe allocation handshake)
# ---------------------------------------------------------------------------
def test_reservation_holds_chips_until_settled():
    eng = PlacementEngine(2, 4)
    res = eng.reserve(6)
    assert res is not None and res.n == 6
    assert eng.idle_chips() == 2
    # a competing allocation cannot steal the reserved chips
    assert eng.allocate("thief", 4) is None
    eng.cancel(res)
    assert eng.idle_chips() == 8
    assert eng.allocate("thief", 4) is not None


def test_reservation_commit_binds_job():
    eng = PlacementEngine(2, 4)
    res = eng.reserve(3)
    alloc = eng.commit(res, "j")
    assert alloc.n == 3 and eng.allocations["j"] is alloc
    assert any("j" in s for s in eng.jobs_on_host)
    with pytest.raises(AssertionError):
        eng.commit(res, "j2")                # already settled
    eng.release(alloc)
    assert eng.idle_chips() == 8 and "j" not in eng.allocations


def test_bind_rejects_oversubscription():
    eng = PlacementEngine(1, 4)
    eng.bind("a", [(0, 3)])
    with pytest.raises(AssertionError):
        eng.bind("b", [(0, 2)])


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def test_resolve_policy_rejects_unknown():
    with pytest.raises(ValueError):
        resolve_policy("fifo")


def test_locality_prefers_best_fit_host():
    # free = [8, 3]: binpack (most-free-first) puts a 3-gang on host 0,
    # stranding 5 chips there; locality picks the exact-fit host 1
    eng = PlacementEngine(2, 8)
    eng.bind("t", [(1, 5)])
    view = eng.view()
    assert BinpackPolicy().place(view, 3) == [(0, 3)]
    assert LocalityScoredPolicy().place(view, 3) == [(1, 3)]


def test_locality_minimises_cross_host_fraction_when_split():
    # free = [4, 3, 3], n = 6: greedy most-free-first takes 4+2; a 3+3
    # split has higher chi, so locality must also choose 4+2 — and place
    # the remainder on a best-fit host
    eng = PlacementEngine(3, 4)
    eng.bind("t", [(1, 1), (2, 1)])
    pl = LocalityScoredPolicy().place(eng.view(), 6)
    sizes = sorted(c for _, c in pl)
    assert sizes == [2, 4]


def test_locality_beats_binpack_mean_chi_on_fragmented_trace():
    """Acceptance: strictly lower mean cross_host_fraction than binpack
    on a fragmented 100-job mixed trace."""
    jobs = S.mixed_trace(100, seed=7)
    bp = S.Simulator(16, 8, "granular", migrate=False,
                     policy="binpack").run(jobs)
    lc = S.Simulator(16, 8, "granular", migrate=False,
                     policy="locality").run(jobs)
    assert len(bp.exec_times) == 100 and len(lc.exec_times) == 100
    assert lc.mean_cross_host_fraction() < bp.mean_cross_host_fraction()


# ---------------------------------------------------------------------------
# CostModel: the one job-time model every layer consumes
# ---------------------------------------------------------------------------
def test_cost_model_equation_and_per_kind_beta():
    m = CostModel()
    pl = [(0, 4), (1, 4)]
    chi = placement_cross_host_fraction(pl)
    assert chi == pytest.approx(0.5)
    assert m.beta("mpi-network") == 13.0 and m.beta("mpi-compute") == 0.4
    assert m.beta(None) == m.beta("unknown-kind") == 0.4
    assert m.slowdown(pl, "omp") == pytest.approx(1.0 + 1.0 * chi)
    # homogeneous: T = (W/n)(1 + beta*chi)
    assert m.predicted_time(80.0, pl, "mpi-compute") == pytest.approx(
        80.0 / 8 * (1 + 0.4 * chi))
    # mixed generations: the scaling term is speed-weighted sum n_h*s_h
    speeds = np.array([0.5, 1.0])
    assert m.effective_parallelism(pl, speeds) == pytest.approx(6.0)
    assert m.predicted_time(60.0, pl, "omp", speeds) == pytest.approx(
        60.0 / 6 * (1 + 1.0 * chi))
    # active-worker cap (OMP overcommit) scales the effective sum
    assert m.effective_parallelism(pl, speeds, active=4) \
        == pytest.approx(3.0)
    assert m.active_workers(16, 8, shared_memory=True) == 8
    assert m.active_workers(16, 8, shared_memory=False) == 16
    assert m.migration_worthwhile(0.8) and not m.migration_worthwhile(0.81)


def test_derive_capacities_is_the_single_host_map():
    assert derive_capacities(10, 4) == [4, 4, 2]
    assert derive_capacities(8, 4) == [4, 4]
    assert derive_capacities(1, 4) == [1]
    eng = PlacementEngine.for_chips(10, 4)
    assert eng.hosts == 3 and list(eng.capacities) == [4, 4, 2]
    assert eng.total_chips == 10
    a = eng.allocate("j", 10)
    assert a is not None and a.n == 10
    eng.release(a)


def test_cluster_view_ragged_capacities_and_locality_exact_fit():
    eng = PlacementEngine(3, 4, capacities=[4, 4, 2])
    view = eng.view()
    assert list(view.capacities) == [4, 4, 2]
    assert not view.heterogeneous
    # the ragged 2-chip host is the best fit for a 2-gang: binpack's
    # most-free-first strands chips on a 4-host instead
    assert LocalityScoredPolicy().place(view, 2) == [(2, 2)]
    assert BinpackPolicy().place(view, 2)[0][0] != 2
    # spanning all ragged hosts still conserves chips
    a = eng.allocate("j", 10)
    assert a.n == 10 and eng.idle_chips() == 0
    eng.release(a)
    assert eng.idle_chips() == 10


def test_locality_stranded_chip_tie_breaking():
    # free = [4, 4, 3], n = 6: plain greedy takes a 4-host + 2 from the
    # other 4-host (chunks 4+2, strands 2); exact-fill finishes the
    # remainder on the best-fit 3-host (same chunks -> equal chi, but
    # strands only 1).  The stranded tie-break must pick the latter.
    eng = PlacementEngine(3, 4, capacities=[4, 4, 3])
    pl = LocalityScoredPolicy().place(eng.view(), 6)
    assert pl == [(0, 4), (2, 2)]


def test_uniform_speeds_keep_the_homogeneous_path():
    # all hosts at the same (non-1) speed rank placements exactly like
    # the homogeneous case: `heterogeneous` stays False
    eng = PlacementEngine(2, 8, speeds=[0.5, 0.5])
    assert not eng.heterogeneous and not eng.view().heterogeneous
    assert eng.idle_throughput() == pytest.approx(8.0)
    het = PlacementEngine(2, 8, speeds=[0.5, 1.0])
    assert het.heterogeneous and het.view().heterogeneous
    assert het.idle_throughput() == pytest.approx(12.0)


# ---------------------------------------------------------------------------
# heterogeneous fleets (per-host speeds through the CostModel)
# ---------------------------------------------------------------------------
def test_hetero_per_kind_beta_drives_locality_placement():
    # one big slow-generation host vs two small fast hosts
    eng = PlacementEngine(3, 8, capacities=[8, 4, 4],
                          speeds=[0.5, 1.0, 1.0])
    pol = LocalityScoredPolicy()
    # network-bound (beta 13): fragmenting costs 7.5x, so co-location on
    # the slow host wins: T = 1/(8*0.5) = 0.25 < (1+13*0.5)/8 = 0.94
    assert pol.place(eng.view(), 8, kind="mpi-network") == [(0, 8)]
    # compute-bound (beta 0.4): the fast split wins:
    # (1+0.4*0.5)/8 = 0.15 < 0.25
    assert pol.place(eng.view(), 8, kind="mpi-compute") == [(1, 4), (2, 4)]


def test_hetero_binpack_prefers_effective_throughput():
    eng = PlacementEngine(2, 8, capacities=[8, 6], speeds=[0.5, 1.0])
    # homogeneous binpack would take the most-free host 0; with speeds
    # the effective free throughput is 4.0 vs 6.0 -> host 1 first
    assert BinpackPolicy().place(eng.view(), 6) == [(1, 6)]


def test_hetero_migration_moves_gang_to_faster_host():
    eng = PlacementEngine(2, 4, speeds=[0.5, 1.0])
    blocker = eng.allocate("b", 4)          # lands on the fast host
    assert blocker.placement == [(1, 4)]
    gang = eng.allocate("g", 4)             # only the slow host is left
    assert gang.placement == [(0, 4)]
    assert eng.migration_plan([gang]) == []  # fast host still occupied
    eng.release(blocker)
    # a single-fragment gang still migrates when predicted T drops 2x
    plans = eng.migration_plan([gang], kinds={"g": "mpi-compute"})
    assert plans == [("g", [(1, 4)])]
    new = eng.apply_migration(gang, plans[0][1])
    # and once on the fast host there is nothing better: no churn
    assert eng.migration_plan([new], kinds={"g": "mpi-compute"}) == []


def test_custom_cost_model_reaches_resolved_policies():
    # a by-name policy must score with the ENGINE's model, not the
    # shared POLICIES singleton's default: with beta("mpi-network")
    # dropped to 0.5 the fast split beats slow co-location
    model = CostModel(betas={"mpi-compute": 0.4, "mpi-network": 0.5,
                             "omp": 1.0})
    eng = PlacementEngine(3, 8, capacities=[8, 4, 4],
                          speeds=[0.5, 1.0, 1.0], policy="locality",
                          cost_model=model)
    a = eng.allocate("j", 8, kind="mpi-network")
    assert a.placement == [(1, 4), (2, 4)]   # (1+0.5*0.5)/8 < 1/4
    # the shared singleton itself is never mutated
    from repro.core.placement import POLICIES
    assert POLICIES["locality"].cost_model.beta("mpi-network") == 13.0


def test_explicit_policy_instance_keeps_its_own_model():
    # with_model must NOT override an explicitly-configured policy:
    # under its softened beta 0.5 the fast split wins for a
    # network-bound job, even though the engine's default model
    # (beta 13) would co-locate on the slow host
    eng = PlacementEngine(3, 8, capacities=[8, 4, 4],
                          speeds=[0.5, 1.0, 1.0])
    soft = LocalityScoredPolicy(cost_model=CostModel(
        betas={"mpi-compute": 0.4, "mpi-network": 0.5, "omp": 1.0}))
    a = eng.allocate("j", 8, policy=soft, kind="mpi-network")
    assert a.placement == [(1, 4), (2, 4)]
    eng.release(a)
    assert eng.allocate("j2", 8, policy="locality",
                        kind="mpi-network").placement == [(0, 8)]


def test_hetero_migration_is_cost_aware_with_remaining_work():
    def setup():
        eng = PlacementEngine(2, 4, speeds=[0.8, 1.0])
        blocker = eng.allocate("b", 4)          # fast host
        gang = eng.allocate("g", 4)             # slow host
        assert gang.placement == [(0, 4)]
        eng.release(blocker)
        return eng, gang

    # moving 0.8 -> 1.0 saves 20% of the remaining time; with only 5s
    # left that is 1s < migration_cost_s = 2s -> not worth the snapshot
    eng, gang = setup()
    assert eng.migration_plan([gang], kinds={"g": "mpi-compute"},
                              remaining={"g": 5.0}) == []
    # with 100s left the saving is 20s -> migrate
    eng, gang = setup()
    assert eng.migration_plan([gang], kinds={"g": "mpi-compute"},
                              remaining={"g": 100.0}) \
        == [("g", [(1, 4)])]
    # no remaining info (live barrier migration): strict improvement
    eng, gang = setup()
    assert eng.migration_plan([gang], kinds={"g": "mpi-compute"}) \
        == [("g", [(1, 4)])]


def test_simulator_plumbs_kind_beta_and_speeds_into_rate():
    speeds = [0.5, 1.0, 1.0]

    def one(kind):
        eng = PlacementEngine(3, 8, capacities=[8, 4, 4], speeds=speeds,
                              policy="locality")
        r = S.Simulator(3, 8, "granular", migrate=False, policy="locality",
                        engine=eng).run([S.Job("j", kind, 8, 80.0)])
        start = next(a for a in r.actions if a.kind == "start")
        return start.payload["placement"], r.makespan

    pl_net, mk_net = one("mpi-network")
    pl_cmp, mk_cmp = one("mpi-compute")
    sched = S.SCHED_LATENCY_PER_HOST * 3
    # placement AND execution rate come from the same model:
    # network co-located on the slow host: T = 80/(8*0.5) = 20
    assert pl_net == [(0, 8)]
    assert mk_net == pytest.approx(20.0 + sched)
    # compute split over the fast hosts: T = 80*(1+0.4*0.5)/8 = 12
    assert pl_cmp == [(1, 4), (2, 4)]
    assert mk_cmp == pytest.approx(12.0 + sched)


def test_hetero_speeds_regime_and_locality_beats_binpack_makespan():
    """Acceptance: on a mixed-generation fleet (half the hosts at s=0.5)
    the CostModel-scored locality policy beats binpack on mean trace
    makespan (the bench_makespan hetero sweep, abbreviated)."""
    speeds = S.hetero_speeds(16, slow_fraction=0.5, slow=0.5)
    assert list(speeds) == [0.5] * 8 + [1.0] * 8
    mean = {}
    for pol in ("binpack", "locality"):
        mean[pol] = float(np.mean(
            [S.Simulator(16, 8, "granular", migrate=True, policy=pol,
                         speeds=speeds).run(
                             S.mixed_trace(100, seed=s)).makespan
             for s in range(5)]))
    assert mean["locality"] < mean["binpack"]


def test_preemption_plan_fit_probe_sees_speeds_and_kind():
    # free after eviction candidates: the fit probe must run under the
    # hetero view — a network-bound arrival that only fits fragmented
    # across fast hosts still places (plan exists), and the planned
    # placement matches what the engine then allocates
    eng = PlacementEngine(3, 8, capacities=[8, 4, 4],
                          speeds=[0.5, 1.0, 1.0], policy="locality")
    eng.allocate("low", 8, kind="mpi-network")      # takes the slow host
    assert eng.allocations["low"].placement == [(0, 8)]
    plan = eng.preemption_plan(8, 5, {"low": 0}, kind="mpi-network")
    assert plan == []        # already fits: the two fast hosts suffice
    eng.allocate("low2", 8, kind="mpi-compute")     # fast hosts now busy
    plan = eng.preemption_plan(8, 5, {"low": 0, "low2": 0},
                               kind="mpi-network")
    assert plan is not None and len(plan) >= 1


# ---------------------------------------------------------------------------
# homogeneous regression: refactors must not move placement decisions
# ---------------------------------------------------------------------------
# Pinned on the same trace: mixed_trace(60, seed=7) on 16 hosts x 8
# chips, and an arrivals/priorities/preempt/backfill regime.  Exact
# float equality on makespan and mean chi, exact migration/preemption
# counts, exact finish order.  Values re-pinned for the once-per-pump
# scheduler-latency fix (PR 4): the fix moves the clock, which shifts
# event interleaving (and thus some downstream placements) — but the
# placement *code path* is pinned separately: vectorized fills are
# loop-parity-tested action-for-action in test_sharded.py.
_HOMOG_PINS = {
    "binpack": (583.6697118451059, 52, "f34e33226b1e3025",
                0.48322871466089357),
    "spread": (612.7864655186706, 93, "14b0b732a16008b9",
               0.7543071843621572),
    "locality": (581.922851328072, 50, "65de56b3fb7a7f56",
                 0.4579788870253544),
}


def _order_sha(result):
    return hashlib.sha256(
        ",".join(result.finish_order).encode()).hexdigest()[:16]


def test_homogeneous_fleet_bit_identical_to_pre_costmodel_refactor():
    for pol, (mk, migs, sha, chi) in _HOMOG_PINS.items():
        r = S.Simulator(16, 8, "granular", policy=pol).run(
            S.mixed_trace(60, seed=7))
        assert r.makespan == mk, pol
        assert r.migrations == migs and _order_sha(r) == sha
        assert r.mean_cross_host_fraction() == chi


def test_homogeneous_arrival_preempt_regime_bit_identical():
    r = S.Simulator(16, 8, "granular", policy="locality", preempt=True,
                    backfill=True).run(
        S.mixed_trace(60, seed=7, arrival_rate=0.3,
                      priority_classes=[(0, 0.8), (5, 0.2)]))
    assert r.makespan == 626.7690408153892
    assert r.migrations == 66 and r.preemptions == 8
    assert _order_sha(r) == "b53bba2f0bd22744"


# ---------------------------------------------------------------------------
# multi-tenant simulator semantics
# ---------------------------------------------------------------------------
def test_arrival_times_are_respected():
    jobs = S.generate_trace(40, "mpi-compute", seed=5, arrival_rate=0.3)
    assert any(j.arrival > 0 for j in jobs)
    res = S.Simulator(8, 8, "granular").run(jobs)
    assert len(res.exec_times) == 40
    assert all(w >= 0 for w in res.waited)   # no job starts before arrival
    assert res.makespan >= max(j.arrival for j in jobs)


def test_explicit_default_trace_matches_plain_trace():
    jobs = S.generate_trace(50, "mpi-compute", seed=4)
    explicit = [S.Job(j.job_id, j.kind, j.parallelism, j.work,
                      arrival=0.0, priority=0) for j in jobs]
    r1 = S.Simulator(8, 8, "granular").run(jobs)
    r2 = S.Simulator(8, 8, "granular").run(explicit)
    assert r1.makespan == r2.makespan
    assert r1.exec_times == r2.exec_times


def test_priority_class_runs_first():
    # one 8-chip host, both jobs need all of it: the high-priority job
    # submitted second must still run first
    low = S.Job("low", "mpi-compute", 8, 400.0, priority=0)
    high = S.Job("high", "mpi-compute", 8, 800.0, priority=10)
    res = S.Simulator(1, 8, "granular").run([low, high])
    # completion order: high (exec 100s) then low (exec 50s)
    assert res.exec_times[0] == pytest.approx(100.0, rel=1e-6)
    assert res.exec_times[1] == pytest.approx(50.0, rel=1e-6)


def test_backfill_runs_small_job_past_blocked_head():
    j1 = S.Job("j1", "mpi-compute", 6, 600.0)
    j2 = S.Job("j2", "mpi-compute", 8, 800.0)      # blocked head-of-line
    j3 = S.Job("j3", "mpi-compute", 2, 200.0)      # fits beside j1
    fifo = S.Simulator(1, 8, "granular").run([j1, j2, j3])
    bf = S.Simulator(1, 8, "granular", backfill=True).run([j1, j2, j3])
    assert len(bf.exec_times) == 3
    assert bf.makespan < fifo.makespan
    # under backfill, j3 starts immediately (modulo scheduler latency)
    # instead of queueing behind the blocked j2
    assert sorted(bf.waited)[1] < 0.1
    assert sorted(fifo.waited)[1] > 10.0


def test_run_baselines_seed_makespan_ordering():
    """Acceptance: with all arrivals at t=0 and default priority, the
    seed's qualitative ordering holds — faabric beats the coarse slices
    and stays on par with the finest slicing (§6.2)."""
    jobs = S.generate_trace(100, "mpi-compute", seed=0)
    res = S.run_baselines(jobs, hosts=32)
    fa = res["faabric"].makespan
    assert fa < res["1-ctr-per-vm"].makespan
    assert fa < res["2-ctr-per-vm"].makespan
    assert fa < res["4-ctr-per-vm"].makespan
    assert abs(fa - res["8-ctr-per-vm"].makespan) \
        / res["8-ctr-per-vm"].makespan < 0.1


# ---------------------------------------------------------------------------
# elastic policy through the engine
# ---------------------------------------------------------------------------
def test_elastic_decide_goes_through_engine():
    eng = PlacementEngine(2, 4)
    tenant = eng.allocate("tenant", 3)
    pol = ElasticPolicy(min_world=1, max_world=64, target_free=0)
    # world 2 + 5 free -> budget 7 -> grow to 4 (reservation verified)
    assert pol.decide(2, eng) == 4
    assert eng.idle_chips() == 5             # reservation was cancelled
    # leaving 5 chips for other tenants caps the budget at 2 -> no change
    assert ElasticPolicy(target_free=5).decide(2, eng) is None
    # tenant pressure + a free-chip target forces a shrink
    eng.release(tenant)
    big = eng.allocate("big", 7)
    assert ElasticPolicy(target_free=3).decide(4, eng) == 2
    eng.release(big)


def test_locality_policy_usable_for_elastic_engine():
    eng = PlacementEngine(4, 8, policy="locality")
    a = eng.allocate("gang", 8)
    assert a.fragmentation() == 1
    assert ElasticPolicy(max_world=16).decide(8, eng) == 16


def test_elastic_decide_passes_kind_to_the_grow_probe():
    # the probe reserves under the tenant's kind: on a hetero fleet the
    # same budget still resolves (placement succeeds either way) and the
    # kind keyword is accepted end-to-end
    eng = PlacementEngine(2, 8, speeds=[0.5, 1.0], policy="locality")
    assert ElasticPolicy(max_world=16).decide(
        2, eng, kind="mpi-network") == 16
    assert eng.idle_chips() == 16            # probe reservation cancelled
