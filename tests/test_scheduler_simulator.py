"""Scheduler allocation invariants (property-based, with example fallback)
and simulator reproduction of the paper's qualitative results
(Fig 10/11/14)."""
import numpy as np
import pytest

import _hyp_compat as hc
from repro.core import simulator as S
from repro.core.scheduler import ClusterState


@hc.hyp_or_examples(
    lambda st: (st.lists(st.integers(1, 20), min_size=1, max_size=30),
                st.integers(2, 8), st.integers(4, 16)),
    examples=[
        ([1] * 30, 2, 4),
        ([20, 13, 7, 1, 5], 8, 16),
        ([8, 8, 8, 8, 8], 4, 5),
        ([3], 5, 9),
        (list(range(1, 21)), 6, 10),
        ([16, 16, 16], 2, 4),
    ])
def test_granular_alloc_conserves_chips(sizes, chips, hosts):
    cs = ClusterState(hosts, chips)
    allocs = []
    for i, n in enumerate(sizes):
        a = cs.alloc_granular(f"j{i}", n)
        if a is not None:
            assert a.n == n
            allocs.append(a)
        assert cs.idle_chips() == cs.total_chips - sum(x.n for x in allocs)
        assert (cs.free >= 0).all()
    for a in allocs:
        cs.release(a)
    assert cs.idle_chips() == cs.total_chips


@hc.hyp_or_examples(
    lambda st: (st.integers(1, 64), st.integers(1, 8)),
    examples=[(1, 1), (7, 2), (64, 1), (64, 8), (13, 4), (33, 8),
              (8, 3), (5, 5)])
def test_slice_alloc_wastes_fragmentation(n, k):
    """Slice allocation rounds up to whole slices — the paper's
    fragmentation waste."""
    cs = ClusterState(8, 8)
    slice_size = 8 // k if 8 % k == 0 else 1
    a = cs.alloc_slices("j", n, slice_size)
    if a is not None:
        assert a.n >= n                      # over-allocation = waste
        assert a.n % slice_size == 0


def test_migration_plan_defragments():
    cs = ClusterState(4, 8)
    fillers = [cs.alloc_granular(f"f{i}", 6) for i in range(4)]
    frag = cs.alloc_granular("frag", 8)      # forced to span hosts
    assert frag.fragmentation() > 1
    for f in fillers[:2]:
        cs.release(f)
    plans = cs.migration_plan([frag])
    assert plans and plans[0][0] == "frag"
    new = cs.apply_migration(frag, plans[0][1])
    assert new.fragmentation() < frag.fragmentation()
    assert new.n == 8


def test_cross_host_fraction():
    cs = ClusterState(2, 8)
    a = cs.alloc_granular("a", 8)            # fits one host
    assert a.cross_host_fraction() == 0.0
    b = cs.alloc_granular("b", 8)
    cs.release(a)
    cs.release(b)


# ---------------------------------------------------------------------------
# simulator: the paper's headline results, qualitatively
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mpi_results():
    jobs = S.generate_trace(100, "mpi-compute", seed=0)
    return S.run_baselines(jobs, hosts=32)


def test_fig10_mpi_faabric_beats_coarse_baselines(mpi_results):
    fa = mpi_results["faabric"].makespan
    # paper: 13-23% lower makespan vs coarse slices; on par with 8-ctr
    assert fa < mpi_results["1-ctr-per-vm"].makespan * 0.9
    assert fa < mpi_results["2-ctr-per-vm"].makespan
    assert abs(fa - mpi_results["8-ctr-per-vm"].makespan) \
        / mpi_results["8-ctr-per-vm"].makespan < 0.1


def test_fig10_idle_chips_lower_for_faabric(mpi_results):
    fa = np.median(mpi_results["faabric"].idle_cdf())
    coarse = np.median(mpi_results["1-ctr-per-vm"].idle_cdf())
    assert fa <= coarse + 0.05


def test_fig10_omp_overcommit_baseline_worst(mpi_results):
    jobs = S.generate_trace(100, "omp", seed=0)
    res = S.run_baselines(jobs, hosts=32)
    fa = res["faabric"].makespan
    # paper: Faabric 38% lower than 8-ctr-per-vm; higher than mid slices
    assert fa < res["8-ctr-per-vm"].makespan * 0.8
    assert fa > res["4-ctr-per-vm"].makespan


def test_fig11_scaling_constant_per_host_throughput():
    makespans = {}
    for hosts, njobs in ((16, 50), (32, 100), (64, 200)):
        jobs = S.generate_trace(njobs, "mpi-compute", seed=1)
        makespans[hosts] = S.Simulator(hosts, 8, "granular").run(jobs).makespan
    ms = list(makespans.values())
    assert max(ms) / min(ms) < 1.6   # roughly flat (paper: within 5-10%)


def test_fig14_migration_helps_network_bound():
    jobs = S.generate_trace(60, "mpi-network", seed=2)
    with_mig = S.Simulator(16, 8, "granular", migrate=True).run(jobs)
    without = S.Simulator(16, 8, "granular", migrate=False).run(jobs)
    assert with_mig.migrations > 0
    assert with_mig.makespan <= without.makespan * 1.02


# ---------------------------------------------------------------------------
# priority preemption (rFaaS-style lease reclamation)
# ---------------------------------------------------------------------------
def _blocked_high_priority_trace():
    return [
        S.Job("low-0", "mpi-compute", 8, 400.0, arrival=0.0, priority=0),
        S.Job("low-1", "mpi-compute", 8, 400.0, arrival=0.0, priority=0),
        S.Job("hi-0", "mpi-compute", 12, 200.0, arrival=5.0, priority=5),
    ]


def test_preemption_lets_high_priority_jump_the_cluster():
    res = S.Simulator(2, 8, "granular", preempt=True).run(
        _blocked_high_priority_trace())
    assert res.preemptions >= 1
    assert res.finish_order[0] == "hi-0"
    # victims resume from their checkpoint and still finish
    assert set(res.finish_order) == {"hi-0", "low-0", "low-1"}
    kinds = [a.kind for a in res.actions]
    assert "preempt" in kinds and "resume" in kinds
    # without preemption the high-priority job waits for the hogs
    base = S.Simulator(2, 8, "granular", preempt=False).run(
        _blocked_high_priority_trace())
    assert base.preemptions == 0 and base.finish_order[-1] == "hi-0"
    hi = next(j for j in _blocked_high_priority_trace()
              if j.job_id == "hi-0")
    assert res.makespans([hi])["hi-0"] < base.makespans([hi])["hi-0"]


def test_preemption_conserves_chips_and_work():
    jobs = S.mixed_trace(40, seed=3, arrival_rate=0.2,
                         priority_classes=[(0, 0.8), (5, 0.2)])
    sim = S.Simulator(8, 8, "granular", preempt=True)
    res = sim.run(jobs)
    assert sim.engine.idle_chips() == sim.engine.total_chips
    assert len(res.finish_order) == len(jobs)     # every job completes
    # preempted progress is preserved: makespan stays sane vs no-preempt
    base = S.Simulator(8, 8, "granular", preempt=False).run(
        S.mixed_trace(40, seed=3, arrival_rate=0.2,
                      priority_classes=[(0, 0.8), (5, 0.2)]))
    assert res.makespan < base.makespan * 1.5


def test_idle_cdf_backlogged_only_both_ways():
    # samples: backlog era up to drain at t=10, then a long idle tail
    res = S.TraceResult(
        makespan=100.0, exec_times=[], migrations=0, waited=[],
        idle_samples=[(0.0, 0.2), (5.0, 0.4), (10.0, 0.3),
                      (50.0, 0.9), (100.0, 1.0)],
        queue_drain_time=10.0)
    backlog = res.idle_cdf(backlogged_only=True)
    full = res.idle_cdf(backlogged_only=False)
    # the backlog-era CDF only sees fragmentation-waste samples
    assert backlog.max() <= 0.4 and set(np.unique(backlog)) \
        <= {0.2, 0.3, 0.4}
    # the full CDF is dominated by the drain-down tail
    assert full.max() == 1.0
    assert np.median(full) > np.median(backlog)
    # degenerate shapes: no drain recorded -> backlogged == full;
    # a single sample collapses to that value; empty -> [0.0]
    res.queue_drain_time = 0.0
    assert np.array_equal(res.idle_cdf(True), res.idle_cdf(False))
    one = S.TraceResult(makespan=1.0, exec_times=[], migrations=0,
                        waited=[], idle_samples=[(0.0, 0.7)])
    assert list(one.idle_cdf()) == [0.7]
    empty = S.TraceResult(makespan=0.0, exec_times=[], migrations=0,
                          waited=[], idle_samples=[])
    assert list(empty.idle_cdf()) == [0.0]
    # drain before every sample: the guard falls back to the first
    # sample instead of an empty CDF
    late = S.TraceResult(makespan=9.0, exec_times=[], migrations=0,
                         waited=[],
                         idle_samples=[(5.0, 0.5), (9.0, 0.8)],
                         queue_drain_time=1.0)
    assert list(late.idle_cdf(True)) == [0.5]


def test_queue_order_deterministic_under_equal_priority_and_arrival():
    """Equal priority + equal arrival time must resolve by submission
    order — on a one-host cluster the start order IS the job order, and
    repeated runs are identical."""
    jobs = [S.Job(f"j{i}", "mpi-compute", 8, 80.0, arrival=0.0,
                  priority=3) for i in range(6)]
    r1 = S.Simulator(1, 8, "granular").run(list(jobs))
    starts = [a.payload["job"] for a in r1.actions if a.kind == "start"]
    assert starts == [f"j{i}" for i in range(6)]
    assert r1.finish_order == starts
    r2 = S.Simulator(1, 8, "granular").run(list(jobs))
    assert r1.finish_order == r2.finish_order \
        and r1.makespan == r2.makespan
    # same ties arriving *late* (one arrival event carrying equal
    # priority/arrival) also resolve by submission order
    late = [S.Job(f"k{i}", "mpi-compute", 8, 80.0, arrival=2.0,
                  priority=3) for i in range(4)]
    r3 = S.Simulator(1, 8, "granular").run(list(late))
    starts = [a.payload["job"] for a in r3.actions if a.kind == "start"]
    assert starts == [f"k{i}" for i in range(4)]


def test_preemption_deterministic_and_actions_shared_vocabulary():
    jobs = lambda: S.mixed_trace(30, seed=5, arrival_rate=0.3,
                                 priority_classes=[(0, 0.7), (3, 0.3)])
    r1 = S.Simulator(4, 8, "granular", preempt=True).run(jobs())
    r2 = S.Simulator(4, 8, "granular", preempt=True).run(jobs())
    assert r1.finish_order == r2.finish_order
    assert r1.makespan == r2.makespan
    from repro.core.control import Action
    assert all(isinstance(a, Action) for a in r1.actions)
    assert {a.kind for a in r1.actions} <= {
        "start", "resume", "preempt", "migrate", "finish"}
