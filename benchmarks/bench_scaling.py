"""Paper Fig 11: cluster-size scaling — 50/100/200/400-job traces on
16/32/64/128 hosts; makespan + execution-time distribution + the
centralised-scheduler degradation at 128 hosts.  Each scale also sweeps
the granular placement policies and a Poisson-arrival regime (the
multi-tenant extension of §6.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator as S


def run(report, tiny=False):
    scales = ((8, 16), (16, 32)) if tiny \
        else ((16, 50), (32, 100), (64, 200), (128, 400))
    for hosts, njobs in scales:
        jobs = S.generate_trace(njobs, "mpi-compute", seed=hosts)
        res = S.run_baselines(jobs, hosts=hosts)
        fa = res["faabric"]
        # policy sweep: faabric's run IS the binpack data point
        report(f"policy/{hosts}h/binpack/makespan",
               round(fa.makespan, 1), "s", "Fig11 policy sweep")
        for policy in ("spread", "locality"):
            r = S.Simulator(hosts, 8, "granular", policy=policy).run(jobs)
            report(f"policy/{hosts}h/{policy}/makespan",
                   round(r.makespan, 1), "s", "Fig11 policy sweep")
        arr = S.generate_trace(njobs, "mpi-compute", seed=hosts,
                               arrival_rate=njobs / 200.0)
        r = S.Simulator(hosts, 8, "granular", backfill=True).run(arr)
        report(f"poisson/{hosts}h/makespan", round(r.makespan, 1), "s",
               "Poisson arrivals + backfill")
        report(f"poisson/{hosts}h/mean_wait",
               round(float(np.mean(r.waited)), 1), "s",
               "Poisson arrivals + backfill")
        report(f"makespan/{hosts}h/faabric", round(fa.makespan, 1), "s",
               "Fig11a")
        best_base = min(v.makespan for k, v in res.items() if k != "faabric")
        worst_base = max(v.makespan for k, v in res.items()
                         if k != "faabric")
        report(f"makespan/{hosts}h/best_baseline", round(best_base, 1), "s",
               "Fig11a")
        report(f"makespan/{hosts}h/worst_baseline", round(worst_base, 1),
               "s", "Fig11a")
        et = np.array(fa.exec_times)
        report(f"exec/{hosts}h/p25", round(float(np.percentile(et, 25)), 1),
               "s", "Fig11b")
        report(f"exec/{hosts}h/p50", round(float(np.percentile(et, 50)), 1),
               "s", "Fig11b")
        report(f"exec/{hosts}h/p75", round(float(np.percentile(et, 75)), 1),
               "s", "Fig11b")
        report(f"sched_latency/{hosts}h",
               round(S.SCHED_LATENCY_PER_HOST * hosts * njobs, 1),
               "s total", "Fig11a centralised-scheduler cost")
