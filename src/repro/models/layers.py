"""Shared neural-net layers: norms, RoPE, MLPs, initialisers.

Pure-JAX (no flax): params are nested dicts of jnp arrays; every function is
``f(params, x, ...) -> y``.  All matmuls accumulate in f32 via
``preferred_element_type`` so bf16 params train stably.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def rms_norm(w, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["w"].astype(jnp.float32)
            + params["b"].astype(jnp.float32)).astype(x.dtype)


def matmul(x, w):
    """bf16 matmul with f32 accumulation (MXU-native on TPU)."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_rp(x, w, cfg=None):
    """Row-parallel matmul (contraction dim TP-sharded -> partial sums are
    all-reduced).  With ``cfg.bf16_tp_reduce`` the partial sums stay bf16,
    halving the TP all-reduce bytes (each shard still accumulates f32
    inside the MXU); otherwise identical to ``matmul``."""
    if cfg is not None and cfg.bf16_tp_reduce and x.dtype == jnp.bfloat16:
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())))
    return matmul(x, w)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, act, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": dense_init(k1, (d_model, d_ff), dtype),
         "w2": dense_init(k2, (d_ff, d_model), dtype)}
    if act == "silu":  # SwiGLU: gate + up
        p["w3"] = dense_init(k3, (d_model, d_ff), dtype)
    return p


def mlp(params, x, act: str, cfg=None):
    h = matmul(x, params["w1"])
    if act == "silu":
        h = jax.nn.silu(h) * matmul(x, params["w3"])
    else:
        h = jax.nn.gelu(h)
    return matmul_rp(h, params["w2"], cfg)


def softmax_xent(logits, labels, vocab: int):
    """Mean next-token cross-entropy in f32; labels==-1 masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    losses = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)


XENT_CHUNK = 512  # sequence chunk of the fused unembed+loss


def fused_unembed_xent(x, head, labels, chunk: int = XENT_CHUNK):
    """Cross-entropy fused with the unembedding matmul, chunked over the
    *sequence* axis with rematerialisation.

    Never materialises the (B, S, V) logits tensor: each checkpointed chunk
    computes (B, chunk, V) logits, reduces them to per-token losses, and the
    backward pass recomputes that chunk's logits on the fly.  Sequence is
    unsharded (batch carries DP; vocab carries TP), so chunk slicing is
    local on every device.  This is the standard large-vocab memory
    optimisation (the (B,S,V) f32 buffer dominates HBM otherwise).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)

    @jax.checkpoint
    def piece(xc, lc):
        logits = jax.lax.dot_general(
            xc, head, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for s0 in range(0, s, chunk):
        t, c = piece(x[:, s0:s0 + chunk], labels[:, s0:s0 + chunk])
        total += t
        count += c
    return total / jnp.maximum(count, 1.0)


def fused_unembed_xent_scan(x, head, labels, chunk: int = XENT_CHUNK):
    """Deploy-mode twin of fused_unembed_xent: lax.scan over seq chunks."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    nc = s // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def piece(xc, lc):
        logits = jax.lax.dot_general(
            xc, head, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(carry, inp):
        t, c = piece(*inp)
        return (carry[0] + t, carry[1] + c), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls))
    return total / jnp.maximum(count, 1.0)
