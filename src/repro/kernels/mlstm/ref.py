"""Pure-jnp oracle for the mLSTM kernel: exact per-token recurrence.

    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) v_t k_t^T
    n_t = ... (same gates on k)
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))      (q scaled by hd^-0.5)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, logi, logf):
    """q,k,v: (B,H,L,hd); logi/logf: (B,H,L,1)."""
    bs, h, l, hd = q.shape
    scale = hd ** -0.5
    qs = jnp.moveaxis(q.astype(jnp.float32), 2, 0)
    ks = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    lis = jnp.moveaxis(logi.astype(jnp.float32), 2, 0)[..., 0]
    lfs = jnp.moveaxis(logf.astype(jnp.float32), 2, 0)[..., 0]

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fi = jnp.exp(lf + m - m_new)[..., None, None]
        ii = jnp.exp(li - m_new)[..., None, None]
        c = fi * c + ii * vt[..., :, None] * kt[..., None, :]
        n = fi[..., 0] * n + ii[..., 0] * kt
        num = jnp.einsum("bhde,bhe->bhd", c, qt) * scale
        den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, qt)) * scale,
                          jnp.exp(-m_new))
        return (c, n, m_new), num / den[..., None]

    c0 = jnp.zeros((bs, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((bs, h, hd), jnp.float32)
    m0 = jnp.full((bs, h), -1e30, jnp.float32)
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0),
                                 (qs, ks, vs, lis, lfs))
    return (jnp.moveaxis(hs, 0, 2).astype(q.dtype),
            (c, n[:, :, None, :], m[:, :, None, None]))
