"""Fleet-churn subsystem (core.fleet + engine/simulator churn paths).

Pillars:

* engine churn primitives — add/drain/fail hosts conserve accounting,
  draining hosts take no new placements and retire freed chips, the
  evacuation planner never lands on doomed hosts;
* churn-free bit-identity — traces with no fleet events (and no
  checkpoint interval) are action-for-action identical to the pre-churn
  code path, central and sharded;
* simulator churn semantics — joins unblock queues, drains evacuate
  gracefully, hard failures requeue from the last checkpoint with lost
  work accounted, and the Young/Daly cadence reduces lost work;
* PR-4 follow-ons — adaptive shard sizing ("auto" + resharding under
  churn) and the per-pump steal budget.
"""
import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import simulator as S
from repro.core.fleet import (FleetController, FleetEvent, churn_mtbf,
                              churn_schedule, optimal_checkpoint_interval)
from repro.core.placement import (PlacementEngine, ShardedPlacementEngine,
                                  auto_shard_hosts)


# ---------------------------------------------------------------------------
# engine churn primitives
# ---------------------------------------------------------------------------
def test_add_hosts_extends_fleet_and_accounting():
    eng = PlacementEngine(2, 8)
    a = eng.allocate("a", 12)
    new = eng.add_hosts([8, 4])
    assert new == [2, 3]
    assert eng.hosts == 4 and eng.total_chips == 28
    assert eng.idle_chips() == 28 - 12 == int(eng.free.sum())
    b = eng.allocate("b", 12)            # needs the joined capacity
    assert b is not None
    eng.release(a), eng.release(b)
    assert eng.idle_chips() == eng.total_chips


def test_add_hosts_speeds_pad_both_ways():
    # homogeneous engine + fast joiners -> speeds materialise at 1.0
    eng = PlacementEngine(2, 8)
    eng.add_hosts([8], speeds=[2.0])
    assert eng.speeds is not None and list(eng.speeds) == [1.0, 1.0, 2.0]
    assert eng.heterogeneous
    # hetero engine + speedless joiners -> joiners at 1.0
    eng2 = PlacementEngine(2, 8, speeds=[0.5, 1.0])
    eng2.add_hosts([8])
    assert list(eng2.speeds) == [0.5, 1.0, 1.0]
    assert eng2.idle_throughput() == pytest.approx(0.5 * 8 + 8 + 8)


def test_drain_hosts_blocks_placement_and_retires_frees():
    eng = PlacementEngine(3, 8)
    a = eng.allocate("a", 4)
    target = a.placement[0][0]           # drain the gang's host
    eng.drain_hosts([target])
    assert eng.free[target] == 0 and eng.capacities[target] == 4
    assert eng.idle_chips() == int(eng.free.sum()) == 16
    # nothing new lands on the draining host
    b = eng.allocate("b", 16)
    assert b is not None and all(h != target for h, _ in b.placement)
    # releasing the gang on the draining host retires its chips
    eng.release(a)
    assert eng.capacities[target] == 0 and eng.free[target] == 0
    assert eng.total_chips == 16


def test_fail_hosts_requeues_victims_and_conserves():
    eng = PlacementEngine(3, 4)
    spans = eng.bind("spans", [(0, 2), (1, 2)])
    safe = eng.allocate("safe", 4)       # host 2 (most free after bind)
    assert spans and safe
    failed = eng.fail_hosts([0])
    assert failed == ["spans"]
    assert "spans" not in eng.allocations and "safe" in eng.allocations
    # surviving chips of the victim returned; dead host zeroed
    assert eng.capacities[0] == 0 and eng.free[0] == 0
    assert eng.idle_chips() == int(eng.free.sum()) == 4
    assert eng.total_chips == 8
    # nothing left to fail on an already-dead host
    assert eng.fail_hosts([0]) == []


def test_evacuation_plan_avoids_doomed_hosts_and_reports_stranded():
    eng = PlacementEngine(3, 4)
    a = eng.allocate("move", 4)
    hosts_a = {h for h, _ in a.placement}
    target = next(iter(hosts_a))
    eng.allocate("fill-1", 4)
    eng.allocate("fill-2", 4)            # fleet now full
    eng.drain_hosts([target])
    plans, stranded = eng.evacuation_plan([target])
    # every chip is held: the draining gang has nowhere to go
    assert plans == [] and stranded == ["move"]
    # free a host elsewhere -> the plan lands entirely off the doomed one
    other = next(jid for jid, al in eng.allocations.items()
                 if jid != "move" and target not in
                 {h for h, _ in al.placement})
    eng.release(eng.allocations[other])
    plans, stranded = eng.evacuation_plan([target])
    assert stranded == [] and len(plans) == 1
    jid, pl = plans[0]
    assert jid == "move" and all(h != target for h, _ in pl)
    eng.apply_migration(eng.allocations["move"], pl)
    assert eng.capacities[target] == 0   # vacated chips retired


def test_overlapping_reclaims_never_credit_earlier_draining_hosts():
    # regression: a gang spanning two reclaims — host 0 drains first
    # (gang stranded), then host 1 — must not count its host-0 chips as
    # a landing spot in the second pass (pre-fix this planned onto the
    # draining host and apply_migration crashed on oversubscription)
    eng = PlacementEngine(3, 2)
    eng.bind("g", [(0, 1), (1, 2)])
    other = eng.allocate("other", 2)     # host 2
    eng.drain_hosts([0])
    plans, stranded = eng.evacuation_plan([0])
    assert plans == [] and stranded == ["g"]
    eng.release(other)                   # host 2 frees up (2 chips)
    eng.drain_hosts([1])
    plans, stranded = eng.evacuation_plan([1])
    # only 2 safe chips exist for a 3-chip gang: stranded, not a crash
    assert plans == [] and stranded == ["g"]
    # and once enough safe capacity exists the plan avoids BOTH
    # draining hosts
    eng.add_hosts([2])
    plans, stranded = eng.evacuation_plan([1])
    assert stranded == [] and len(plans) == 1
    assert all(not eng.draining[h] for h, _ in plans[0][1])
    eng.apply_migration(eng.allocations["g"], plans[0][1])
    assert eng.idle_chips() == int(eng.free.sum())


def test_preemption_fit_probe_ignores_draining_chips():
    eng = PlacementEngine(2, 8)
    a = eng.allocate("low", 8)
    eng.allocate("low2", 8)
    eng.drain_hosts([a.placement[0][0]])
    # evicting "low" frees only draining chips the arrival cannot use,
    # so the plan must evict low2 (and prune low back out)
    plan = eng.preemption_plan(8, 5, {"low": 0, "low2": 0})
    assert plan == ["low2"]


def test_sharded_summaries_consistent_under_churn():
    rng = np.random.default_rng(4)
    eng = ShardedPlacementEngine(12, 8, hosts_per_shard=4)
    allocs = {}
    drained = []
    for i in range(300):
        u = rng.random()
        if u < 0.35 and allocs:
            jid = sorted(allocs)[int(rng.integers(len(allocs)))]
            eng.release(allocs.pop(jid))
        elif u < 0.42 and eng.alive_hosts() > 6:
            cands = [h for h in range(eng.hosts)
                     if eng.capacities[h] > 0 and not eng.draining[h]]
            victim = int(cands[int(rng.integers(len(cands)))])
            if u < 0.38:
                for jid in eng.fail_hosts([victim]):
                    allocs.pop(jid)
            else:
                eng.drain_hosts([victim])
                drained.append(victim)
        elif u < 0.47:
            eng.add_hosts([int(rng.integers(1, 9))])
        else:
            a = eng.allocate(f"j{i}", int(rng.integers(1, 16)))
            if a is not None:
                allocs[a.job_id] = a
        assert eng.idle_chips() == int(eng.free.sum())
        assert (eng.free <= eng.capacities).all()
        assert (eng.free[eng.draining] == 0).all()
        for s, (lo, hi) in enumerate(eng.shard_bounds):
            assert eng._shard_idle[s] == eng.free[lo:hi].sum()
    for a in list(allocs.values()):
        eng.release(a)
    assert eng.idle_chips() == eng.total_chips


# ---------------------------------------------------------------------------
# churn-free bit-identity + controller
# ---------------------------------------------------------------------------
def test_churn_free_traces_bit_identical():
    jobs = S.mixed_trace(60, seed=7, arrival_rate=0.3,
                         priority_classes=[(0, 0.8), (5, 0.2)])
    for sched, shards in (("central", None), ("sharded", 8)):
        a = S.Simulator(16, 8, "granular", migrate=True, preempt=True,
                        sched=sched, shard_hosts=shards).run(list(jobs))
        b = S.Simulator(16, 8, "granular", migrate=True, preempt=True,
                        sched=sched, shard_hosts=shards).run(
            list(jobs), fleet_events=[])
        assert a.actions == b.actions and a.makespan == b.makespan
        assert b.recoveries == 0 and b.evacuations == 0
        assert b.lost_work_s == 0.0


def test_fleet_controller_outcomes():
    eng = PlacementEngine(2, 8)
    a = eng.allocate("a", 8)
    gang_host = a.placement[0][0]
    ctl = FleetController(eng)
    out = ctl.apply(FleetEvent(0.0, "join", capacities=[8]), now=0.0)
    assert out.joined == [2] and eng.hosts == 3
    out = ctl.apply(FleetEvent(1.0, "reclaim", hosts=[gang_host],
                               drain_s=4.0), now=1.0)
    assert out.deadline == 5.0
    assert [jid for jid, _ in out.evacuations] == ["a"]
    assert all(h != gang_host
               for _, pl in out.evacuations for h, _ in pl)
    # the caller did not move the gang: expiry fails it
    out2 = ctl.expire(FleetEvent(1.0, "reclaim", hosts=[gang_host]),
                      kinds=None)
    assert [jid for jid, _ in out2.evacuations] == ["a"]
    failed = ctl.fail([gang_host])
    assert failed == ["a"] and eng.capacities[gang_host] == 0


def test_fleet_event_validation():
    with pytest.raises(AssertionError):
        FleetEvent(0.0, "join")            # no capacities
    with pytest.raises(AssertionError):
        FleetEvent(0.0, "fail")            # no hosts
    with pytest.raises(AssertionError):
        FleetEvent(0.0, "bogus", hosts=[1])


# ---------------------------------------------------------------------------
# simulator churn semantics
# ---------------------------------------------------------------------------
def test_join_event_unblocks_queued_job():
    jobs = [S.Job("first", "mpi-compute", 16, 160.0),
            S.Job("blocked", "mpi-compute", 16, 160.0)]
    # 2 hosts x 8: only one 16-gang fits at a time...
    base = S.Simulator(2, 8, "granular").run(list(jobs))
    # ...but a join at t=5 lets the second start immediately after
    r = S.Simulator(2, 8, "granular").run(
        list(jobs), fleet_events=[FleetEvent(5.0, "join",
                                             capacities=[8, 8])])
    assert [a.kind for a in r.actions].count("join") == 1
    assert r.makespan < base.makespan
    starts = {a.payload["job"]: a.payload["t"] for a in r.actions
              if a.kind == "start"}
    assert starts["blocked"] == pytest.approx(
        5.0 + S.SCHED_LATENCY_PER_HOST * 4)


def test_graceful_drain_evacuates_without_lost_work():
    # both gangs land on the upper hosts (binpack ties pick the highest
    # index); reclaiming those hosts forces both onto the free lower two
    jobs = [S.Job("a", "mpi-compute", 8, 240.0),
            S.Job("b", "mpi-compute", 8, 240.0)]
    r = S.Simulator(4, 8, "granular").run(
        list(jobs),
        fleet_events=[FleetEvent(5.0, "reclaim", hosts=[2, 3],
                                 drain_s=10.0)])
    assert r.evacuations == 2 and r.recoveries == 0
    assert r.lost_work_s == 0.0
    assert len(r.finish_order) == 2
    kinds = [a.kind for a in r.actions]
    assert "drain" in kinds and "evacuate" in kinds and "retire" in kinds
    for ev in (a for a in r.actions if a.kind == "evacuate"):
        assert all(h in (0, 1) for h, _ in ev.payload["placement"])


def test_hard_fail_requeues_from_checkpoint_and_accounts_lost_work():
    jobs = [S.Job("victim", "mpi-compute", 8, 240.0)]
    # no checkpoints: the failure rolls back to the start
    r = S.Simulator(1, 8, "granular").run(
        list(jobs), fleet_events=[FleetEvent(10.0, "fail", hosts=[0]),
                                  FleetEvent(12.0, "join",
                                             capacities=[8])])
    assert r.recoveries == 1
    assert r.lost_work_s == pytest.approx(10.0, abs=0.1)
    assert len(r.finish_order) == 1      # recovered and finished
    rec = next(a for a in r.actions if a.kind == "recover")
    assert rec.payload["progress"] == 0.0
    # the resume action restarts the gang on the joined host
    resume = next(a for a in r.actions if a.kind == "resume")
    assert all(h == 1 for h, _ in resume.payload["placement"])


def test_checkpoint_cadence_bounds_lost_work():
    jobs = [S.Job("victim", "mpi-compute", 8, 240.0)]
    events = [FleetEvent(20.0, "fail", hosts=[0]),
              FleetEvent(22.0, "join", capacities=[8])]
    no_ckpt = S.Simulator(1, 8, "granular").run(list(jobs),
                                                fleet_events=events)
    ckpt = S.Simulator(1, 8, "granular", checkpoint_interval=5.0).run(
        list(jobs), fleet_events=events)
    assert no_ckpt.lost_work_s > 15.0
    # at most one interval (+ checkpoint pauses) can be lost
    assert ckpt.lost_work_s < 6.0
    assert sum(1 for a in ckpt.actions if a.kind == "checkpoint") >= 3
    assert len(ckpt.finish_order) == 1
    # checkpoints cost time: the protected run finishes later than an
    # unprotected churn-free one would
    assert ckpt.makespan < no_ckpt.makespan


def test_deadline_retries_evacuation_when_capacity_frees():
    # at drain time the fleet is full (no evacuation possible); a gang
    # finishing before the deadline frees room and the last-chance pass
    # moves the draining gang instead of failing it
    jobs = [S.Job("short", "mpi-compute", 8, 40.0),
            S.Job("long", "mpi-compute", 8, 400.0)]
    r = S.Simulator(2, 8, "granular").run(
        list(jobs), fleet_events=[FleetEvent(1.0, "reclaim",
                                             hosts=[0],
                                             drain_s=20.0)])
    # short (host 1) finishes at ~5s freeing it; the deadline's
    # last-chance pass then moves long (host 0) instead of failing it
    assert r.evacuations == 1 and r.recoveries == 0
    assert len(r.finish_order) == 2


def test_single_shard_churn_trace_bit_identical_to_central():
    jobs = S.mixed_trace(50, seed=9, arrival_rate=0.3,
                         priority_classes=[(0, 0.8), (5, 0.2)])
    events = churn_schedule("spot-heavy", 16, 8, 150.0, seed=3,
                            rate=0.03)
    central = S.Simulator(16, 8, "granular", migrate=True,
                          preempt=True).run(list(jobs),
                                            fleet_events=events)
    sharded = S.Simulator(16, 8, "granular", migrate=True, preempt=True,
                          sched="sharded", shard_hosts=4096).run(
        list(jobs), fleet_events=events)
    assert sharded.actions == central.actions
    assert sharded.makespan == central.makespan


@pytest.mark.parametrize("regime", F.CHURN_REGIMES)
def test_churn_regimes_complete_all_jobs(regime):
    jobs = S.mixed_trace(40, seed=11, arrival_rate=0.25)
    events = churn_schedule(regime, 16, 8, 200.0, seed=5, rate=0.02)
    assert events, regime
    r = S.Simulator(16, 8, "granular", migrate=True,
                    checkpoint_interval=10.0).run(list(jobs),
                                                  fleet_events=events)
    assert len(r.finish_order) == 40
    assert r.makespan > 0


# ---------------------------------------------------------------------------
# Young/Daly checkpoint-interval policy
# ---------------------------------------------------------------------------
def test_young_daly_interval():
    assert optimal_checkpoint_interval(800.0, 0.5) \
        == pytest.approx((2 * 0.5 * 800.0) ** 0.5)
    assert optimal_checkpoint_interval(float("inf")) == float("inf")
    events = [FleetEvent(10.0, "fail", hosts=[0, 1]),
              FleetEvent(50.0, "reclaim", hosts=[2]),
              FleetEvent(60.0, "join", capacities=[8])]
    # unweighted: 2 disruptions over 100s
    assert churn_mtbf(events, 100.0) == pytest.approx(50.0)
    # blast-weighted: (2 + 1)/8 of the fleet
    assert churn_mtbf(events, 100.0, hosts=8) \
        == pytest.approx(100.0 / (3 / 8))
    assert churn_mtbf([], 100.0) == float("inf")


# ---------------------------------------------------------------------------
# PR-4 follow-ons: adaptive shard sizing + steal budget
# ---------------------------------------------------------------------------
def test_auto_shard_sizing_and_resharding_under_churn():
    assert auto_shard_hosts(128) == 16
    assert auto_shard_hosts(2) == 2
    eng = ShardedPlacementEngine(32, 8, hosts_per_shard="auto")
    assert eng.hosts_per_shard == auto_shard_hosts(32) == 8
    a = eng.allocate("a", 20)
    # fleet quadruples: the resharding hook re-derives the shard size
    eng.add_hosts([8] * 96)
    assert eng.hosts_per_shard == auto_shard_hosts(128) == 16
    assert eng.n_shards == 8
    assert eng.idle_chips() == int(eng.free.sum())
    # existing allocations survive the reshard
    eng.release(a)
    assert eng.idle_chips() == eng.total_chips
    # numeric specs re-apply their fleet clamp after joins (single-shard
    # parity survives growth)
    one = ShardedPlacementEngine(4, 8, hosts_per_shard=64)
    assert one.n_shards == 1
    one.add_hosts([8] * 4)
    assert one.n_shards == 1 and one.hosts_per_shard == 8


def test_steal_budget_caps_cross_shard_splits():
    # 2 shards of 1 host; a 12-chip gang must split across shards
    free_budget = ShardedPlacementEngine(2, 8, hosts_per_shard=1)
    assert free_budget.allocate("split", 12) is not None
    # direct (one-shot) use: the cap applies per decision, so a caller
    # is never starved by budget a *past* decision spent
    capped = ShardedPlacementEngine(2, 8, hosts_per_shard=1,
                                    steal_budget=1)
    a = capped.allocate("split-1", 12)
    assert a is not None
    capped.release(a)
    assert capped.allocate("split-2", 12) is not None
    # loop-managed (the simulator's queue pump owns the lifecycle):
    # budget persists across decisions until the pump resets it
    managed = ShardedPlacementEngine(2, 8, hosts_per_shard=1,
                                     steal_budget=1)
    managed.external_budget_reset = True
    managed.reset_steal_budget()
    b = managed.allocate("m-1", 12)       # split spends the budget
    assert b is not None
    managed.release(b)
    assert managed.allocate("m-2", 11) is None   # spent this pump
    managed.reset_steal_budget()                 # next pump
    assert managed.allocate("m-3", 11) is not None


def test_steal_budget_resets_per_pump_in_simulator():
    # two 12-chip gangs need splits; budget 1 forces them into separate
    # pumps but both still run (the queue retries after each event)
    jobs = [S.Job("a", "mpi-compute", 12, 80.0),
            S.Job("b", "mpi-compute", 12, 80.0),
            S.Job("c", "mpi-compute", 12, 80.0)]
    sim = S.Simulator(4, 8, "granular", sched="sharded", shard_hosts=1,
                      steal_budget=1)
    r = sim.run(list(jobs))
    assert len(r.finish_order) == 3
    # unbounded budget is bit-identical to the pre-budget engine
    a = S.Simulator(4, 8, "granular", sched="sharded",
                    shard_hosts=2).run(list(jobs))
    b = S.Simulator(4, 8, "granular", sched="sharded", shard_hosts=2,
                    steal_budget=0).run(list(jobs))
    assert a.actions == b.actions
