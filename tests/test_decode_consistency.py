"""Serving-path correctness: token-by-token decode must reproduce the
full-sequence forward logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.models import model as M
from repro.models import transformer as tf

B, S = 2, 16

DECODE_ARCHS = [a for a in ARCH_IDS if reduced_config(a).family
                not in ("audio", "vlm")]
PREFILL_ARCHS = [a for a in ARCH_IDS if reduced_config(a).family
                 in ("audio", "vlm")]


def _setup(arch, no_drop=False):
    cfg = reduced_config(arch)
    if no_drop and cfg.n_experts:
        cfg = cfg.with_(capacity_factor=8.0)
    params = jax.jit(lambda k: tf.init_params(k, cfg))(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model),
            cfg.param_dtype())
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_img_tokens, cfg.d_model),
            cfg.param_dtype())
    ctx = {k: batch[k] for k in ("frames", "img") if k in batch}
    logits_full, _, _ = jax.jit(
        lambda p, t: tf.forward(p, t, cfg, ctx))(params, tokens)
    return cfg, params, batch, tokens, logits_full


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg, params, batch, tokens, logits_full = _setup(arch, no_drop=True)
    serve = jax.jit(M.make_serve_step(cfg))
    states = tf.init_decode_state(cfg, B, S, cfg.param_dtype())
    for t in range(S):
        lg, states = serve(params, states, tokens[:, t:t + 1],
                           jnp.full((B, 1), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(logits_full[:, t], np.float32),
                                   atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", PREFILL_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg, params, batch, tokens, logits_full = _setup(arch)
    prefill = jax.jit(M.make_prefill_step(cfg))
    serve = jax.jit(M.make_serve_step(cfg))
    _, st = prefill(params, {**batch, "tokens": tokens[:, :S - 1]})

    def pad(x):
        if x.ndim == 5 and x.shape[2] == S - 1:
            spec = [(0, 0)] * x.ndim
            spec[2] = (0, 1)
            return jnp.pad(x, spec)
        return x
    states = [jax.tree.map(pad, s) for s in st]
    lg, _ = serve(params, states, tokens[:, S - 1:S],
                  jnp.full((B, 1), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(logits_full[:, S - 1], np.float32),
                               atol=5e-4, rtol=1e-3)


def test_prefill_state_matches_decode_state_ssm():
    """Prefill handover: running prefill then decoding must equal decoding
    from scratch (exact recurrent-state extraction for mamba/mlstm)."""
    arch = "zamba2-2.7b"
    cfg, params, batch, tokens, logits_full = _setup(arch)
    prefill = jax.jit(M.make_prefill_step(cfg))
    serve = jax.jit(M.make_serve_step(cfg))
    _, st = prefill(params, {"tokens": tokens[:, :S - 1]})

    def pad(x):
        if x.ndim == 5 and x.shape[2] == S - 1:
            spec = [(0, 0)] * x.ndim
            spec[2] = (0, 1)
            return jnp.pad(x, spec)
        return x
    states = [jax.tree.map(pad, s) for s in st]
    lg, _ = serve(params, states, tokens[:, S - 1:S],
                  jnp.full((B, 1), S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(logits_full[:, S - 1], np.float32),
                               atol=5e-4, rtol=1e-3)
