"""Checkpointing built on Granule snapshots (paper §3.4's fault-tolerance
sketch, implemented for real).

* **Full checkpoints**: the job-state snapshot serialised to disk
  (one ``.npz`` per checkpoint + a JSON manifest with step/fingerprint).
* **Incremental checkpoints**: chunk-diffs against the last full snapshot
  (``core.diffsync``) — the paper's byte-wise diff protocol as a
  checkpoint-size optimisation.  Restore = full + replay of diffs.
* **Async save**: serialisation happens on a background thread so the
  training loop only blocks for the device->host copy.
"""
from __future__ import annotations

import json
import os
import pickle
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core import diffsync, snapshot as snap_mod, telemetry


class CheckpointManager:
    def __init__(self, directory: str, job_id: str = "job",
                 keep: int = 3, incremental_every: int = 0,
                 delta_chain: bool = False, rebase_every: int = 8):
        """``incremental_every``: if > 0, only every k-th checkpoint is
        full; the rest are diffs against the last full one.

        ``delta_chain``: write ``(base, delta*)`` chains instead — the
        first save (and every ``rebase_every``-th) is a full base, each
        save between diffs against the *previous save* (not the base),
        so per-save bytes track what the job dirtied since the last
        tick.  Restore replays the whole chain in order and verifies
        the recorded fingerprint (bit-exact or it raises).  Mutually
        exclusive with ``incremental_every``."""
        assert not (delta_chain and incremental_every), \
            "delta_chain and incremental_every are mutually exclusive"
        self.dir = directory
        self.job_id = job_id
        self.keep = keep
        self.incremental_every = incremental_every
        self.delta_chain = delta_chain
        self.rebase_every = max(1, int(rebase_every))
        os.makedirs(directory, exist_ok=True)
        self._last_full: Optional[snap_mod.Snapshot] = None
        self._chain_prev: Optional[snap_mod.Snapshot] = None
        self._chain_len = 0
        self._n_saved = 0
        self._pending: List[threading.Thread] = []
        self.stats: List[Dict[str, Any]] = []

    # ---- paths --------------------------------------------------------------
    def _path(self, step: int, kind: str) -> str:
        return os.path.join(self.dir, f"{self.job_id}-{step:08d}.{kind}")

    def _manifest_path(self) -> str:
        return os.path.join(self.dir, f"{self.job_id}-manifest.json")

    def _manifest(self) -> List[Dict[str, Any]]:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except FileNotFoundError:
            return []

    def _write_manifest(self, entries) -> None:
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(entries, f, indent=1)
        os.replace(tmp, self._manifest_path())

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = True) -> Dict[str, Any]:
        """Checkpoint the state pytree at ``step``."""
        t0 = time.time()
        snap = snap_mod.take(self.job_id, step, state)
        copy_s = time.time() - t0
        incremental = (self.incremental_every > 0
                       and self._last_full is not None
                       and self._n_saved % self.incremental_every != 0)
        chained = (self.delta_chain and self._chain_prev is not None
                   and self._chain_len < self.rebase_every - 1)

        base_step = None
        if chained:
            # chain link: diff against the *previous save*, so restore
            # replays base + every delta up to the target step
            diffs = diffsync.diff_tree(self._chain_prev.state, snap.state,
                                       op="overwrite")
            payload = {"kind": "delta", "base_step": self._chain_prev.step,
                       "diffs": diffs, "step": step,
                       "fingerprint": snap.fingerprint}
            path = self._path(step, "delta.pkl")
            nbytes = diffsync.diff_nbytes(diffs)
            base_step = self._chain_prev.step
            self._chain_prev = snap
            self._chain_len += 1
        elif incremental:
            diffs = snap_mod.delta(self._last_full, state, op="overwrite")
            payload = {"kind": "diff", "base_step": self._last_full.step,
                       "diffs": diffs, "step": step,
                       "fingerprint": snap.fingerprint}
            path = self._path(step, "diff.pkl")
            nbytes = diffsync.diff_nbytes(diffs)
            base_step = self._last_full.step
        else:
            payload = {"kind": "full", "state": snap.state, "step": step,
                       "fingerprint": snap.fingerprint}
            path = self._path(step, "full.pkl")
            nbytes = snap.nbytes
            self._last_full = snap
            self._chain_prev = snap
            self._chain_len = 0
        self._n_saved += 1

        def _write():
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                pickle.dump(payload, f, protocol=4)
            os.replace(tmp, path)
            entries = self._manifest()
            entry = {"step": step, "path": path,
                     "kind": payload["kind"],
                     "fingerprint": snap.fingerprint,
                     "nbytes": nbytes}
            if base_step is not None:
                entry["base_step"] = base_step
            entries.append(entry)
            self._write_manifest(entries)
            self._gc(entries)

        if blocking:
            _write()
        else:
            t = threading.Thread(target=_write, daemon=True)
            t.start()
            self._pending.append(t)
        stat = {"step": step, "bytes": nbytes,
                "incremental": incremental or chained,
                "kind": payload["kind"],
                "full_bytes": snap.nbytes,
                "device_to_host_s": copy_s}
        self.stats.append(stat)
        tel = telemetry.get()
        if tel.enabled:
            tel.count(f"ckpt.save.{payload['kind']}")
            tel.count("ckpt.save.bytes", nbytes)
            tel.observe("ckpt.device_to_host_s", copy_s)
            tel.gauge("ckpt.chain_len", self._chain_len)
            p1 = time.perf_counter()
            tel.span_at("ckpt.save", p1 - (time.time() - t0), p1,
                        track=f"gang:{self.job_id}", clock="wall",
                        step=step, kind=payload["kind"], bytes=nbytes,
                        full_bytes=snap.nbytes)
        return stat

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _gc(self, entries) -> None:
        """Keep the last ``keep`` full checkpoints + diffs newer than the
        oldest kept full one."""
        fulls = [e for e in entries if e["kind"] == "full"]
        if len(fulls) <= self.keep:
            return
        cutoff = fulls[-self.keep]["step"]
        kept, dropped = [], []
        for e in entries:
            (kept if e["step"] >= cutoff else dropped).append(e)
        for e in dropped:
            try:
                os.remove(e["path"])
            except FileNotFoundError:
                pass
        self._write_manifest(kept)

    # ---- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        entries = self._manifest()
        return entries[-1]["step"] if entries else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load state at ``step`` (default: latest).  Diff checkpoints are
        replayed on top of their base full checkpoint."""
        t0 = time.perf_counter()
        self.wait()
        entries = self._manifest()
        if not entries:
            raise FileNotFoundError("no checkpoints")
        if step is None:
            entry = entries[-1]
        else:
            entry = next(e for e in entries if e["step"] == step)
        with open(entry["path"], "rb") as f:
            payload = pickle.load(f)
        if payload["kind"] == "full":
            state = payload["state"]
        elif payload["kind"] == "delta":
            # (base, delta*) chain: walk back to the base full, then
            # replay every delta in order and prove the reconstruction
            # bit-exact against the recorded fingerprint
            pos = entries.index(entry)
            chain = [payload]
            while chain[0]["kind"] != "full":
                base_step = chain[0]["base_step"]
                pos = next(i for i in range(pos - 1, -1, -1)
                           if entries[i]["step"] == base_step)
                with open(entries[pos]["path"], "rb") as f:
                    chain.insert(0, pickle.load(f))
            state = chain[0]["state"]
            for link in chain[1:]:
                state = diffsync.apply_tree(state, link["diffs"])
            import jax.tree_util as jtu
            fp = snap_mod._fingerprint(jtu.tree_leaves(state))
            if fp != payload["fingerprint"]:
                raise RuntimeError(
                    f"delta-chain restore at step {payload['step']} is "
                    f"not bit-exact (fingerprint mismatch)")
        else:
            base = next(e for e in entries
                        if e["kind"] == "full"
                        and e["step"] == payload["base_step"])
            with open(base["path"], "rb") as f:
                base_payload = pickle.load(f)
            state = diffsync.apply_tree(base_payload["state"],
                                        payload["diffs"])
        snap = snap_mod.Snapshot(self.job_id, payload["step"], state,
                                 fingerprint=payload["fingerprint"])
        restored = snap_mod.restore(snap, shardings)
        tel = telemetry.get()
        if tel.enabled:
            t1 = time.perf_counter()
            tel.count("ckpt.restores")
            tel.observe("ckpt.restore_s", t1 - t0)
            tel.span_at("ckpt.restore", t0, t1,
                        track=f"gang:{self.job_id}", clock="wall",
                        step=payload["step"], kind=payload["kind"])
        return restored, payload["step"]
