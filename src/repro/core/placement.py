"""Policy-driven gang placement on a shared cluster (paper §3.4, §6.2).

This is the single code path behind every placement decision in the repo:
the discrete-event simulator (paper Fig 10/11/14), the live runtime's
sub-mesh carving / rescale / migrate control-point actions, and the
scheduler facade in ``core.scheduler``.  The split is:

* ``PlacementPolicy`` — a pure function from a free-chip snapshot
  (``ClusterView``) to a gang placement ``[(host, n_chips)]``.  Shipped
  policies:

  - ``binpack``      Faabric's default: greedy most-free-first so the gang
                     spans as few hosts as possible (the seed behaviour).
  - ``spread``       round-robin chips over hosts (load balancing).
  - ``fixed-slice``  the §6.2 k-containers-per-VM baselines: whole slices
                     of ``slice_size`` chips, never shared between jobs.
  - ``locality``     scores candidate placements under the simulator's
                     cost model T = (W/n)(1 + beta*chi) and picks the one
                     minimising the predicted slowdown, tie-breaking on
                     chips stranded on touched hosts (best-fit) so large
                     contiguous blocks survive for later gangs.

* ``PlacementEngine`` — owns the mutable cluster state: free-chip
  accounting, gang allocation, preemption-safe reservations (hold chips
  before binding a job so multi-step decisions are atomic), migration
  planning at barrier points, and adoption of externally-created
  placements (``bind``, used by the live runtime).  Hosts default to
  ``chips_per_host`` chips each; ``capacities`` overrides per-host chip
  counts (a ragged last host on the CPU fabric, heterogeneous
  generations later).

* ``PreemptPolicy`` — victim selection when a high-priority arrival
  cannot be placed: evict the cheapest set of strictly-lower-priority
  gangs (checkpoint + requeue is the *caller's* job — the engine only
  plans).  Used by the simulator's priority traces and by
  ``core.fabric.Fabric`` for live preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Placement = List[Tuple[int, int]]          # [(host, n_chips)] sorted


def placement_cross_host_fraction(placement: Sequence[Tuple[int, int]]
                                  ) -> float:
    """chi = P[two random ranks sit on different hosts] — the collective
    slow-path fraction used by the simulator's time model."""
    n = sum(c for _, c in placement)
    if n <= 1:
        return 0.0
    return 1.0 - sum((c / n) ** 2 for _, c in placement)


@dataclasses.dataclass
class Allocation:
    job_id: str
    placement: Placement
    slice_size: int = 0                     # 0 = granular

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)

    @property
    def hosts(self) -> List[int]:
        return [h for h, _ in self.placement]

    def fragmentation(self) -> int:
        return len(self.placement)

    def cross_host_fraction(self) -> float:
        return placement_cross_host_fraction(self.placement)


class ClusterView:
    """Read-only free-chip snapshot handed to policies (keeps them pure)."""

    __slots__ = ("free", "chips_per_host")

    def __init__(self, free: np.ndarray, chips_per_host: int):
        self.free = free
        self.chips_per_host = chips_per_host

    @property
    def hosts(self) -> int:
        return len(self.free)

    def idle_chips(self) -> int:
        return int(self.free.sum())


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------
class PlacementPolicy:
    """A pure placement function; the engine commits the result."""

    name = "abstract"
    slice_size = 0                          # granular unless overridden

    def place(self, view: ClusterView, n: int) -> Optional[Placement]:
        raise NotImplementedError


def _greedy_most_free(free: np.ndarray, n: int) -> Optional[Placement]:
    """Most-free-first greedy: the gang spans as few hosts as possible."""
    order = np.argsort(free)[::-1]
    placement: Placement = []
    remaining = n
    for h in order:
        if free[h] == 0:
            continue
        take = min(int(free[h]), remaining)
        placement.append((int(h), take))
        remaining -= take
        if remaining == 0:
            break
    return sorted(placement) if remaining == 0 else None


class BinpackPolicy(PlacementPolicy):
    """Faabric's default: fewest hosts via greedy most-free-first."""

    name = "binpack"

    def place(self, view: ClusterView, n: int) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        return _greedy_most_free(view.free, n)


class SpreadPolicy(PlacementPolicy):
    """Round-robin chips over hosts (load balancing)."""

    name = "spread"

    def place(self, view: ClusterView, n: int) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        counts: Dict[int, int] = {}
        free = view.free.copy()
        remaining = n
        while remaining > 0:
            candidates = np.nonzero(free > 0)[0]
            if candidates.size == 0:
                return None
            h = int(candidates[np.argmax(free[candidates])])
            counts[h] = counts.get(h, 0) + 1
            free[h] -= 1
            remaining -= 1
        return sorted(counts.items())


class FixedSlicePolicy(PlacementPolicy):
    """Whole-slice allocation: ceil(n/slice) slices, each on one host.

    Emulates the paper's k-containers-per-VM baselines: a host holds
    ``chips_per_host // slice_size`` slices; slices are never shared
    between jobs, so a request is rounded up to whole slices (the
    fragmentation waste of Fig 10).
    """

    name = "fixed-slice"

    def __init__(self, slice_size: int):
        assert slice_size > 0
        self.slice_size = slice_size

    def place(self, view: ClusterView, n: int) -> Optional[Placement]:
        slice_size = self.slice_size
        n_slices = -(-n // slice_size)
        placement: Dict[int, int] = {}
        need = n_slices
        free = view.free
        for h in np.argsort(free)[::-1]:
            while free[h] - placement.get(int(h), 0) >= slice_size \
                    and need > 0:
                placement[int(h)] = placement.get(int(h), 0) + slice_size
                need -= 1
            if need == 0:
                break
        if need:
            return None
        return sorted(placement.items())


class LocalityScoredPolicy(PlacementPolicy):
    """Minimise the predicted cross-host slowdown of the §6 cost model.

    Candidate placements are scored by the slowdown factor (1 + beta*chi)
    of T = (W/n)(1 + beta*chi); W/n is identical across candidates so it
    drops out.  Ties (e.g. every single-host placement has chi = 0) break
    on chips *stranded* on touched hosts: best-fit keeps large free blocks
    intact, so later gangs fragment less — that second-order effect is
    what lowers the trace-wide mean chi versus binpack's worst-fit choice
    of the most-free host.
    """

    name = "locality"

    def __init__(self, beta: float = 0.4):
        self.beta = beta

    def _stranded(self, view: ClusterView, placement: Placement) -> int:
        return sum(int(view.free[h]) - c for h, c in placement)

    def place(self, view: ClusterView, n: int) -> Optional[Placement]:
        if n > view.idle_chips():
            return None
        free = view.free
        candidates: List[Placement] = []
        fits = np.nonzero(free >= n)[0]
        if fits.size:                        # best-fit single host
            h = int(fits[np.argmin(free[fits])])
            candidates.append([(h, n)])
        greedy = _greedy_most_free(free, n)
        if greedy is not None:
            candidates.append(greedy)
        exact = self._greedy_exact_fill(free, n)
        if exact is not None:
            candidates.append(exact)
        if not candidates:
            return None
        return min(candidates, key=lambda p: (
            1.0 + self.beta * placement_cross_host_fraction(p),
            self._stranded(view, p)))

    @staticmethod
    def _greedy_exact_fill(free: np.ndarray, n: int) -> Optional[Placement]:
        """Greedy most-free-first, but finish the remainder on the
        best-fit host (smallest free count that still covers it) — same
        chi as plain greedy when the chunk multiset matches, strictly
        fewer stranded chips otherwise."""
        avail = free.copy()
        placement: Placement = []
        remaining = n
        while remaining > 0:
            fits = np.nonzero(avail >= remaining)[0]
            if fits.size:
                h = int(fits[np.argmin(avail[fits])])
                placement.append((h, remaining))
                remaining = 0
                break
            h = int(np.argmax(avail))
            if avail[h] == 0:
                return None
            take = int(avail[h])
            placement.append((h, take))
            avail[h] = 0
            remaining -= take
        return sorted(placement)


POLICIES: Dict[str, PlacementPolicy] = {
    "binpack": BinpackPolicy(),
    "spread": SpreadPolicy(),
    "locality": LocalityScoredPolicy(),
}


def resolve_policy(policy: Union[str, PlacementPolicy, None],
                   default: Optional[PlacementPolicy] = None
                   ) -> PlacementPolicy:
    if policy is None:
        assert default is not None
        return default
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(f"unknown placement policy: {policy!r}") from None


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PreemptPolicy:
    """Victim selection for a high-priority arrival that cannot be placed.

    Victims are strictly-lower-priority gangs, evicted cheapest-first:
    lowest priority class first, and within a class the largest gang first
    (frees the most chips per eviction).  Greedy selection stops as soon
    as the arrival fits under the engine's placement policy; a prune pass
    then drops any victim the fit does not actually need — preferring to
    spare the *higher*-priority ones — so no gang is evicted needlessly.
    The plan is a pure decision — the caller performs the actual
    checkpoint + release + requeue.

    ``max_victims`` bounds the blast radius of one arrival (0 = unbounded).
    """

    max_victims: int = 0

    def plan(self, engine: "PlacementEngine", n: int, priority: int,
             priorities: Dict[str, int],
             policy: Union[str, PlacementPolicy, None] = None
             ) -> Optional[List[str]]:
        """job_ids to evict so an ``n``-chip gang at ``priority`` places;
        ``None`` if no lower-priority victim set suffices, ``[]`` if it
        already fits without eviction."""
        pol = resolve_policy(policy, engine.default_policy)
        scratch = engine.free.copy()

        def fits() -> bool:
            return pol.place(ClusterView(scratch.copy(),
                                         engine.chips_per_host),
                             n) is not None

        if fits():
            return []
        # cheapest-first victim order: priority asc, gang size desc, id
        victims = sorted(
            (a for a in engine.allocations.values()
             if priorities.get(a.job_id, 0) < priority),
            key=lambda a: (priorities.get(a.job_id, 0), -a.n, a.job_id))
        chosen: List[Allocation] = []
        for a in victims:
            for h, c in a.placement:
                scratch[h] += c
            chosen.append(a)
            if fits():
                break
        else:
            return None
        # prune needless victims, sparing higher-priority gangs first
        for a in sorted(chosen,
                        key=lambda a: (-priorities.get(a.job_id, 0), a.n,
                                       a.job_id)):
            for h, c in a.placement:
                scratch[h] -= c
            if fits():
                chosen.remove(a)        # not needed after all
            else:
                for h, c in a.placement:
                    scratch[h] += c
        if self.max_victims and len(chosen) > self.max_victims:
            return None
        return [a.job_id for a in chosen]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Reservation:
    """Chips held but not yet bound to a job.

    The preemption-safe handshake: ``reserve`` carves the chips out of the
    free pool atomically, so a multi-step decision (e.g. elastic grow:
    decide, snapshot, reshard) cannot lose the chips to a concurrent
    allocation; ``commit`` binds them to a job, ``cancel`` returns them.
    """

    placement: Placement
    slice_size: int = 0
    settled: bool = False                   # committed or cancelled

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)


class PlacementEngine:
    """Free-chip accounting + policy-driven gang allocation for a cluster
    of ``hosts`` hosts with ``chips_per_host`` chips each (``capacities``
    overrides individual hosts, e.g. a ragged last host)."""

    def __init__(self, hosts: int, chips_per_host: int,
                 policy: Union[str, PlacementPolicy] = "binpack",
                 capacities: Optional[Sequence[int]] = None):
        self.hosts = hosts
        self.chips_per_host = chips_per_host
        if capacities is None:
            self.capacities = np.full(hosts, chips_per_host, dtype=np.int64)
        else:
            assert len(capacities) == hosts
            self.capacities = np.asarray(capacities, dtype=np.int64)
            assert (self.capacities >= 0).all() \
                and (self.capacities <= chips_per_host).all()
        self.free = self.capacities.copy()
        self.jobs_on_host: List[set] = [set() for _ in range(hosts)]
        self.default_policy = resolve_policy(policy)
        self.allocations: Dict[str, Allocation] = {}

    # ---- capacity ----------------------------------------------------------
    @property
    def total_chips(self) -> int:
        return int(self.capacities.sum())

    def idle_chips(self) -> int:
        return int(self.free.sum())

    def idle_fraction(self) -> float:
        return self.idle_chips() / self.total_chips

    def view(self) -> ClusterView:
        return ClusterView(self.free.copy(), self.chips_per_host)

    # ---- reservation lifecycle ---------------------------------------------
    def reserve(self, n: int,
                policy: Union[str, PlacementPolicy, None] = None
                ) -> Optional[Reservation]:
        pol = resolve_policy(policy, self.default_policy)
        placement = pol.place(self.view(), n)
        if placement is None:
            return None
        for h, c in placement:
            self.free[h] -= c
        assert (self.free >= 0).all()
        return Reservation(placement, slice_size=pol.slice_size)

    def commit(self, res: Reservation, job_id: str) -> Allocation:
        assert not res.settled, "reservation already settled"
        res.settled = True
        for h, _ in res.placement:
            self.jobs_on_host[h].add(job_id)
        alloc = Allocation(job_id, sorted(res.placement),
                           slice_size=res.slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def cancel(self, res: Reservation) -> None:
        assert not res.settled, "reservation already settled"
        res.settled = True
        for h, c in res.placement:
            self.free[h] += c
        assert (self.free <= self.capacities).all()

    # ---- allocation ----------------------------------------------------------
    def allocate(self, job_id: str, n: int,
                 policy: Union[str, PlacementPolicy, None] = None
                 ) -> Optional[Allocation]:
        res = self.reserve(n, policy)
        return None if res is None else self.commit(res, job_id)

    def bind(self, job_id: str, placement: Sequence[Tuple[int, int]],
             slice_size: int = 0) -> Allocation:
        """Adopt an externally-determined placement (the live runtime
        attaching the gang it was launched with)."""
        for h, c in placement:
            assert 0 < c <= self.free[h], \
                f"bind over-subscribes host {h}: {c} > {self.free[h]}"
            self.free[h] -= c
            self.jobs_on_host[h].add(job_id)
        alloc = Allocation(job_id, sorted(placement), slice_size=slice_size)
        self.allocations[job_id] = alloc
        return alloc

    def release(self, alloc: Allocation) -> None:
        for h, c in alloc.placement:
            self.free[h] += c
            self.jobs_on_host[h].discard(alloc.job_id)
        self.allocations.pop(alloc.job_id, None)
        assert (self.free <= self.capacities).all()

    # ---- preemption -----------------------------------------------------------
    def preemption_plan(self, n: int, priority: int,
                        priorities: Dict[str, int],
                        policy: Union[str, PlacementPolicy, None] = None,
                        preempt: Optional[PreemptPolicy] = None
                        ) -> Optional[List[str]]:
        """Plan victims (see ``PreemptPolicy.plan``) against the live
        allocation table; the caller checkpoints + releases + requeues."""
        return (preempt or PreemptPolicy()).plan(self, n, priority,
                                                 priorities, policy)

    # ---- migration (defragmentation at barrier points) ------------------------
    def migration_plan(self, allocs: Sequence[Allocation]
                       ) -> List[Tuple[str, Placement]]:
        """For each fragmented granular gang, try to consolidate onto
        fewer hosts using currently-free chips (+ the chips the gang
        already holds).  Returns [(job_id, new_placement)].

        Invariants: slice allocations are never migrated; a plan that
        frees zero hosts (same host count) is not emitted; plans are
        committed against a scratch free map so they never double-book
        chips among themselves.
        """
        plans = []
        free = self.free.copy()
        for alloc in allocs:
            if alloc.slice_size or alloc.fragmentation() <= 1:
                continue
            held = dict(alloc.placement)
            avail = free.copy()
            for h, c in held.items():
                avail[h] += c
            # can the gang fit on fewer hosts?
            order = np.argsort(avail)[::-1]
            new_placement: Placement = []
            remaining = alloc.n
            for h in order:
                if avail[h] <= 0 or remaining == 0:
                    break
                take = min(int(avail[h]), remaining)
                new_placement.append((int(h), take))
                remaining -= take
            if remaining == 0 and len(new_placement) < alloc.fragmentation():
                plans.append((alloc.job_id, sorted(new_placement)))
                # commit against the scratch free map so plans don't overlap
                for h, c in held.items():
                    free[h] += c
                for h, c in new_placement:
                    free[h] -= c
        return plans

    def apply_migration(self, alloc: Allocation,
                        new_placement: Sequence[Tuple[int, int]]
                        ) -> Allocation:
        self.release(alloc)
        for h, c in new_placement:
            self.free[h] -= c
            self.jobs_on_host[h].add(alloc.job_id)
        assert (self.free >= 0).all()
        new = Allocation(alloc.job_id, sorted(new_placement))
        self.allocations[alloc.job_id] = new
        return new
