"""xlstm-1.3b: 48 blocks d2048 4H (kv=4) no FFN, sLSTM + mLSTM (xLSTM[7:1]).

[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,       # 1-in-8 blocks are sLSTM
    xlstm_proj_factor=2.0,
)
