"""Telemetry plane acceptance: predicted-vs-live divergence + Perfetto.

One pinned mixed train+serve trace with fleet churn, executed twice in
an 8-device subprocess (real jax gangs on the host fabric):

* ``Fabric.predict_trace`` — the discrete-event simulator's Action log.
* ``Fabric.run_trace`` — the live event loop driving real gangs, with
  a ``core.telemetry`` recorder enabled end to end.

``telemetry.diff_traces`` aligns the two Action streams; the gate is
**zero divergence** — the live fabric must replay the simulator's
decision sequence event for event even while recording (the recorder's
no-perturbation contract, measured rather than asserted).  The per-
phase predicted-vs-measured time-error report lands at
``results/<prefix>_bench_telemetry_diff.json`` and the recorded
timeline — placement decisions, gang lifecycle, checkpoints,
collective dispatch, serve admission — as a Perfetto-loadable Chrome
trace at ``results/<prefix>_bench_telemetry_perfetto.json``.

Reported metrics (gated in check_results.py at both tiers):

* ``diff/zero_divergence`` — 1.0 iff the aligned streams diverge
  nowhere (gate > 0).
* ``trace/layers_present`` — how many of the five instrumented layers
  (placement, gang/fabric, ckpt, collective, serve) emitted events
  into the exported trace (gate > 4: all five).
* ``telemetry/spans_total`` / ``telemetry/decision_latency_count`` —
  the recorder saw real spans and the placement engine's decision-
  latency histogram is populated (gates > 0).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

RESULTS_DIR = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "results"))
SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# the five layers the exported timeline must cover (event ``cat`` =
# name prefix, see telemetry.to_chrome_trace)
REQUIRED_LAYERS = ("placement", "gang", "ckpt", "collective", "serve")

# fleet config stamped into the results/ artifact by run.py
FLEET = {"hosts": 3, "chips_per_host": 2, "spare_hosts": 1,
         "sched": "central", "policy": "binpack",
         "churn": "pinned fail@6s + join@10s",
         "checkpoint_interval_s": 4.0}

_PROG = """
import json, sys
import jax
from repro.configs.registry import reduced_config
from repro.core import telemetry
from repro.core.fabric import Fabric
from repro.core.fleet import FleetEvent
from repro.core.simulator import Job
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.gang_workloads import workload_factory

trace_path, diff_path = sys.argv[1], sys.argv[2]
cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
# pinned mixed train+serve trace + churn schedule: one hard host
# failure mid-run (checkpoint rollback + recover) and a like-for-like
# join from the staged spares
jobs = [
    Job("train-a", "mpi-compute", 4, 200.0, arrival=0.0,
        workload="train"),
    Job("serve-0", "omp", 2, 120.0, arrival=0.0, priority=1,
        workload="serve"),
]
events = [FleetEvent(6.0, "fail", hosts=[0]),
          FleetEvent(10.0, "join", capacities=[2])]
devs = jax.devices()
fab = Fabric(devices=devs[:6], chips_per_host=2, spares=devs[6:])

tel = telemetry.enable()
predicted = fab.predict_trace(jobs, preempt=True, fleet_events=events,
                              checkpoint_interval=4.0)


def factory(job):
    wl = workload_factory(cfg, ocfg, dcfg, train_steps=3,
                          serve_tokens=3)(job)
    # "auto" routes the gradient-sync schedule through the fabric's
    # CollectiveTuner on every (re)bind — the collectives layer's
    # dispatch counters
    if hasattr(wl, "sync_mode"):
        wl.sync_mode = "auto"
    return wl


ex = fab.run_trace(jobs, factory, preempt=True, fleet_events=events,
                   checkpoint_interval=4.0)
live = ex.result
diff = telemetry.diff_traces(predicted, live)

tel.write_chrome_trace(trace_path)
with open(diff_path, "w") as f:
    json.dump(telemetry._plain(diff), f, indent=1, sort_keys=True)

summary = tel.summary()
dec = summary["histograms"].get("placement.decision_latency_s", {})
with open(trace_path) as f:
    cats = {e.get("cat") for e in json.load(f)["traceEvents"]}
out = {
    "divergences": diff["divergences"],
    "aligned": diff["aligned"],
    "n_predicted": diff["n_predicted"],
    "n_live": diff["n_live"],
    "phase_kinds": len(diff["phase_error"]),
    "max_phase_dt_s": max(
        [p["max_abs_dt_s"] for p in diff["phase_error"].values()],
        default=0.0),
    "spans_total": summary["spans_total"],
    "decision_latency_count": dec.get("count", 0),
    "layers": sorted(c for c in cats if c),
    "recoveries": live.recoveries,
    "checkpoints": sum(r.get("checkpoints", 0)
                       for r in ex.live.values()),
}
print(json.dumps(out))
"""


def run(report, tiny=False):
    prefix = "SMOKE" if tiny else "BENCH"
    trace_path = os.path.join(RESULTS_DIR,
                              f"{prefix}_bench_telemetry_perfetto.json")
    diff_path = os.path.join(RESULTS_DIR,
                             f"{prefix}_bench_telemetry_diff.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PROG),
         trace_path, diff_path],
        capture_output=True, text=True, env=env, timeout=1800)
    assert res.returncode == 0, res.stderr[-3000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])

    layers = [l for l in REQUIRED_LAYERS if l in data["layers"]]
    report("diff/divergences", data["divergences"], "",
           "predicted vs live Action streams (pinned churn trace)")
    report("diff/zero_divergence",
           1.0 if data["divergences"] == 0 else 0.0, "",
           "1.0 iff live replays the prediction event for event")
    report("diff/aligned_actions", data["aligned"], "",
           f"of {data['n_predicted']} predicted / {data['n_live']} live")
    report("diff/phase_kinds", data["phase_kinds"], "",
           "Action kinds with a per-phase time-error entry")
    report("diff/max_phase_dt_s", round(data["max_phase_dt_s"], 6), "s",
           "worst aligned |t_live - t_predicted| (virtual clock)")
    report("trace/layers_present", len(layers), "",
           f"of {len(REQUIRED_LAYERS)}: {'+'.join(layers)}")
    report("telemetry/spans_total", data["spans_total"], "",
           "recorder spans (wall + virtual)")
    report("telemetry/decision_latency_count",
           data["decision_latency_count"], "",
           "placement.decision_latency_s histogram samples")
    report("run/recoveries", data["recoveries"], "",
           "checkpoint rollbacks on the pinned host failure")
    report("run/checkpoints", data["checkpoints"], "",
           "real snapshots taken by live gangs")
