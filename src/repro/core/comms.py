"""Analytical collective cost model — the shared language between the
placement layer and the comms layer (DESIGN.md §11).

The placement layer knows each gang's host topology; the comms layer
(``core.collectives``) owns the schedules (flat / ring / hierarchical /
compressed).  Both need the same question answered — *how long does an
all-reduce of B bytes take on this topology under the best schedule?* —
so the pricing lives here, in a numpy-only module imported by both
(``collectives`` must not import ``placement`` and vice versa).

The model is deliberately first-order (Faabric §5.3 accounting): a
schedule's time is its serialized slow-link bytes over the slow-link
bandwidth, plus fast-link bytes over fast bandwidth, plus per-step
latencies and per-collective launch overhead.  It seeds the
``CollectiveTuner`` dispatch table; one-shot measured probes then
overwrite individual entries with ground truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

MODES: Tuple[str, ...] = ("flat", "ring", "hierarchical", "compressed")

#: dispatch-table size buckets: power-of-two message sizes from 1 KiB
#: to 1 GiB (below/above clamp to the end buckets)
MIN_BUCKET = 10
MAX_BUCKET = 30

#: default message size priced when a gang's state size is unknown yet
#: (first bind happens before ``init_state``) — 4 MiB, a typical
#: flattened-gradient bucket
DEFAULT_NBYTES = 4 << 20


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gang's placement shape, as the comms layer sees it.

    ``hosts`` — VMs/pods spanned; ``chips`` — total ranks; ``min_fast``
    — the smallest per-host contingent, which bounds the usable
    reduce-scatter fan-in of the hierarchical schedule (the slow hop
    ships ``bytes / min_fast`` in the worst shard)."""

    hosts: int
    chips: int
    min_fast: int

    @classmethod
    def from_placement(cls, placement: Sequence[Tuple[int, int]]
                       ) -> "Topology":
        counts = [int(c) for _, c in placement if c > 0]
        if not counts:
            return cls(1, 1, 1)
        return cls(len(counts), sum(counts), max(1, min(counts)))

    @property
    def key(self) -> Tuple[int, int, int]:
        return (self.hosts, self.chips, self.min_fast)


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Measured (or declared) per-link characteristics.

    Bandwidths are bytes/second; the defaults model the paper's cloud
    testbed — ~3 Gbit/s sustained VM-to-VM (slow, DCN) against
    in-memory intra-VM transfers (fast) — and a vectorized codec that
    streams at memory-ish bandwidth with a fixed launch cost."""

    slow_bps: float = 0.4e9        # cross-host (DCN) link
    fast_bps: float = 16e9         # intra-host (ICI / shared memory)
    slow_lat_s: float = 50e-6      # per-step latency across hosts
    fast_lat_s: float = 2e-6       # per-step latency within a host
    launch_s: float = 5e-6         # per-collective-op launch overhead
    codec_bps: float = 8e9         # threshold-select + sparse merge
    codec_lat_s: float = 30e-6     # fixed codec launch cost


def size_bucket(nbytes: Optional[int]) -> int:
    """Message-size bucket: clamped ceil(log2(bytes))."""
    if not nbytes or nbytes <= 0:
        nbytes = DEFAULT_NBYTES
    b = max(1, int(nbytes))
    return min(MAX_BUCKET, max(MIN_BUCKET, int(math.ceil(math.log2(b)))))


def bucket_nbytes(bucket: int) -> int:
    return 1 << bucket


def schedule_cost(topo: Topology, nbytes: int, mode: str,
                  link: Optional[LinkProfile] = None,
                  frac: float = 0.05) -> float:
    """Predicted seconds for one all-reduce of ``nbytes`` (per rank)
    under ``mode`` on ``topo``.  ``inf`` marks an unavailable schedule
    (compressed needs a slow axis to compress across)."""
    link = link or LinkProfile()
    H, n, f = topo.hosts, max(1, topo.chips), max(1, topo.min_fast)
    nbytes = max(1, int(nbytes))
    multi = H > 1
    if n == 1:
        return link.launch_s if mode == "flat" else float("inf")
    if mode == "flat":
        # one fused all-reduce; the whole vector crosses the slow
        # boundary (matches the HLO output-bytes accounting the bench
        # measures), bandwidth-optimal within a host
        slow_b = float(nbytes) if multi else 0.0
        fast_b = 2.0 * nbytes * (n - 1) / n
        slow_steps = 2 * math.ceil(math.log2(H)) if multi else 0
        fast_steps = 2 * math.ceil(math.log2(max(2, f)))
        ops = 1
        codec = 0.0
    elif mode == "ring":
        # one ring over every rank: bandwidth-optimal per link, but the
        # cross-host edges serialize 2(n-1) chunk hops and every step
        # waits on the slowest link — cross-host rings lose on latency
        steps = 2 * (n - 1)
        ring_b = 2.0 * nbytes * (n - 1) / n
        slow_b = ring_b if multi else 0.0
        fast_b = ring_b
        slow_steps = steps if multi else 0
        fast_steps = 0 if multi else steps
        ops = steps
        codec = 0.0
    elif mode == "hierarchical":
        # reduce-scatter(fast) -> all-reduce(slow) -> all-gather(fast):
        # only the per-chip shard (bytes / min_fast) crosses the slow
        # boundary (paper Fig 9)
        slow_b = (nbytes / f) if multi else 0.0
        fast_b = 2.0 * nbytes * (f - 1) / f
        slow_steps = 2 * math.ceil(math.log2(H)) if multi else 0
        fast_steps = 2 * math.ceil(math.log2(max(2, f)))
        ops = 3 if multi else 2
        codec = 0.0
    elif mode == "compressed":
        if not multi or not (0.0 < frac <= 1.0):
            return float("inf")
        shard = nbytes / f
        slow_b = 2.0 * frac * shard          # (vals, idx) pairs
        fast_b = 2.0 * nbytes * (f - 1) / f
        slow_steps = 2 * math.ceil(math.log2(H)) + 2   # two gathers
        fast_steps = 2 * math.ceil(math.log2(max(2, f)))
        ops = 5
        codec = link.codec_lat_s + 2.0 * shard / link.codec_bps
    else:
        raise ValueError(f"unknown collective mode: {mode}")
    return (slow_b / link.slow_bps + fast_b / link.fast_bps
            + slow_steps * link.slow_lat_s + fast_steps * link.fast_lat_s
            + ops * link.launch_s + codec)


def schedule_costs(topo: Topology, nbytes: int,
                   link: Optional[LinkProfile] = None,
                   frac: float = 0.05,
                   modes: Sequence[str] = MODES) -> Dict[str, float]:
    return {m: schedule_cost(topo, nbytes, m, link, frac) for m in modes}


def best_schedule(topo: Topology, nbytes: int,
                  link: Optional[LinkProfile] = None,
                  frac: float = 0.05,
                  modes: Sequence[str] = MODES,
                  measured: Optional[Mapping[str, float]] = None
                  ) -> Tuple[str, float]:
    """(mode, predicted seconds) of the cheapest *available* schedule.
    ``measured`` overrides the analytical estimate per mode (the
    tuner's one-shot probe refinement)."""
    costs = schedule_costs(topo, nbytes, link, frac, modes)
    if measured:
        for m, t in measured.items():
            if m in costs and costs[m] != float("inf"):
                costs[m] = float(t)
    mode = min(costs, key=lambda m: costs[m])
    return mode, costs[mode]


def crossover_bytes(topo: Topology, lo_mode: str, hi_mode: str,
                    link: Optional[LinkProfile] = None,
                    frac: float = 0.05) -> Optional[int]:
    """Smallest bucketed message size where ``hi_mode`` beats
    ``lo_mode`` (None if it never does in the bucket range)."""
    for b in range(MIN_BUCKET, MAX_BUCKET + 1):
        nb = bucket_nbytes(b)
        if (schedule_cost(topo, nb, hi_mode, link, frac)
                < schedule_cost(topo, nb, lo_mode, link, frac)):
            return nb
    return None
