"""Spot fleet end-to-end: a train+serve tenant mix rides out a
spot-reclaim wave — the serving gang drains *gracefully* off its
reclaimed host (live evacuation, zero lost requests), the training gang
loses its host to a hard failure and recovers *bit-exactly* from its
last snapshot, and a replacement host leases in from the spare pool
(core.fleet + the rFaaS-style reclaimable-executor story).

Act 2 replays the same hard failure against a *risk-aware* fabric
(``CostModel(risk_tau_s=...)`` + ``shrink_recovery=True``): the wide
training gang that act 1 would have rolled back instead sheds the dead
host's chips, keeps training at reduced width on the survivors, and
regrows to its submitted width the moment the replacement host joins —
zero lost work, and the live Action log still matches the simulator's
prediction step for step.

Run:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spot_fleet.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import reduced_config
from repro.core.fabric import Fabric
from repro.core.fleet import FleetEvent
from repro.core.simulator import Job
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.gang_workloads import workload_factory


def main():
    cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
    dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8, seed=0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    devs = jax.devices()
    assert len(devs) >= 8, "run with host_platform_device_count=8"
    # 3 leased hosts of 2 chips; one spare host staged for the rejoin
    fabric = Fabric(devices=devs[:6], chips_per_host=2,
                    spares=devs[6:8])
    print(f"fabric: {fabric.engine.hosts} hosts x 2 chips, "
          f"{len(fabric.spares)} spare chips staged")

    serve_tokens = 4
    jobs = [
        Job("serve-0", "omp", 2, 150.0, arrival=0.0, priority=1,
            workload="serve"),
        Job("train-0", "mpi-compute", 2, 180.0, arrival=0.0,
            workload="train"),
    ]
    # the spot wave: the serve gang's host is lease-reclaimed with a
    # drain window (graceful), the train gang's host hard-fails with no
    # warning, and a replacement host joins from the spares
    wave = [
        FleetEvent(5.0, "reclaim", hosts=[2], drain_s=20.0),
        FleetEvent(8.0, "fail", hosts=[1]),
        FleetEvent(12.0, "join", capacities=[2]),
    ]

    predicted = fabric.predict_trace(jobs, preempt=True,
                                     fleet_events=wave,
                                     checkpoint_interval=4.0)
    ex = fabric.run_trace(
        jobs, workload_factory(cfg, ocfg, dcfg, train_steps=4,
                               serve_tokens=serve_tokens),
        preempt=True, fleet_events=wave, checkpoint_interval=4.0)
    res = ex.result

    print("churn events:", [(a.kind, a.payload.get("hosts"))
                            for a in res.actions
                            if a.kind in ("drain", "evacuate",
                                          "host-fail", "recover",
                                          "join", "retire")])
    assert res.actions == predicted.actions, \
        "live churn diverged from the simulator's prediction"
    assert res.evacuations >= 1, "serve gang should drain gracefully"
    assert res.recoveries >= 1, "train gang should recover from snapshot"
    assert set(res.finish_order) == {j.job_id for j in jobs}

    # zero lost serve requests: every request decoded its full budget,
    # and the serve gang was never rolled back
    serve = ex.live["serve-0"]
    outputs = serve["final_metrics"]["outputs"]
    assert all(len(o) == serve_tokens for o in outputs), outputs
    assert serve.get("failures", 0) == 0
    print(f"serve-0 drained gracefully: {len(outputs)} requests x "
          f"{serve_tokens} tokens, zero lost ({outputs})")

    train = ex.live["train-0"]
    assert train.get("failures", 0) >= 1
    assert train["resumes_verified"] >= 1
    print(f"train-0 survived the hard failure: "
          f"{train['failures']} failure(s), "
          f"{train['resumes_verified']} bit-exact resume(s), "
          f"final loss {train['final_metrics']['loss']:.4f}")
    print("spot wave survived: completion order", res.finish_order,
          "makespan", round(res.makespan, 1), "s ✓")

    # ---- act 2: the same failure, but risk-aware ----------------------
    # a 4-chip gang spans two hosts; losing one would roll it back to
    # its last snapshot.  With the risk term on and shrink_recovery
    # enabled it sheds the dead host instead (live reshard from a
    # surviving replica), then regrows when the spare host joins.
    from repro.core.placement import CostModel

    fabric2 = Fabric(devices=devs[:6], chips_per_host=2,
                     spares=devs[6:8],
                     cost_model=CostModel(risk_tau_s=4.0))
    jobs2 = [
        Job("train-wide", "mpi-compute", 4, 200.0, arrival=0.0,
            workload="train"),
        Job("serve-1", "omp", 2, 120.0, arrival=0.0, priority=1,
            workload="serve"),
    ]
    wave2 = [
        FleetEvent(6.0, "fail", hosts=[0]),
        FleetEvent(10.0, "join", capacities=[2]),
    ]
    predicted2 = fabric2.predict_trace(jobs2, preempt=True,
                                       fleet_events=wave2,
                                       checkpoint_interval=4.0,
                                       shrink_recovery=True)
    ex2 = fabric2.run_trace(
        jobs2, workload_factory(cfg, ocfg, dcfg, train_steps=4,
                                serve_tokens=serve_tokens),
        preempt=True, fleet_events=wave2, checkpoint_interval=4.0,
        shrink_recovery=True)
    res2 = ex2.result

    assert res2.actions == predicted2.actions, \
        "risk-aware live run diverged from the simulator's prediction"
    assert res2.shrinks >= 1, "gang should shrink onto survivors"
    assert res2.regrows >= 1, "gang should regrow when the spare joins"
    assert res2.recoveries == 0 and res2.lost_work_s == 0.0, \
        "shrink-before-rollback should make the rollback unnecessary"
    train2 = ex2.live["train-wide"]
    print(f"train-wide shrank {train2.get('shrinks', 0)}x and regrew "
          f"{train2.get('regrows', 0)}x instead of rolling back: "
          f"0.0s lost work (act 1's train-0 lost "
          f"{round(res.lost_work_s, 1)}s), final loss "
          f"{train2['final_metrics']['loss']:.4f}")
    print("risk-aware wave survived: completion order",
          res2.finish_order, "makespan", round(res2.makespan, 1),
          "s ✓")


if __name__ == "__main__":
    main()
