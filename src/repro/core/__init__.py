"""Faabric-on-TPU core: Granules, snapshots, diff-sync, hierarchical
collectives, chip-granular scheduling, migration, elasticity, and the
trace simulator (the paper's primary contribution, adapted per DESIGN.md)."""
