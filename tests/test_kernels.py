"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp ref.py oracles (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

key = jax.random.PRNGKey(0)
sub = lambda i: jax.random.fold_in(key, i)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,s,hd", [
    (2, 4, 4, 256, 64), (1, 8, 2, 256, 64), (2, 4, 2, 512, 128),
    (1, 2, 1, 128, 64),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, hd, causal, window, dtype):
    from repro.kernels.flash_attention import kernel as K, ref as R
    q = jax.random.normal(sub(1), (b, h, s, hd), dtype)
    k = jax.random.normal(sub(2), (b, kv, s, hd), dtype)
    v = jax.random.normal(sub(3), (b, kv, s, hd), dtype)
    out = K.flash_attention(q, k, v, causal=causal, window=window,
                            interpret=True)
    expect = R.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_ops_layout_and_padding():
    from repro.kernels.flash_attention import ops as O
    from repro.models.attention import sdpa
    b, s, h, kv, hd = 2, 256, 4, 2, 80   # hd=80: exercises lane padding
    q = jax.random.normal(sub(4), (b, s, h, hd))
    k = jax.random.normal(sub(5), (b, s, kv, hd))
    v = jax.random.normal(sub(6), (b, s, kv, hd))
    out = O.flash_attention(q, k, v, causal=True, interpret=True)
    expect = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=2e-5,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# diff_merge
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("op", ["sum", "subtract", "multiply", "divide",
                                "overwrite"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_diff_merge(op, dtype):
    from repro.kernels.diff_merge import kernel as K, ref as R
    a0 = (jax.random.normal(sub(7), (32, 1024)) + 2.0).astype(dtype)
    b0 = a0 + jnp.zeros_like(a0)
    b1 = b0.at[3:7].add(1.5).at[20].multiply(1.25)
    out, dirty = K.diff_merge(a0, b0, b1, op=op, interpret=True)
    eout, edirty = R.diff_merge_ref(a0, b0, b1, op=op)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(eout, np.float32), atol=1e-5,
                               rtol=1e-5)
    assert bool((dirty == edirty).all())
    assert int(dirty.sum()) == 5


def test_diff_merge_leaf_wrapper_odd_shapes():
    from repro.kernels.diff_merge import ops as O
    x0 = jax.random.normal(sub(8), (13, 77))
    b0 = x0 + 0.0
    b1 = b0.at[5].add(1.0)
    m, d = O.diff_merge_leaf(x0, b0, b1, op="sum", interpret=True)
    np.testing.assert_allclose(np.asarray(m), np.asarray(x0 + (b1 - b0)),
                               atol=1e-6)
    assert m.shape == x0.shape


@pytest.mark.parametrize("op", ["sum", "subtract", "overwrite"])
def test_diff_merge_int32_exact(op):
    """Integer leaves merge exactly in the kernel — no float cast."""
    from repro.kernels.diff_merge import kernel as K, ref as R
    rng = np.random.default_rng(0)
    a0 = jnp.asarray(rng.integers(-2**30, 2**30, (16, 1024)),
                     dtype=jnp.int32)
    b0 = a0 + jnp.zeros_like(a0)
    b1 = b0.at[3:5].add(7)
    out, dirty = K.diff_merge(a0, b0, b1, op=op, interpret=True)
    eout, edirty = R.diff_merge_ref(a0, b0, b1, op=op)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(eout))
    if op == "overwrite":
        expect = np.asarray(a0).copy()
        expect[3:5] = np.asarray(b1)[3:5]
    else:
        expect = np.asarray(a0).copy()
        expect[3:5] += 7
    np.testing.assert_array_equal(np.asarray(out), expect)
    assert int(dirty.sum()) == 2


def test_diff_merge_leaf_f64_keeps_precision():
    """f64 leaves keep full precision through the kernel path (the old
    blanket float32 cast flattened sub-f32 deltas)."""
    from jax.experimental import enable_x64
    from repro.kernels.diff_merge import ops as O
    with enable_x64():
        a0 = jnp.full((3000,), 1.0, dtype=jnp.float64)
        b0 = a0 + 0.0
        b1 = b0.at[:1024].add(1e-12)
        m, d = O.diff_merge_leaf(a0, b0, b1, op="sum", interpret=True)
        assert m.dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(m), np.asarray(b1))
        assert int(d.sum()) == 1


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("op", ["sum", "overwrite", "multiply"])
def test_diff_merge_leaf_roundtrip_ragged(op, dtype):
    """Kernel-path diff -> merge on a ragged leaf reproduces the child
    under op semantics, across dtypes (satellite 3)."""
    from repro.kernels.diff_merge import ops as O
    if jnp.issubdtype(dtype, jnp.integer):
        a0 = jnp.arange(3333, dtype=dtype) % 100 + 1
    else:
        a0 = (jax.random.uniform(sub(9), (3333,)) + 1.0).astype(dtype)
    b0 = a0 + jnp.zeros_like(a0)
    if op == "multiply":
        b1 = b0.at[100:400].multiply(2)
    else:
        b1 = b0.at[100:400].add(3)
    m, _ = O.diff_merge_leaf(a0, b0, b1, op=op, interpret=True)
    assert m.dtype == a0.dtype and m.shape == a0.shape
    if op == "overwrite" or op == "sum":
        np.testing.assert_allclose(np.asarray(m, np.float64),
                                   np.asarray(b1, np.float64),
                                   rtol=1e-2 if dtype == jnp.bfloat16
                                   else 0)
    else:
        np.testing.assert_allclose(np.asarray(m, np.float64),
                                   np.asarray(b1, np.float64),
                                   rtol=1e-2 if dtype == jnp.bfloat16
                                   else 1e-6)


# ---------------------------------------------------------------------------
# moe_gmm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e,m,d,ff,act", [
    (4, 256, 64, 256, "silu"), (2, 128, 128, 512, "gelu"),
    (8, 64, 32, 128, "silu"),
])
def test_moe_gmm(e, m, d, ff, act):
    from repro.kernels.moe_gmm import kernel as K, ref as R
    x = jax.random.normal(sub(9), (e, m, d)) * 0.5
    w1 = jax.random.normal(sub(10), (e, d, ff)) * 0.05
    w2 = jax.random.normal(sub(11), (e, ff, d)) * 0.05
    w3 = jax.random.normal(sub(12), (e, d, ff)) * 0.05
    out = K.expert_ffn(x, w1, w2, w3, act=act, block_m=64, block_f=128,
                       interpret=True)
    expect = R.expert_ffn_ref(x, w1, w2, w3, act=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-4)


def test_moe_gmm_matches_model_path():
    """Kernel path through moe_ffn == reference einsum path."""
    from repro.configs.registry import reduced_config
    from repro.models import moe as moe_mod
    cfg = reduced_config("granite-moe-1b-a400m").with_(capacity_factor=8.0)
    params = jax.jit(lambda k: moe_mod.init_moe(k, cfg))(sub(13))
    x = jax.random.normal(sub(14), (2, 64, cfg.d_model))
    y_ref, aux_ref = jax.jit(
        lambda p, x: moe_mod.moe_ffn(p, x, cfg))(params, x)
    cfg_k = cfg.with_(use_pallas_kernels=True)
    y_k, aux_k = jax.jit(
        lambda p, x: moe_mod.moe_ffn(p, x, cfg_k))(params, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,l,p,n,chunk", [
    (2, 3, 128, 32, 16, 32), (1, 2, 256, 64, 64, 64), (2, 2, 64, 16, 8, 16),
])
def test_mamba_scan(b, h, l, p, n, chunk):
    from repro.kernels.mamba_scan import kernel as K, ref as R
    x = jax.random.normal(sub(15), (b, h, l, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(sub(16), (b, h, l, 1)))
    a = -jnp.exp(jax.random.normal(sub(17), (h, 1, 1)) * 0.3)
    bb = jax.random.normal(sub(18), (b, l, n)) * 0.5
    cc = jax.random.normal(sub(19), (b, l, n)) * 0.5
    y, s = K.ssd_scan(x, dt, a.astype(jnp.float32), bb, cc, chunk=chunk,
                      interpret=True)
    ye, se = R.ssd_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=5e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(se), atol=5e-5,
                               rtol=1e-3)


def test_mamba_ops_matches_model_chunked():
    from repro.kernels.mamba_scan import ops as O
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n = 2, 128, 4, 16, 8
    x = jax.random.normal(sub(20), (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(sub(21), (b, l, h)))
    a = -jnp.exp(jax.random.normal(sub(22), (h,)) * 0.3)
    bb = jax.random.normal(sub(23), (b, l, n)) * 0.5
    cc = jax.random.normal(sub(24), (b, l, n)) * 0.5
    y_k, s_k = O.ssd(x, dt, a, bb, cc, chunk=32, interpret=True)
    y_r, s_r = ssd_chunked(x, dt, a, bb, cc, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=5e-5,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# mlstm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,l,hd,chunk", [
    (2, 2, 128, 32, 32), (1, 4, 256, 64, 64), (2, 1, 64, 16, 16),
])
def test_mlstm_kernel(b, h, l, hd, chunk):
    from repro.kernels.mlstm import kernel as K, ref as R
    q = jax.random.normal(sub(25), (b, h, l, hd))
    k = jax.random.normal(sub(26), (b, h, l, hd))
    v = jax.random.normal(sub(27), (b, h, l, hd))
    li = jax.random.normal(sub(28), (b, h, l, 1)) - 1
    lf = -jax.nn.softplus(jax.random.normal(sub(29), (b, h, l, 1)))
    hh, c, n, m = K.mlstm_scan(q, k, v, li, lf, chunk=chunk, interpret=True)
    he, (ce, ne, me) = R.mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(hh), np.asarray(he), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c), np.asarray(ce), atol=1e-4,
                               rtol=1e-3)
    # m is a log-domain stabiliser: only exp-differences matter
    np.testing.assert_allclose(np.asarray(m[..., 0, 0]),
                               np.asarray(me[..., 0, 0]), atol=1e-3)


def test_mlstm_ops_matches_model_chunked():
    from repro.kernels.mlstm import ops as O
    from repro.models.xlstm import mlstm_chunked
    b, l, h, hd = 2, 128, 2, 32
    q = jax.random.normal(sub(30), (b, l, h, hd))
    k = jax.random.normal(sub(31), (b, l, h, hd))
    v = jax.random.normal(sub(32), (b, l, h, hd))
    li = jax.random.normal(sub(33), (b, l, h)) - 1
    lf = -jax.nn.softplus(jax.random.normal(sub(34), (b, l, h)))
    h_k, (c_k, n_k, m_k) = O.mlstm(q, k, v, li, lf, chunk=32,
                                   interpret=True)
    h_r, (c_r, n_r, m_r) = mlstm_chunked(q, k, v, li, lf, chunk=32)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# collective_codec (chunk-max threshold select)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k,m", [(8, 16), (8, 128), (16, 1), (24, 33),
                                 (1, 64)])
def test_collective_codec_kernel_matches_ref(k, m):
    from repro.kernels.collective_codec import kernel as K
    from repro.kernels.collective_codec import ref as R
    x = jax.random.normal(sub(40), (k, m))
    rows = K.BLOCK_ROWS if k % K.BLOCK_ROWS == 0 else 1
    vals, col, resid = K.chunk_select(x, block_rows=rows, interpret=True)
    v_r, c_r, r_r = R.chunk_select_ref(x)
    # bit-exact: the kernel and ref share the min-lane-argmax formulation
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(col), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(resid), np.asarray(r_r))


@pytest.mark.parametrize("n,frac", [(1000, 0.1), (7, 0.3), (4096, 0.05),
                                    (100, 1.0), (1, 0.5), (1 << 17, 0.05)])
def test_collective_codec_roundtrip_exact(n, frac):
    from repro.kernels.collective_codec import ops as O
    vec = jax.random.normal(sub(41), (n,))
    # big sizes force the kernel path explicitly (default routing keeps
    # non-TPU backends on the ref)
    kw = dict(use_kernel=True, interpret=True) if n >= O.KERNEL_MIN_SIZE \
        else {}
    vals, idx, resid = O.select_codec(vec, frac=frac, **kw)
    k, m, _ = O.codec_geometry(n, frac)
    assert vals.shape == (k,) and idx.shape == (k,)
    assert idx.dtype == jnp.int32
    recon = jnp.zeros((n,)).at[idx].add(vals) + resid
    # selected + residual reconstructs the input exactly (error feedback
    # invariant), for both the ref path and the kernel path (n = 2^17)
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(vec))
    # each chunk's pick is its own largest-|x| element
    mag = np.abs(np.asarray(vec))
    for i in range(k):
        lo, hi = i * m, min((i + 1) * m, n)
        if lo >= n:
            continue
        assert mag[int(idx[i])] == mag[lo:hi].max()


def test_collective_codec_frac_one_is_identity():
    from repro.kernels.collective_codec import ops as O
    vec = jax.random.normal(sub(42), (257,))
    vals, idx, resid = O.select_codec(vec, frac=1.0)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(257))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vec))
    assert not np.asarray(resid).any()
