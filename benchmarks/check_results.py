"""CI gate: every standardized benchmark artifact in results/ must
parse as JSON and carry a non-empty ``metrics`` table (schema in
``benchmarks/run.py``).  Covers both the committed full-size
``BENCH_*.json`` trajectory and freshly-produced ``SMOKE_*.json``.

Two stronger checks ride on top (the delta data plane's perf gate):

* **required metrics** — ``bench_shared_memory`` artifacts must report
  ``merge_apply_throughput`` and ``delta_checkpoint_bytes``; a refactor
  that silently drops the data-plane measurements fails the gate.
* **regression guard** — metrics listed in
  ``benchmarks/recorded_baselines.json`` (committed, since results/ is
  gitignored) must stay within 2x of their recorded value; a merge
  throughput collapse back toward the chunk-loop reference
  (~100x slower) fails loudly even at smoke tier.

Schema-3 artifacts additionally carry telemetry sidecars (schema-2
artifacts, lacking the keys, skip these checks — back-compat):

* the ``telemetry_summary`` file must parse, and for scheduler-driven
  benches (``TELEMETRY_REQUIRED``) must hold nonzero spans and a
  populated ``placement.decision_latency_s`` histogram;
* the ``trace`` file (smoke tier) must parse as Chrome trace-event
  JSON (Perfetto-loadable: non-empty ``traceEvents``, each with
  ``ph``/``name``);
* ``*_perfetto.json`` exports must cover all five instrumented layers
  and ``*_diff.json`` predicted-vs-live reports must show zero
  divergence (bench_telemetry's acceptance artifacts).
"""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
BASELINES = os.path.join(os.path.dirname(__file__),
                         "recorded_baselines.json")

# bench name -> metrics every artifact of that bench must report
REQUIRED_METRICS = {
    "bench_shared_memory": ("merge_apply_throughput",
                            "delta_checkpoint_bytes"),
    "bench_message_passing": ("hierarchical_vs_flat_speedup",
                              "compressed_vs_flat_speedup",
                              "compressed_crossover_bytes",
                              "slowlink_bytes_flat",
                              "slowlink_bytes_hierarchical",
                              "codec_select_speedup"),
    "bench_makespan": ("collective_priced/improvement",),
    "bench_serving": ("continuous_vs_fixed/min_throughput_ratio",
                      "burst_autoscaler/p99_within_target",
                      "train_serve/drain_saves_work_s",
                      "train_serve/p99_within_target"),
    "bench_churn": tuple(
        [f"risk/{r}/{m}" for r in ("spot-heavy", "steady-join",
                                   "correlated-rack-failure")
         for m in ("lost_work_blind_s", "lost_work_aware_s",
                   "inflation_pct_aware", "improves")]
        + ["risk/correlated-rack-failure/shrink_recoveries",
           "risk/aware_identical_rerun", "risk/off_bit_identical"]),
    "bench_telemetry": ("diff/zero_divergence", "trace/layers_present",
                        "telemetry/spans_total",
                        "telemetry/decision_latency_count"),
}
REGRESSION_FACTOR = 2.0

# benches that drive the placement engine / simulator: their schema-3
# telemetry summaries must show real recorded spans and a populated
# decision-latency histogram (bench_telemetry runs in a subprocess and
# asserts the same through its own metrics + sidecar artifacts)
TELEMETRY_REQUIRED = ("bench_makespan", "bench_scaling",
                      "bench_scheduler_scale", "bench_churn")

# every layer bench_telemetry's exported Perfetto timeline must cover
# (event ``cat`` = span/counter name prefix)
REQUIRED_LAYERS = ("placement", "gang", "ckpt", "collective", "serve")

# hard acceptance gates, full-tier (BENCH_*) artifacts only — smoke
# sizes are too small for the Fig 9 schedule gaps to show:
#  * the two-level schedule must beat flat >= 2x on the slow-link mesh,
#  * the compressed schedule must beat flat past a measured crossover,
#  * collective_time-scored placement must beat scalar-beta on the
#    net-heavy trace
FULL_TIER_GATES = {
    "bench_message_passing": (
        ("hierarchical_vs_flat_speedup", 2.0),
        ("compressed_vs_flat_speedup", 1.0),
        ("compressed_crossover_bytes", 0.0),
    ),
    "bench_makespan": (
        ("collective_priced/improvement", 0.0),
    ),
}

# gates enforced on BOTH tiers (BENCH_* and SMOKE_*): bench_serving
# and bench_churn run on deterministic virtual clocks, so their
# acceptance criteria — continuous batching strictly out-throughputs
# fixed batching at every offered load, the autoscaler holds the p99
# SLO under burst / combined train+serve load, and risk-aware placement
# + shrink-before-rollback loses no more work and no more makespan than
# the risk-blind arm in every churn regime (with the correlated-rack
# case recovering stranded gangs by shrinking, and the risk term
# staying bit-identical when off) — are exact even at smoke sizes
ALL_TIER_GATES = {
    "bench_serving": (
        ("continuous_vs_fixed/min_throughput_ratio", 1.0),
        ("burst_autoscaler/p99_within_target", 0.0),
        ("train_serve/drain_saves_work_s", 0.0),
        ("train_serve/p99_within_target", 0.0),
    ),
    "bench_churn": (
        ("risk/spot-heavy/improves", 0.0),
        ("risk/steady-join/improves", 0.0),
        ("risk/correlated-rack-failure/improves", 0.0),
        ("risk/correlated-rack-failure/shrink_recoveries", 0.0),
        ("risk/aware_identical_rerun", 0.0),
        ("risk/off_bit_identical", 0.0),
    ),
    # telemetry plane acceptance: the live fabric replays the simulator
    # event-for-event while recording, and the exported timeline covers
    # every instrumented layer — exact at smoke sizes (virtual clocks)
    "bench_telemetry": (
        ("diff/zero_divergence", 0.0),
        ("trace/layers_present", len(REQUIRED_LAYERS) - 1),
        ("telemetry/spans_total", 0.0),
        ("telemetry/decision_latency_count", 0.0),
    ),
}


def _chrome_trace_errors(path: str) -> list:
    """Why ``path`` is not a loadable Chrome trace-event JSON (empty
    list = it is)."""
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace ({e})"]
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["trace has no traceEvents"]
    bad = [e for e in events
           if not isinstance(e, dict) or "ph" not in e or "name" not in e]
    if bad:
        return [f"{len(bad)} events lack ph/name"]
    return []


def _telemetry_errors(payload: dict) -> list:
    """Schema-3 sidecar checks for one artifact (schema-2 artifacts
    carry neither key and pass vacuously)."""
    errors = []
    summary_path = payload.get("telemetry_summary")
    if summary_path:
        try:
            with open(summary_path) as f:
                summary = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable telemetry summary ({e})"]
        if payload.get("bench") in TELEMETRY_REQUIRED:
            if not summary.get("spans_total"):
                errors.append("telemetry summary has zero spans")
            hist = summary.get("histograms", {})
            if not hist.get("placement.decision_latency_s",
                            {}).get("count"):
                errors.append("placement.decision_latency_s histogram "
                              "missing or empty")
    elif payload.get("schema", 2) >= 3:
        errors.append("schema>=3 artifact lacks telemetry_summary")
    trace_path = payload.get("trace")
    if trace_path:
        errors += _chrome_trace_errors(trace_path)
    return errors


def _sidecar_artifacts() -> list:
    """bench_telemetry's own exports: the Perfetto timeline must cover
    every instrumented layer, the diff report must show zero
    predicted-vs-live divergence."""
    problems = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              "*_perfetto.json"))):
        name = os.path.basename(path)
        errs = _chrome_trace_errors(path)
        if not errs:
            with open(path) as f:
                cats = {e.get("cat") for e in
                        json.load(f)["traceEvents"]}
            missing = [l for l in REQUIRED_LAYERS if l not in cats]
            if missing:
                errs = [f"layers missing from timeline: {missing}"]
        problems += [(name, e) for e in errs]
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              "*_diff.json"))):
        name = os.path.basename(path)
        try:
            with open(path) as f:
                diff = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append((name, f"unreadable diff report ({e})"))
            continue
        if diff.get("divergences") != 0:
            problems.append(
                (name, f"predicted-vs-live divergences = "
                       f"{diff.get('divergences')} (first: "
                       f"{diff.get('first_divergence')})"))
        if not isinstance(diff.get("phase_error"), dict):
            problems.append((name, "diff report lacks phase_error"))
    return problems


def _baselines() -> dict:
    try:
        with open(BASELINES) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    return {k: v for k, v in data.items() if isinstance(v, dict)}


def main() -> int:
    # telemetry sidecars share the BENCH_/SMOKE_ prefix but are not
    # bench artifacts; they get their own checks below
    sidecar_suffixes = ("_telemetry.json", "_trace.json",
                        "_perfetto.json", "_diff.json")
    paths = sorted(p for p in
                   glob.glob(os.path.join(RESULTS_DIR, "BENCH_*.json"))
                   + glob.glob(os.path.join(RESULTS_DIR,
                                            "SMOKE_*.json"))
                   if not p.endswith(sidecar_suffixes))
    if not paths:
        print("no BENCH_*/SMOKE_* artifacts found", file=sys.stderr)
        return 1
    bad = 0
    baselines = _baselines()
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {name}: unreadable ({e})", file=sys.stderr)
            bad += 1
            continue
        metrics = payload.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            print(f"FAIL {name}: empty or missing metrics",
                  file=sys.stderr)
            bad += 1
            continue
        bench = payload.get("bench")
        missing = [m for m in REQUIRED_METRICS.get(bench, ())
                   if m not in metrics]
        if missing:
            print(f"FAIL {name}: missing required metrics "
                  f"{missing}", file=sys.stderr)
            bad += 1
            continue
        regressed = []
        for metric, floor in baselines.get(bench, {}).items():
            cur = metrics.get(metric, {})
            value = cur.get("value") if isinstance(cur, dict) else None
            if not isinstance(value, (int, float)):
                continue
            if value * REGRESSION_FACTOR < floor:
                regressed.append(
                    f"{metric}={value} (recorded {floor}, floor "
                    f"{round(floor / REGRESSION_FACTOR, 2)})")
        if regressed:
            print(f"FAIL {name}: regression guard: "
                  f"{'; '.join(regressed)}", file=sys.stderr)
            bad += 1
            continue
        gates = list(ALL_TIER_GATES.get(bench, ()))
        if name.startswith("BENCH_"):
            gates += list(FULL_TIER_GATES.get(bench, ()))
        gated = []
        for metric, floor in gates:
            cur = metrics.get(metric, {})
            value = cur.get("value") if isinstance(cur, dict) \
                else None
            if not isinstance(value, (int, float)) \
                    or value <= floor:
                gated.append(f"{metric}={value} (must be > {floor})")
        if gated:
            print(f"FAIL {name}: acceptance gate: "
                  f"{'; '.join(gated)}", file=sys.stderr)
            bad += 1
            continue
        tel_errors = _telemetry_errors(payload)
        if tel_errors:
            print(f"FAIL {name}: telemetry: {'; '.join(tel_errors)}",
                  file=sys.stderr)
            bad += 1
            continue
        print(f"ok   {name}: {len(metrics)} metrics "
              f"(bench={payload.get('bench')}, "
              f"schema={payload.get('schema', 2)}, "
              f"wall={payload.get('wall_s')}s)")
    for name, problem in _sidecar_artifacts():
        print(f"FAIL {name}: {problem}", file=sys.stderr)
        bad += 1
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
