"""zamba2-2.7b: 54L d2560 32H (GQA kv=32) d_ff=10240, ssm_state=64.

Mamba2 backbone + one SHARED attention block applied every 6th layer
(paper-faithful weight sharing).  [arXiv:2411.15242; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=64,
    shared_attn_every=6,
    rope_theta=10_000.0,
    window=4096,  # used only for the long_500k shape (see DESIGN.md)
)
