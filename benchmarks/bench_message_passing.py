"""Paper Fig 13 (MPI / ParRes kernels) + Fig 9 (two-level schedules).

Runs the ParRes-analogue kernels on a 2x4 (pod, data) host mesh in a
subprocess (so the main process keeps 1 device):

  p2p      ring exchange via collective-permute (paper: p2p kernel)
  nstream  axpy over sharded arrays + barrier  (paper: nstream)
  reduce   all-reduce size sweep: flat vs hierarchical vs ring vs
           compressed (threshold-select codec)
  stencil  halo exchange via ppermute          (paper: stencil)

Slow-link byte counts per schedule are *measured* from the compiled HLO
(``collectives.slowlink_bytes_from_hlo``), not assumed.  The forced-host
CPU mesh has no real slow link, so each schedule's headline time is its
``effective_s``: wall time plus measured slow bytes over the modeled
cross-pod bandwidth — the quantity Faabric's VM-leader schedule
minimises (Fig 9).  The sweep also locates the compressed-vs-flat
crossover size and A/Bs the vectorized chunk-select codec against the
old global top-k.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

FLEET = {"hosts": 2, "chips_per_host": 4, "mesh": "2x4 (pod, data)",
         "slow_bps": 0.025e9, "backend": "cpu-forced-host"}

_PROG = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.core import comms
from repro.core.compat import make_mesh, shard_map
from repro.kernels.collective_codec import ops as codec_ops

mesh = make_mesh((2, 4), ("pod", "data"))
# bench link: a congested cross-VM link (200 Mbit/s) — the Fig 9 regime
# where schedule choice matters; chip-local walls on the forced-host CPU
# mesh are large relative to a datacenter slow link, so the emulated
# cross-pod term must dominate for the schedule gap to be visible
link = comms.LinkProfile(slow_bps=0.025e9)
out = {}
REPS = __REPS__
LOGS = __LOGS__          # sweep: log2(elements); bytes = 4 << log
TOP = LOGS[-1]

def timeit(f, *args, reps=REPS):
    r = jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps

n = 1 << TOP
vec = jnp.arange(8 * n, dtype=jnp.float32).reshape(8, n)

# --- p2p ring (collective-permute) ---
def p2p(x):
    def body(v):
        perm = [(i, (i + 1) % 4) for i in range(4)]
        return jax.lax.ppermute(v, "data", perm)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data")),
                                 out_specs=P(("pod","data")),
                                 check_vma=False))(x)
p2p_s = timeit(p2p, vec)
out["p2p_ring_us"] = p2p_s * 1e6
# every chip forwards its n-element shard once per step
out["fastlink_gbps_measured"] = (n * 4 / p2p_s) / 1e9

# --- nstream: axpy + allreduce barrier ---
def nstream(x):
    def body(v):
        v = v * 2.0 + 1.0
        s = jax.lax.psum(jnp.sum(v), ("pod", "data"))
        return v + 0.0 * s
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data")),
                                 out_specs=P(("pod","data")),
                                 check_vma=False))(x)
out["nstream_us"] = timeit(nstream, vec) * 1e6

# --- reduce: size sweep, all four schedules ---
# measure_schedule times the jitted all-reduce AND reads its slow-link
# bytes off the compiled HLO; effective_s adds the modeled cross-pod
# transfer (no real slow link on a forced-host mesh).
sweep = {}
for log in LOGS:
    nbytes = 4 << log
    for mode in comms.MODES:
        m = C.measure_schedule(mesh, mode, nbytes, compress_frac=0.05,
                               reps=REPS, link=link, emulate_slow=True)
        sweep[(log, mode)] = m
for mode in comms.MODES:
    m = sweep[(TOP, mode)]
    out[f"allreduce_{mode}_us"] = m["wall_s"] * 1e6
    out[f"allreduce_{mode}_effective_us"] = m["effective_s"] * 1e6
    out[f"slowlink_bytes_{mode}"] = m["slowlink_bytes"]

out["hierarchical_vs_flat_speedup"] = (
    sweep[(TOP, "flat")]["effective_s"]
    / sweep[(TOP, "hierarchical")]["effective_s"])
out["compressed_vs_flat_speedup"] = (
    sweep[(TOP, "flat")]["effective_s"]
    / sweep[(TOP, "compressed")]["effective_s"])

# smallest swept size where the compressed schedule beats flat; -1 when
# it never does (check_results asserts it exists at full tier)
cross = -1
for log in LOGS:
    if (sweep[(log, "compressed")]["effective_s"]
            < sweep[(log, "flat")]["effective_s"]):
        cross = 4 << log
        break
out["compressed_crossover_bytes"] = cross
topo = comms.Topology(hosts=2, chips=8, min_fast=4)
out["compressed_crossover_bytes_analytic"] = comms.crossover_bytes(
    topo, "flat", "compressed", link)

# --- codec A/B: chunk-select kernel vs old global top-k ---
shard = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                    jnp.float32)
t_new = timeit(lambda v: codec_ops.select_codec(v, frac=0.05)[0], shard)
t_old = timeit(lambda v: C.reference_topk_select(v, 0.05)[0], shard)
out["codec_select_us"] = t_new * 1e6
out["codec_topk_us"] = t_old * 1e6
out["codec_select_speedup"] = t_old / t_new

# --- stencil: halo exchange ---
def stencil(x):
    def body(v):
        perm_f = [(i, (i + 1) % 4) for i in range(4)]
        perm_b = [((i + 1) % 4, i) for i in range(4)]
        left = jax.lax.ppermute(v[:, -128:], "data", perm_f)
        right = jax.lax.ppermute(v[:, :128], "data", perm_b)
        mid = v.at[:, :128].add(left).at[:, -128:].add(right)
        return mid * 0.25
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data"), None),
                                 out_specs=P(("pod","data"), None),
                                 check_vma=False))(x)
grid = jnp.ones((8, 4096), jnp.float32)
out["stencil_us"] = timeit(stencil, grid) * 1e6

print(json.dumps(out))
"""


def run(report, tiny=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    logs = "[12, 14]" if tiny else "[12, 14, 16, 18, 20]"
    prog = textwrap.dedent(_PROG) \
        .replace("__REPS__", "2" if tiny else "10") \
        .replace("__LOGS__", logs)
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, env=env,
                         timeout=1800)
    assert res.returncode == 0, res.stderr[-3000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    for k, v in data.items():
        if k.endswith("_us"):
            unit = "us"
        elif k.endswith("_bytes") or k.startswith("slowlink_bytes"):
            unit = "bytes"
        elif k.endswith("_speedup"):
            unit = "x"
        elif k.endswith("_gbps_measured"):
            unit = "GB/s"
        else:
            unit = ""
        note = "Fig9 two-level schedule" if "speedup" in k else "Fig13/Fig9"
        report(k, round(float(v), 2), unit, note)
