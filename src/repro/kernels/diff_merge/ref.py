"""Pure-jnp oracle for the diff_merge kernel (Table 3 semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def diff_merge_ref(a0, b0, b1, *, op: str = "sum"):
    a0f = a0.astype(jnp.float32)
    b0f = b0.astype(jnp.float32)
    b1f = b1.astype(jnp.float32)
    if op == "sum":
        merged = a0f + (b1f - b0f)
    elif op == "subtract":
        merged = a0f - (b0f - b1f)
    elif op == "multiply":
        merged = a0f * jnp.where(b0f == 0, 1.0, b1f / b0f)
    elif op == "divide":
        merged = a0f / jnp.where(b1f == 0, 1.0,
                                 jnp.where(b0f == 0, 1.0, b0f / b1f))
    elif op == "overwrite":
        merged = b1f
    else:
        raise ValueError(op)
    dirty = jnp.any(b0f != b1f, axis=1, keepdims=True)
    a1 = jnp.where(dirty, merged, a0f).astype(a0.dtype)
    return a1, dirty
