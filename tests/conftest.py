import os
import sys

# Tests run on the default 1-device CPU backend; multi-device distribution
# tests spawn subprocesses that set XLA_FLAGS themselves (see test_dist_*).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
