"""jit'd wrapper: diff+merge a whole state pytree leaf against a snapshot.

Pads flat leaves into (n_chunks, CHUNK) tiles and runs the fused kernel;
returns (merged leaf, dirty chunk mask) — the jit-side dense-diff path of
``core.diffsync`` accelerated for TPU deployment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.diffsync import CHUNK
from repro.kernels.diff_merge import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def diff_merge_leaf(a0, b0, b1, *, op: str = "sum",
                    interpret: bool | None = None):
    """a0 = main value, b0 = fork snapshot, b1 = child value (same shape).

    Returns (merged like a0, dirty (n_chunks,) bool)."""
    if interpret is None:
        interpret = _interpret_default()
    shape, dtype = a0.shape, a0.dtype
    flat = lambda x: x.reshape(-1)
    fa, fb0, fb1 = flat(a0), flat(b0), flat(b1)
    pad = (-fa.size) % CHUNK
    if pad:
        fa = jnp.pad(fa, (0, pad))
        fb0 = jnp.pad(fb0, (0, pad))
        fb1 = jnp.pad(fb1, (0, pad))
    tiles = lambda x: x.reshape(-1, CHUNK)
    n = fa.size // CHUNK
    rows = _k.BLOCK_ROWS if n % _k.BLOCK_ROWS == 0 else 1
    a1, dirty = _k.diff_merge(tiles(fa), tiles(fb0), tiles(fb1), op=op,
                              block_rows=rows, interpret=interpret)
    out = a1.reshape(-1)[: a0.size].reshape(shape).astype(dtype)
    return out, dirty[:, 0]
