"""Paper Fig 13 (MPI / ParRes kernels) — collective microbenchmarks.

Runs the ParRes-analogue kernels on an 8-device host mesh in a subprocess
(so the main process keeps 1 device):

  p2p      ring exchange via collective-permute (paper: p2p kernel)
  nstream  axpy over sharded arrays + barrier  (paper: nstream)
  reduce   all-reduce: flat vs hierarchical vs ring vs compressed
  stencil  halo exchange via ppermute          (paper: stencil)

Reports wall time per op and the slow-link byte counts of each schedule
(the quantity Faabric's VM-leader schedule minimises, Fig 9).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PROG = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.core.compat import make_mesh, shard_map

mesh = make_mesh((2, 4), ("pod", "data"))
out = {}
REPS = __REPS__

def timeit(f, *args, reps=REPS):
    r = jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps

n = 1 << __LOG_N__
vec = jnp.arange(8 * n, dtype=jnp.float32).reshape(8, n)

# --- p2p ring (collective-permute) ---
def p2p(x):
    def body(v):
        perm = [(i, (i + 1) % 4) for i in range(4)]
        return jax.lax.ppermute(v, "data", perm)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data")),
                                 out_specs=P(("pod","data")),
                                 check_vma=False))(x)
out["p2p_ring_us"] = timeit(p2p, vec) * 1e6

# --- nstream: axpy + allreduce barrier ---
def nstream(x):
    def body(v):
        v = v * 2.0 + 1.0
        s = jax.lax.psum(jnp.sum(v), ("pod", "data"))
        return v + 0.0 * s
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data")),
                                 out_specs=P(("pod","data")),
                                 check_vma=False))(x)
out["nstream_us"] = timeit(nstream, vec) * 1e6

# --- reduce: flat vs hierarchical vs ring vs compressed ---
tree = {"g": vec}
for mode, frac in (("flat", None), ("hierarchical", None), ("ring", None),
                   ("compressed", 0.05)):
    f = jax.jit(C.build_tree_allreduce(mesh, mode=mode, compress_frac=frac))
    resid = C.init_residual_buffer(mesh, {"g": vec[0]}) \
        if mode == "compressed" else None
    t = timeit(lambda v: f({"g": v}, resid)[0]["g"], vec)
    out[f"allreduce_{mode}_us"] = t * 1e6

# slow-link bytes per schedule (per chip, analytical; Fig 9's quantity)
bytes_full = n * 4
out["slowlink_bytes_flat"] = bytes_full          # whole vector crosses
out["slowlink_bytes_hierarchical"] = bytes_full // 4   # 1/n_fast shard
out["slowlink_bytes_compressed"] = int(bytes_full // 4 * 0.05 * 2)

# --- stencil: halo exchange ---
def stencil(x):
    def body(v):
        perm_f = [(i, (i + 1) % 4) for i in range(4)]
        perm_b = [((i + 1) % 4, i) for i in range(4)]
        left = jax.lax.ppermute(v[:, -128:], "data", perm_f)
        right = jax.lax.ppermute(v[:, :128], "data", perm_b)
        mid = v.at[:, :128].add(left).at[:, -128:].add(right)
        return mid * 0.25
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data"), None),
                                 out_specs=P(("pod","data"), None),
                                 check_vma=False))(x)
grid = jnp.ones((8, 4096), jnp.float32)
out["stencil_us"] = timeit(stencil, grid) * 1e6

print(json.dumps(out))
"""


def run(report, tiny=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    prog = textwrap.dedent(_PROG) \
        .replace("__REPS__", "2" if tiny else "20") \
        .replace("__LOG_N__", "14" if tiny else "20")
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    for k, v in data.items():
        unit = "us" if k.endswith("_us") else "bytes/chip"
        report(k, round(v, 1), unit, "Fig13/Fig9")
    hier = data["allreduce_hierarchical_us"]
    flat = data["allreduce_flat_us"]
    report("hierarchical_vs_flat_speedup", round(flat / hier, 2), "x",
           "Fig9 two-level schedule")
